"""Lightweight rescheduling demo (paper §3.4 + Fig. 11 + Table 4).

Scenario 1 — workload shift: live traffic drifts from coding to
conversation; the profiler detects the shift and the scheduler flips phase
designations + re-solves the TSTP in well under a second, with no parameter
reload.

Scenario 2 — node failure: one node (4 GPUs) dies; the affected replicas are
dropped and the survivors are re-designated. Compares lightweight vs full
rescheduling vs doing nothing, on simulated SLO attainment.

  PYTHONPATH=src python examples/reschedule_demo.py
"""
import sys
import time

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core import scheduler
from repro.core.cluster import make_paper_cloud
from repro.core.orchestrator import SloSpec
from repro.core.simulator import simulate
from repro.core.workload import CODING, CONVERSATION, generate, mix


def main():
    cfg = get_config("llama-30b")
    cluster = make_paper_cloud()
    slo = SloSpec(ttft_s=2.0, tpot_s=0.15, e2e_s=30.0)
    rate = 2.0

    print("== initial deployment (coding workload) ==")
    plan = scheduler.schedule(cluster, cfg, CODING, rate, slo, n_step=40)
    print(plan.describe())

    print("\n== scenario 1: workload shift coding -> conversation ==")
    t0 = time.time()
    plan_shift = scheduler.reschedule_lightweight(
        cluster, cfg, plan, CONVERSATION, rate, slo)
    dt = time.time() - t0
    print(f"lightweight rescheduling took {dt*1e3:.0f}ms "
          f"(no parameter reload)")
    print(f"  P:D was {len(plan.prefill_replicas)}:"
          f"{len(plan.decode_replicas)} -> "
          f"{len(plan_shift.prefill_replicas)}:"
          f"{len(plan_shift.decode_replicas)}")
    reqs = generate(CONVERSATION, rate=rate, duration=60, seed=7)
    for name, p in (("stale plan", plan), ("lightweight", plan_shift)):
        r = simulate(cluster, cfg, p.replicas, p.orchestration, reqs, slo)
        print(f"  {name:12s} e2e_attain={r.e2e_attain:.3f} "
              f"thpt={r.throughput_tokens:.0f} tok/s")

    print("\n== scenario 2: node failure (4 of 32 GPUs offline) ==")
    dead = [d.idx for d in cluster.devices if d.node == 0]
    shrunk = scheduler.drop_nodes(cluster, plan_shift, dead)
    t0 = time.time()
    plan_fail = scheduler.reschedule_lightweight(
        cluster, cfg, plan_shift, CONVERSATION, rate, slo,
        init_solution=shrunk)
    t_light = time.time() - t0
    t0 = time.time()
    cluster_live = cluster.remove_nodes([0])
    plan_full = scheduler.schedule(cluster_live, cfg, CONVERSATION, rate,
                                   slo, n_step=40)
    t_full = time.time() - t0

    import repro.core.tabu as tabu
    noplan_sol = shrunk  # no rescheduling: keep surviving groups as-is
    solver = scheduler.LowerLevelSolver(cluster, cfg, CONVERSATION, rate, slo)
    _, noplan_reps, noplan_o = solver.solve(noplan_sol)

    print(f"  lightweight: {t_light:.2f}s search, 0s reload "
          f"(paper Table 4: 13±2 s total at real cluster scale)")
    print(f"  full:        {t_full:.2f}s search + ~103s parameter reload "
          f"(paper Table 4: 157±13 s)")
    for name, reps, o in (
            ("no-resched", noplan_reps, noplan_o),
            ("lightweight", plan_fail.replicas, plan_fail.orchestration),
            ("full", plan_full.replicas, plan_full.orchestration)):
        cl = cluster_live if name == "full" else cluster
        r = simulate(cl, cfg, reps, o, reqs, slo)
        print(f"  {name:12s} e2e_attain={r.e2e_attain:.3f} "
              f"thpt={r.throughput_tokens:.0f} tok/s")


if __name__ == "__main__":
    main()
