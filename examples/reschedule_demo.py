"""Lightweight rescheduling demo (paper §3.4 + Fig. 11 + Table 4).

Scenario 1 — workload shift: live traffic drifts from coding to
conversation; the profiler detects the shift and the scheduler flips phase
designations + re-solves the TSTP in well under a second, with no parameter
reload.

Scenario 2 — node failure: one node (4 GPUs) dies; the affected replicas are
dropped and the survivors are re-designated. Compares lightweight vs full
rescheduling vs doing nothing, on simulated SLO attainment.

Scenario 3 — LIVE: the same mechanism applied to a RUNNING gateway (real
reduced-config engines, real tokens): short-output traffic establishes a
baseline, long-output traffic triggers shift detection mid-trace, and
`maybe_reschedule` applies the new plan as an epoch transition — draining
and flipping replicas around their resident parameters, requeueing
in-flight requests, with zero dropped requests and zero reloads.

  PYTHONPATH=src python examples/reschedule_demo.py           # all three
  PYTHONPATH=src python examples/reschedule_demo.py --live    # scenario 3
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.core import scheduler, tabu
from repro.core.cluster import make_paper_cloud
from repro.core.orchestrator import SloSpec
from repro.core.simulator import simulate
from repro.core.workload import CODING, CONVERSATION, generate

SLO = SloSpec(ttft_s=2.0, tpot_s=0.15, e2e_s=30.0)

# engine-level trace scale of launch/serve.py: prompts ~ n_in/32, outputs
# ~ n_out/16 — the profiler is configured with the inverse so the cost
# model sees full-model workloads
IN_SCALE, OUT_SCALE = 32, 16


def offline_scenarios():
    cfg = get_config("llama-30b")
    cluster = make_paper_cloud()
    rate = 2.0

    print("== initial deployment (coding workload) ==")
    plan = scheduler.schedule(cluster, cfg, CODING, rate, SLO, n_step=40)
    print(plan.describe())

    print("\n== scenario 1: workload shift coding -> conversation ==")
    t0 = time.time()
    plan_shift = scheduler.reschedule_lightweight(
        cluster, cfg, plan, CONVERSATION, rate, SLO)
    dt = time.time() - t0
    print(f"lightweight rescheduling took {dt*1e3:.0f}ms "
          f"(no parameter reload)")
    delta = scheduler.plan_diff(plan, plan_shift)
    print(f"  delta: {delta.describe()}")
    print(f"  P:D was {len(plan.prefill_replicas)}:"
          f"{len(plan.decode_replicas)} -> "
          f"{len(plan_shift.prefill_replicas)}:"
          f"{len(plan_shift.decode_replicas)}")
    reqs = generate(CONVERSATION, rate=rate, duration=60, seed=7)
    for name, p in (("stale plan", plan), ("lightweight", plan_shift)):
        r = simulate(cluster, cfg, p.replicas, p.orchestration, reqs, SLO)
        print(f"  {name:12s} e2e_attain={r.e2e_attain:.3f} "
              f"thpt={r.throughput_tokens:.0f} tok/s")

    print("\n== scenario 2: node failure (4 of 32 GPUs offline) ==")
    dead = [d.idx for d in cluster.devices if d.node == 0]
    shrunk = scheduler.drop_nodes(cluster, plan_shift, dead)
    t0 = time.time()
    plan_fail = scheduler.reschedule_lightweight(
        cluster, cfg, plan_shift, CONVERSATION, rate, SLO,
        init_solution=shrunk)
    t_light = time.time() - t0
    t0 = time.time()
    cluster_live = cluster.remove_nodes([0])
    plan_full = scheduler.schedule(cluster_live, cfg, CONVERSATION, rate,
                                   SLO, n_step=40)
    t_full = time.time() - t0

    noplan_sol = shrunk  # no rescheduling: keep surviving groups as-is
    solver = scheduler.LowerLevelSolver(cluster, cfg, CONVERSATION, rate,
                                        SLO)
    _, noplan_reps, noplan_o = solver.solve(noplan_sol)

    print(f"  lightweight: {t_light:.2f}s search, 0s reload "
          f"(paper Table 4: 13±2 s total at real cluster scale)")
    print(f"  full:        {t_full:.2f}s search + ~103s parameter reload "
          f"(paper Table 4: 157±13 s)")
    for name, reps, o in (
            ("no-resched", noplan_reps, noplan_o),
            ("lightweight", plan_fail.replicas, plan_fail.orchestration),
            ("full", plan_full.replicas, plan_full.orchestration)):
        cl = cluster_live if name == "full" else cluster
        r = simulate(cl, cfg, reps, o, reqs, SLO)
        print(f"  {name:12s} e2e_attain={r.e2e_attain:.3f} "
              f"thpt={r.throughput_tokens:.0f} tok/s")


def live_scenario():
    from repro.serving.gateway import (ServeRequest, drive_open_loop,
                                       gateway_from_plan, summarize_handles,
                                       warmup_engines)
    from repro.serving.profiler import WorkloadProfiler

    print("== scenario 3: LIVE epoch transition on a running gateway ==")
    cfg_full = get_config("llama-30b")
    cluster = make_paper_cloud()
    solver = scheduler.LowerLevelSolver(cluster, cfg_full, CODING, 2.0, SLO)
    # four paper-cloud groups that each hold the full model; a
    # coding-shaped (prefill-heavy) initial designation
    groups = ((0, 1, 2, 3), (4, 5, 6, 7), tuple(range(8, 16)),
              tuple(range(16, 24)))
    sol = tabu.Solution(groups, ("prefill", "prefill", "prefill", "decode"))
    score, replicas, o = solver.solve(sol)
    plan = scheduler.DeploymentPlan(solution=sol, replicas=replicas,
                                    orchestration=o, score=score)
    print(f"  initial designation: P:{len(plan.prefill_replicas)} "
          f"D:{len(plan.decode_replicas)} on {len(groups)} resident groups")

    cfg = get_reduced("llama-30b")
    params = build_params(cfg)
    leaf_ids = {id(x) for x in jax.tree_util.tree_leaves(params)}
    gw = gateway_from_plan(plan, cfg, params, max_seq=64, max_slots=4,
                           chunk_size=4, backend="ref",
                           profiler=WorkloadProfiler(in_scale=IN_SCALE,
                                                     out_scale=OUT_SCALE))
    warmup_engines([h.engine for h in gw.pre], [h.engine for h in gw.dec],
                   cfg.vocab_size, backend="ref", prompt_lens=(12, 16))

    rng = np.random.default_rng(0)

    def req(rid, max_new):
        return ServeRequest(rid, rng.integers(
            1, cfg.vocab_size, int(rng.choice([10, 12, 16]))).astype(
                np.int32), max_new_tokens=max_new)

    print("  [phase A] short-output traffic (coding-like) ...")
    a = [(i * 0.03, req(i, 3)) for i in range(12)]
    ha = drive_open_loop(gw, a)
    gw.profiler.set_baseline()

    printed = [0]

    def tick(g):
        g.maybe_reschedule(cluster, cfg_full, rate=4.0, slo=SLO)
        for e in g.events[printed[0]:]:
            print(f"    | {e}")
        printed[0] = len(g.events)

    print("  [phase B] long-output traffic; control plane ticking ...")
    b = [(i * 0.08, req(100 + i, 12)) for i in range(16)]
    t0 = time.time()
    hb = drive_open_loop(gw, b, tick=tick, tick_interval_s=0.2)
    wall = time.time() - t0

    s = summarize_handles(ha + hb)
    resident_ok = all(
        {id(x) for x in jax.tree_util.tree_leaves(h.engine.params)}
        == leaf_ids for h in gw.pre + gw.dec)
    requeued = sum(h.restarts for h in hb)
    print(f"  epoch {gw.epoch}, P:{len(gw.pre)} D:{len(gw.dec)} after "
          f"{wall:.1f}s of phase B")
    print(f"  {s['n_done']}/{s['n_submitted']} requests DONE "
          f"(states {s['states']}), {requeued} requeued through the flip, "
          f"0 dropped")
    print(f"  no-reload invariant: params resident across flip = "
          f"{resident_ok}")
    if not resident_ok or s["n_done"] != s["n_submitted"]:
        raise SystemExit("live epoch transition violated an invariant")
    if gw.epoch == 0:
        print("  (cost model kept the designation; shift was detected but "
              "no flip scored better)")


def build_params(cfg):
    from repro.models import build
    api = build(cfg)
    return api.init(jax.random.PRNGKey(0))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--live", action="store_true",
                    help="run only the live gateway scenario (3)")
    args = ap.parse_args()
    if not args.live:
        offline_scenarios()
        print()
    live_scenario()


if __name__ == "__main__":
    main()
