"""Training driver: small LM trained for a few hundred steps on the packed
synthetic pipeline, with AdamW, cosine schedule, async checkpointing and a
mid-run restore (checkpoint/restart fault-tolerance demo).

The paper's system is a *serving* system, so serve_e2e.py is the primary
end-to-end driver; this exercises the training substrate (train_4k cells).
Default config is CPU-sized (--d-model/--layers scale it up to ~100M).

  PYTHONPATH=src python examples/train_e2e.py --steps 120
"""
import argparse
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, load_checkpoint, latest_step
from repro.configs import get_reduced
from repro.models import build
from repro.training import optimizer as opt
from repro.training.data import DataConfig, PackedLM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--outdir", default=None)
    args = ap.parse_args()

    cfg = get_reduced("llama-30b").replace(
        name="train-e2e", d_model=args.d_model, num_layers=args.layers,
        num_heads=args.d_model // 32, num_kv_heads=args.d_model // 32,
        head_dim=32, d_ff=args.d_model * 3, vocab_size=2048,
        vocab_chunk=args.seq)
    api = build(cfg)
    print(f"model: {cfg.num_layers}L d={cfg.d_model} "
          f"params={cfg.param_count()/1e6:.1f}M")

    params = api.init(jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    opt_state = opt.adamw_init(params)
    train_step = jax.jit(opt.make_train_step(api, ocfg))

    data = PackedLM(DataConfig(cfg.vocab_size, args.seq, args.batch))
    outdir = args.outdir or tempfile.mkdtemp(prefix="repro_ckpt_")
    ckpt = AsyncCheckpointer(outdir)

    losses = []
    t0 = time.time()
    restored_at = None
    for step, batch in enumerate(data):
        if step >= args.steps:
            break
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, stats = train_step(params, opt_state, jb)
        losses.append(float(stats["loss"]))
        if step % 20 == 0:
            print(f"step {step:4d} loss={losses[-1]:.4f} "
                  f"lr={float(stats['lr']):.2e} "
                  f"gnorm={float(stats['grad_norm']):.2f}")
        if step and step % args.ckpt_every == 0:
            ckpt.save({"params": params, "opt": opt_state}, step,
                      extra={"data": data.state()})
        # fault-tolerance demo: at 60% of the run, restore from the last
        # checkpoint (simulating a preemption + restart)
        if restored_at is None and step == int(args.steps * 0.6) \
                and latest_step(outdir) is not None:
            ckpt.wait()
            state, s, extra = load_checkpoint(
                outdir, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            data.restore(extra["data"])
            restored_at = (step, s)
            print(f"-- simulated failure: restored step {s} "
                  f"checkpoint (was at {step}) --")
    ckpt.wait()
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({args.steps/dt:.2f} steps/s)")
    k = max(len(losses) // 10, 1)
    print(f"loss: first10={np.mean(losses[:k]):.4f} "
          f"last10={np.mean(losses[-k:]):.4f} "
          f"(restore demo at {restored_at})")
    assert np.mean(losses[-k:]) < np.mean(losses[:k]), "loss did not drop"
    print("training loss decreased ✓; checkpoints in", outdir)


if __name__ == "__main__":
    main()
