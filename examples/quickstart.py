"""Quickstart: schedule LLaMA-30B serving on the paper's heterogeneous cloud.

Reproduces the Table 3 experience: the two-level scheduler partitions the
32-GPU pool into prefill/decode groups with per-group parallel configs and
TSTP request routing — then shows the coding vs conversation contrast.

  PYTHONPATH=src python examples/quickstart.py [--rate 2.0] [--steps 40]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core import scheduler
from repro.core.cluster import make_paper_cloud
from repro.core.orchestrator import SloSpec
from repro.core.workload import CODING, CONVERSATION


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--arch", default="llama-30b")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    cluster = make_paper_cloud()
    slo = SloSpec(ttft_s=2.0, tpot_s=0.15, e2e_s=30.0)

    print(f"cluster: {cluster.types()}  price=${cluster.price_per_hr():.2f}/hr")
    print(f"model:   {cfg.name} ({cfg.param_count()/1e9:.1f}B params)\n")

    for wl in (CODING, CONVERSATION):
        plan = scheduler.schedule(cluster, cfg, wl, rate=args.rate, slo=slo,
                                  n_step=args.steps, seed=0)
        print(f"=== {wl.name} workload "
              f"(mean in/out = {wl.mean_in:.0f}/{wl.mean_out:.0f}) ===")
        print(plan.describe())
        print(f"  search: {plan.search_seconds:.1f}s, {plan.evals} "
              f"lower-level evals\n")


if __name__ == "__main__":
    main()
