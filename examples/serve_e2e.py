"""End-to-end phase-split serving driver (the paper's kind of system).

Runs REAL model computation on CPU: a prefill engine and two decode engines
(reduced-config LLaMA-30B family), int4-quantized KV transfer between them,
continuous batching on decode, TSTP-style routing in the coordinator.
Reports per-request TTFT / TPOT / E2E and tokens/s.

  PYTHONPATH=src python examples/serve_e2e.py [--requests 12] [--no-compress]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import build
from repro.serving.coordinator import Coordinator
from repro.serving.engine import DecodeEngine, GenRequest, PrefillEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--arch", default="llama-30b")
    ap.add_argument("--no-compress", action="store_true")
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    print(f"model {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    prefill = PrefillEngine(cfg, params, max_seq=128)
    decodes = [DecodeEngine(cfg, params, max_slots=4, max_seq=128)
               for _ in range(2)]
    coord = Coordinator([prefill], decodes,
                        compress=not args.no_compress, backend="ref")

    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        n_in = int(rng.choice([16, 24, 32]))
        req = GenRequest(rid, rng.integers(
            1, cfg.vocab_size, size=n_in).astype(np.int32),
            max_new_tokens=args.max_new)
        coord.submit(req)
    done = coord.run_until_drained()
    wall = time.time() - t0

    ttft = [r.t_first - r.t_submit for r in done]
    e2e = [r.t_done - r.t_submit for r in done]
    tpot = [(r.t_done - r.t_first) / max(len(r.out_tokens) - 1, 1)
            for r in done]
    toks = sum(len(r.out_tokens) for r in done)
    print(f"\nfinished {len(done)}/{args.requests} requests in {wall:.2f}s "
          f"({toks/wall:.1f} tok/s)")
    print(f"TTFT  p50={np.percentile(ttft,50)*1e3:.0f}ms "
          f"p99={np.percentile(ttft,99)*1e3:.0f}ms")
    print(f"TPOT  p50={np.percentile(tpot,50)*1e3:.0f}ms")
    print(f"E2E   p50={np.percentile(e2e,50)*1e3:.0f}ms "
          f"p99={np.percentile(e2e,99)*1e3:.0f}ms")
    kv = "int4" if not args.no_compress else "raw bf16"
    print(f"KV transfer wire format: {kv}")
    syncs = sum(d.host_syncs for d in decodes)
    steps = sum(d.steps_run for d in decodes)
    print(f"decode host syncs: {syncs} for {steps} device steps "
          f"({steps / max(syncs, 1):.1f} steps/sync)")


if __name__ == "__main__":
    main()
