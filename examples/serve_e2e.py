"""End-to-end phase-split serving through the v2 gateway API.

Runs REAL model computation on CPU: a prefill engine and two decode engines
(reduced-config LLaMA-30B family), int4-quantized KV transfer between them,
continuous batching on decode — driven OPEN-LOOP: requests are submitted at
Poisson arrival times against the wall clock, tokens stream back through
``RequestHandle`` callbacks while later requests are still arriving, and
per-request TTFT / TPOT / E2E deadlines are accounted.

  PYTHONPATH=src python examples/serve_e2e.py [--requests 12] [--rate 3] \\
      [--transport sim] [--no-compress]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import build
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.gateway import (DONE, Gateway, SchedulerConfig,
                                   ServeRequest, drive_open_loop,
                                   summarize_handles, warmup_gateway)
from repro.serving.transport import InProcessTransport, SimNetworkTransport


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--arch", default="llama-30b")
    ap.add_argument("--rate", type=float, default=3.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--no-compress", action="store_true")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--transport", choices=("inproc", "sim"),
                    default="inproc")
    ap.add_argument("--ttft-slo", type=float, default=0.0,
                    help="per-request TTFT deadline in s (0 = none)")
    ap.add_argument("--e2e-slo", type=float, default=0.0,
                    help="per-request E2E deadline in s (0 = none)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged decode cache)")
    ap.add_argument("--pages", type=int, default=0,
                    help="page-pool budget per decode engine (0 = parity "
                         "with the dense max_slots x max_seq budget)")
    ap.add_argument("--no-paged", action="store_true",
                    help="use the dense slotted decode cache instead of "
                         "the paged int4-resident pool")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable the radix prefix cache (refcounted "
                         "copy-on-write page sharing + prefill skip)")
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="prefill chunk token budget per scheduler tick "
                         "(0 = one-shot prefill); with chunking, a long "
                         "prompt no longer head-of-line-blocks the TTFT "
                         "of short prompts behind it")
    ap.add_argument("--decode-chunk-steps", type=int, default=0,
                    help="decode steps per scheduler tick (0 = engine "
                         "default)")
    ap.add_argument("--max-prefill-batch", type=int, default=4,
                    help="max prompts per prefill dispatch/chunk tick")
    ap.add_argument("--chaos", action="store_true",
                    help="inject a decode-replica crash and a spot "
                         "preemption mid-trace (3 decode replicas so a "
                         "survivor remains; every request must still "
                         "finish)")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    print(f"model {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    prefill = PrefillEngine(cfg, params, max_seq=128)
    n_dec = 3 if args.chaos else 2
    decodes = [DecodeEngine(cfg, params, max_slots=4, max_seq=128,
                            paged=not args.no_paged,
                            page_size=args.page_size,
                            num_pages=args.pages or None,
                            prefix_sharing=not (args.no_paged
                                                or args.no_prefix_sharing))
               for _ in range(n_dec)]
    if decodes[0].paged_fallback:
        print(f"note: {decodes[0].paged_fallback}")
    if args.transport == "sim":
        # shared-ethernet-class link, full-model wire bytes (the reduced
        # engine computes, the FULL model's KV crosses the network)
        transport = SimNetworkTransport(alpha=5e-3, bandwidth=0.6e9,
                                        bytes_scale=400.0)
    else:
        transport = InProcessTransport()
    gw = Gateway([prefill], decodes, transport=transport,
                 scheduler=SchedulerConfig(
                     prefill_chunk_tokens=args.chunk_tokens,
                     max_prefill_batch=args.max_prefill_batch,
                     decode_chunk_steps=args.decode_chunk_steps),
                 compress=not args.no_compress, backend="ref")

    print("warming up jit caches...")
    warmup_gateway(gw, cfg.vocab_size, prompt_lens=(16, 24, 32))

    # open-loop Poisson trace: every prompt opens with a shared 16-token
    # "system prompt" (page-aligned — partial radix hits once the first
    # request donates its chain), and ~1/3 of requests repeat an earlier
    # prompt verbatim (full hits: prefill skipped entirely). Seed differs
    # from warmup_engines' rng(0) so the warmup donations don't collide
    # with the trace prompts.
    rng = np.random.default_rng(7)
    sys_prefix = rng.integers(1, cfg.vocab_size, 16).astype(np.int32)
    arrivals, prompts = [], []
    t = 0.0
    for rid in range(args.requests):
        t += rng.exponential(1.0 / args.rate)
        if prompts and rng.random() < 0.35:
            toks = prompts[int(rng.integers(len(prompts)))]
        else:
            n_in = int(rng.choice([16, 24, 32]))
            toks = np.concatenate([sys_prefix, rng.integers(
                1, cfg.vocab_size, n_in - 16).astype(np.int32)])
            prompts.append(toks)
        arrivals.append((t, ServeRequest(
            rid, toks, max_new_tokens=args.max_new,
            ttft_deadline_s=args.ttft_slo or float("inf"),
            e2e_deadline_s=args.e2e_slo or float("inf"))))

    ctl = None
    if args.chaos:
        from repro.serving.faults import (CRASH, PREEMPT, FaultEvent,
                                          FaultSchedule, install_chaos)
        span = args.requests / args.rate
        schedule = FaultSchedule([
            FaultEvent(t=0.3 * span, kind=CRASH, phase="decode", idx=-1,
                       require_busy=True),
            FaultEvent(t=0.55 * span, kind=PREEMPT, phase="decode", idx=-1,
                       grace_s=0.75, require_busy=True)])
        ctl = install_chaos(gw, schedule)
        print(f"chaos armed: decode crash at ~{0.4*span:.1f}s, spot "
              f"preemption (grace 0.75s) at ~{0.7*span:.1f}s")

    t0 = time.time()
    first_seen = {}

    def on_token(h, tok):
        if h.request.rid not in first_seen:
            first_seen[h.request.rid] = time.time() - t0
            if len(first_seen) <= 4:
                print(f"  rid {h.request.rid}: first token streamed at "
                      f"+{first_seen[h.request.rid]*1e3:.0f}ms "
                      f"({h.state}, {len(gw.queue)} queued behind it)")

    print(f"serving {args.requests} requests open-loop at "
          f"{args.rate:.1f} req/s ({args.transport} transport)...")
    handles = drive_open_loop(gw, arrivals, on_token=on_token)
    wall = time.time() - t0

    s = summarize_handles(handles)
    print(f"\nstates: {s['states']}  "
          f"({s['n_done']}/{s['n_submitted']} done, "
          f"{s['tokens']} tokens in {wall:.2f}s = "
          f"{s['tokens']/wall:.1f} tok/s)")
    print(f"TTFT  p50={s['ttft_p50_s']*1e3:.0f}ms "
          f"p99={s['ttft_p99_s']*1e3:.0f}ms")
    print(f"TPOT  p50={s['tpot_p50_s']*1e3:.0f}ms")
    print(f"E2E   p50={s['e2e_p50_s']*1e3:.0f}ms "
          f"p99={s['e2e_p99_s']*1e3:.0f}ms")
    print(f"goodput (deadline attainment): {s['goodput']*100:.0f}%")
    kv = "int4" if not args.no_compress else "raw bf16"
    print(f"KV transfer wire format: {kv}")
    if isinstance(transport, SimNetworkTransport):
        print(f"sim network: {transport.transfers} transfers, "
              f"{transport.bytes_sent/1e6:.1f}MB, "
              f"mean hop {transport.mean_delay_s*1e3:.1f}ms")
    syncs = sum(d.host_syncs for d in decodes)
    steps = sum(d.steps_run for d in decodes)
    print(f"decode host syncs: {syncs} for {steps} device steps "
          f"({steps / max(syncs, 1):.1f} steps/sync)")
    if decodes[0].paged:
        for i, d in enumerate(decodes):
            st = d.page_stats()
            print(f"decode {i} page pool: {st['pages']} pages x "
                  f"{st['page_size']} tok, peak {st['peak_in_use']} in use, "
                  f"{st['zero_copy_inserts']} zero-dequant wire inserts "
                  f"({st['reencoded_inserts']} re-encoded), "
                  f"{st['alloc_failures']} admission stalls")
    st = gw.stats()
    c = st["counters"]
    print(f"gateway stats: epoch={st['epoch']} retries={c['retries']} "
          f"requeues={c['requeues']} migrations={c['migrations']} "
          f"(tokens={c['migrated_tokens']}) "
          f"preemptions={c['preemptions']} failed={c['failed']}")
    if args.chunk_tokens > 0:
        print(f"chunked prefill: {c['chunk_ticks']} chunk ticks, "
              f"{c['chunked_prefills']} prompts chunked "
              f"(budget {args.chunk_tokens} tok/tick)")
    if st["page_pool"]:
        print(f"page pool (fleet): "
              f"{st['page_pool']['alloc_failures']:.0f} admission stalls, "
              f"{st['page_pool']['in_use']:.0f} pages still in use")
    pfx = st["prefix"]
    if pfx["hits"] or pfx["partial_hits"] or pfx["misses"]:
        pool = st["page_pool"] or {}
        print(f"prefix cache: {pfx['hits']} full hits (prefill skipped), "
              f"{pfx['partial_hits']} partial (suffix prefill), "
              f"{pfx['misses']} misses "
              f"(hit rate {pfx['hit_rate']*100:.0f}%, "
              f"{pfx['hit_tokens']} prompt tokens reused, "
              f"{pool.get('cow_copies', 0):.0f} COW copies)")
    print("replicas:", "  ".join(
        f"{r['phase']}:{r['idx']}={r['status']}"
        + (f"({r['suspect_why']})" if r["suspect_why"] else "")
        for r in st["replicas"]))
    if ctl is not None:
        print("chaos fired:", [
            {k: f.get(k) for k in ("kind", "idx", "migrated", "requeued")
             if k in f} for f in ctl.fired])
    if gw.events:
        print("events:", gw.events[:5])
    n_done = s["states"].get(DONE, 0)
    if n_done < args.requests:
        print(f"WARNING: only {n_done}/{args.requests} requests finished")
        if args.chaos:
            sys.exit(1)


if __name__ == "__main__":
    main()
