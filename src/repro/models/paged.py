"""Paged, quantization-resident decode cache format + decode step.

The decode KV cache is a pool of fixed-size PAGES (``page_size`` tokens)
shared by every slot of a decode engine; each slot names its pages through
a per-slot page table row. At rest the pages hold the SAME group-wise
affine int4 encoding the prefill->decode wire uses (``kernels/kv_quant``),
so an arriving ``KVWire`` scatters straight into pages with no dequant
round-trip, and attention dequantizes inside the kernel
(``kernels/paged_attention``). A bf16 residency exists for ablation.

Layout, per attention layer slot (stacked over ``n_super`` superblocks):

* int4: ``kp/vp (n_super, P, page_size*ppr, g//2) u8`` packed nibbles,
  ``ks/vs`` / ``kz/vz (n_super, P, page_size*ppr, 1) f32`` scale/zero.
  ``g = page_group(cfg)`` is the wire's position-aligned quantization
  group (g | Hkv*hd); ``ppr = Hkv*hd // g`` groups per token. Row
  ``t*ppr + r`` of a page is token ``t``'s r-th group — identical row
  order to the wire's flattened ``(L*len*ppr, g)`` quantization.
* bf16: ``k/v (n_super, P, page_size, Hkv, hd)``.

Page 0 is the TRASH page: page-table entries default to 0, and inactive
slots' in-scan tail writes land there (the chunked decode scan keeps
stepping inactive slots with frozen lengths; their garbage K/V must not
hit a page another slot owns). The allocator (``serving/page_pool``)
never hands page 0 out.

``decode_step_paged`` mirrors ``transformer.decode_step_inplace``: a
``fori_loop`` over superblocks, the new token's K/V quantized INLINE
(bit-identical to ``kv_quant_ref``) and scattered into the slot's tail
page, attention via ``ops.paged_decode_attention``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops
from repro.kernels.ref import kv_quant_ref
from repro.models import layers
from repro.models.layers import dense, norm_apply
from repro.models.transformer import (_apply_ffn, _embed_inputs, slot_kinds,
                                      unembed_matrix)
# the ONE group-selection rule (kernels/kv_layout.py — no cycle): pages
# must pick groups exactly like the wire's padded-extract path or
# zero-copy insertion silently degrades to re-encoding (lint rule R005)
from repro.kernels.kv_layout import pick_group

DEFAULT_PAGE_SIZE = 16


def page_group(cfg) -> int:
    """The quantization group width shared with the wire format: the
    largest candidate dividing Hkv*hd (groups never straddle tokens)."""
    return pick_group(cfg.num_kv_heads * cfg.head_dim)


def groups_per_token(cfg) -> int:
    return (cfg.num_kv_heads * cfg.head_dim) // page_group(cfg)


def table_width(max_seq: int, page_size: int) -> int:
    """Page-table row width: enough entries for a max_seq-token slot."""
    return -(-max_seq // page_size)


def paged_supported(cfg) -> bool:
    """Paged decode covers pure-attention stacks (dense/MoE/VLM): recurrent
    state is O(1) and not paged, ring-buffer SWA caches have their own
    bounded layout, and encoder-decoder (audio) caches are flat arrays.
    Softcap archs keep the dense path (the paged kernel does not apply
    tanh capping)."""
    if cfg.family == "audio" or cfg.sliding_window or cfg.attn_logit_softcap:
        return False
    mixes = {k.split("+")[0] for k in cfg.layer_kinds()}
    return mixes == {"attn"} and page_group(cfg) > 0


def init_paged_cache(cfg, max_slots: int, max_seq: int, num_pages: int, *,
                     page_size: int = DEFAULT_PAGE_SIZE,
                     resident: str = "int4", dtype=jnp.bfloat16):
    """Build the paged decode cache pytree: per-layer page buffers plus
    ``page_table (max_slots, W)`` (all trash) and ``lengths``."""
    assert paged_supported(cfg), cfg.name
    kinds = slot_kinds(cfg)
    n_super = cfg.num_layers // len(kinds)
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    ps = page_size
    cache = {}
    for s in range(len(kinds)):
        if resident == "int4":
            g = page_group(cfg)
            ppr = groups_per_token(cfg)
            R = ps * ppr
            cache[f"slot{s}"] = {
                "kp": jnp.zeros((n_super, num_pages, R, g // 2), jnp.uint8),
                "ks": jnp.zeros((n_super, num_pages, R, 1), jnp.float32),
                "kz": jnp.zeros((n_super, num_pages, R, 1), jnp.float32),
                "vp": jnp.zeros((n_super, num_pages, R, g // 2), jnp.uint8),
                "vs": jnp.zeros((n_super, num_pages, R, 1), jnp.float32),
                "vz": jnp.zeros((n_super, num_pages, R, 1), jnp.float32),
            }
        elif resident == "bf16":
            cache[f"slot{s}"] = {
                "k": jnp.zeros((n_super, num_pages, ps, Hkv, hd), dtype),
                "v": jnp.zeros((n_super, num_pages, ps, Hkv, hd), dtype),
            }
        else:
            raise ValueError(f"unknown residency {resident!r}")
    cache["page_table"] = jnp.zeros((max_slots, table_width(max_seq, ps)),
                                    jnp.int32)
    cache["lengths"] = jnp.zeros((max_slots,), jnp.int32)
    return cache


def decode_step_paged(cfg, params, cache, tokens, *, rt=None,
                      page_size: int = DEFAULT_PAGE_SIZE,
                      backend: str = "auto"):
    """One decode step against the paged pool. tokens: (B, 1).

    Appends each slot's new-token K/V to its tail page (quantized in-loop
    for the int4 residency) and attends through the page table with the
    fused-dequant kernel. Returns (logits, new_cache); only ``lengths``
    advances — page-table mutation is HOST business (admission/release in
    the engine), the device only ever writes through it.
    """
    kinds = slot_kinds(cfg)
    x = _embed_inputs(cfg, params, tokens)
    B = x.shape[0]
    positions = cache["lengths"][:, None]
    n_super = cfg.num_layers // len(kinds)
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    gq = cfg.num_heads // Hkv
    pt = cache["page_table"]
    W = pt.shape[1]
    int4 = "kp" in cache["slot0"]
    if int4:
        g = page_group(cfg)
        ppr = groups_per_token(cfg)
    ps = page_size
    # tail-page coordinates for this step's writes; clamped so a slot at
    # its very last position (or an inactive one) indexes a real table
    # entry — unallocated entries are 0, the trash page
    pos0 = positions[:, 0]
    tail_pi = jnp.minimum(pos0 // ps, W - 1)
    tail_page = jnp.take_along_axis(pt, tail_pi[:, None], axis=1)[:, 0]
    tail_page = jnp.maximum(tail_page, 0)
    tail_off = pos0 % ps
    block_cache = {k: v for k, v in cache.items()
                   if k not in ("lengths", "page_table")}

    def body(i, carry):
        x, bc = carry
        blk = jax.tree.map(lambda a: lax.dynamic_index_in_dim(
            a, i, 0, keepdims=False), params["blocks"])
        for s, kind in enumerate(kinds):
            p = blk[f"slot{s}"]
            h = norm_apply(cfg, p["norm1"], x)
            q, k, v = layers.attn_qkv(cfg, p["attn"], h, positions)
            slot = bc[f"slot{s}"]
            if int4:
                # append-path quantization IS the wire/ref kernel math
                # (kv_quant_ref), so page contents are bit-identical no
                # matter which path wrote a token
                kq, ksc, kzp = kv_quant_ref(k[:, 0].reshape(B * ppr, g))
                vq, vsc, vzp = kv_quant_ref(v[:, 0].reshape(B * ppr, g))
                rows = tail_off[:, None] * ppr + jnp.arange(ppr)[None]
                pg = tail_page[:, None]
                for name, val, width in (("kp", kq, g // 2), ("ks", ksc, 1),
                                         ("kz", kzp, 1), ("vp", vq, g // 2),
                                         ("vs", vsc, 1), ("vz", vzp, 1)):
                    slot[name] = slot[name].at[i, pg, rows].set(
                        val.reshape(B, ppr, width).astype(slot[name].dtype))
                kpages = tuple(lax.dynamic_index_in_dim(slot[n], i, 0,
                                                        keepdims=False)
                               for n in ("kp", "ks", "kz"))
                vpages = tuple(lax.dynamic_index_in_dim(slot[n], i, 0,
                                                        keepdims=False)
                               for n in ("vp", "vs", "vz"))
            else:
                slot["k"] = slot["k"].at[i, tail_page, tail_off].set(
                    k[:, 0].astype(slot["k"].dtype))
                slot["v"] = slot["v"].at[i, tail_page, tail_off].set(
                    v[:, 0].astype(slot["v"].dtype))
                kpages = lax.dynamic_index_in_dim(slot["k"], i, 0,
                                                  keepdims=False)
                vpages = lax.dynamic_index_in_dim(slot["v"], i, 0,
                                                  keepdims=False)
            bc[f"slot{s}"] = slot
            qr = q[:, 0].reshape(B, Hkv, gq, hd)
            o = ops.paged_decode_attention(qr, kpages, vpages, pt,
                                           pos0 + 1, page_size=ps,
                                           backend=backend)
            out = dense(p["attn"]["wo"],
                        o.reshape(B, 1, cfg.q_dim).astype(x.dtype))
            if cfg.parallel_block and "mlp" in p:
                x = x + out + layers.mlp_apply(cfg, p["mlp"], h)
            else:
                x = x + out
                if kind.split("+")[1] != "none":
                    delta, _ = _apply_ffn(cfg, p, kind, x, rt)
                    x = x + delta
        return (x, bc)

    x, block_cache = lax.fori_loop(0, n_super, body, (x, block_cache))
    x = norm_apply(cfg, params["final_norm"], x)
    w = unembed_matrix(cfg, params)
    logits = (x @ w).astype(jnp.float32)
    new_cache = dict(block_cache)
    new_cache["page_table"] = pt
    new_cache["lengths"] = cache["lengths"] + 1
    return logits, new_cache
