"""Mamba (selective SSM) layer — the recurrent half of the jamba hybrid.

Prefill/train path: chunked associative scan over time (diagonal-A selective
SSM is linear-recurrent, so `h_t = dA_t * h_{t-1} + dBx_t` composes
associatively). Chunking bounds the materialized (chunk, d_inner, d_state)
tensors so the memory roofline stays within VMEM-friendly tiles.

Decode path: single-step recurrence over carried (conv_state, ssm_state) —
O(1) per token, which is what makes the jamba long_500k cell runnable.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import causal_conv1d, dense, dense_init


def mamba_init(cfg, key):
    d, di = cfg.d_model, cfg.mamba_d_inner
    ds, dc, dtr = cfg.mamba_d_state, cfg.mamba_d_conv, cfg.mamba_dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_bias = jnp.log(jnp.exp(jnp.exp(
        jax.random.uniform(ks[4], (di,), jnp.float32)
        * (math.log(0.1) - math.log(0.001)) + math.log(0.001))) - 1.0 + 1e-9)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di),
        "conv_w": (jax.random.normal(ks[1], (dc, di), jnp.float32)
                   / math.sqrt(dc)).astype(jnp.bfloat16),
        "conv_b": jnp.zeros((di,), jnp.bfloat16),
        "x_proj": dense_init(ks[2], di, dtr + 2 * ds),
        "dt_proj": {"w": (jax.random.normal(ks[3], (dtr, di), jnp.float32)
                          * dtr ** -0.5).astype(jnp.bfloat16),
                    "b": dt_bias.astype(jnp.float32)},
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, scale=1.0 / math.sqrt(di)),
    }


def _ssm_inputs(cfg, p, x):
    """Shared front half: projections, conv, gate computation.

    Returns (xc, z, dA, dBx, C, D) with dA/dBx: (B, S, di, ds) fp32.
    """
    ds, dtr = cfg.mamba_d_state, cfg.mamba_dt_rank
    xz = dense(p["in_proj"], x)
    x_, z = jnp.split(xz, 2, axis=-1)
    return x_, z


def _selective_terms(cfg, p, xc):
    ds, dtr = cfg.mamba_d_state, cfg.mamba_dt_rank
    proj = dense(p["x_proj"], xc).astype(jnp.float32)  # (B,S,dtr+2ds)
    dt_r, Bmat, Cmat = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"]["w"].astype(jnp.float32)
                         + p["dt_proj"]["b"])          # (B,S,di)
    A = -jnp.exp(p["A_log"])                           # (di, ds)
    dA = jnp.exp(dt[..., None] * A)                    # (B,S,di,ds)
    # dt*x (B,S,di) outer B (B,S,ds) -> (B,S,di,ds)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * Bmat[..., None, :]
    return dA, dBx, Cmat


def mamba_apply(cfg, p, x, *, chunk: int = 512, state=None):
    """Full-sequence path. x: (B, S, d). state: optional carried
    (conv_state, ssm_state) from a previous segment. Returns (y, new_state).
    """
    B, S, d = x.shape
    di, ds = cfg.mamba_d_inner, cfg.mamba_d_state
    conv_state = state[0] if state is not None else None
    h0 = (state[1] if state is not None
          else jnp.zeros((B, di, ds), jnp.float32))

    x_, z = _ssm_inputs(cfg, p, x)
    xc, conv_state = causal_conv1d(x_, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)

    c = min(chunk, S)
    n = -(-S // c)
    pad = n * c - S
    xcp = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    xcb = jnp.swapaxes(xcp.reshape(B, n, c, di), 0, 1)  # (n,B,c,di)

    def chunk_step(h, xcb_i):
        dA, dBx, Cmat = _selective_terms(cfg, p, xcb_i)  # (B,c,di,ds)x2,(B,c,ds)
        # prepend carried state as an extra "step" with dA=1
        ones = jnp.ones((B, 1, di, ds), jnp.float32)
        a = jnp.concatenate([ones, dA], axis=1)
        b = jnp.concatenate([h[:, None], dBx], axis=1)

        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a1 * a2, a2 * b1 + b2

        _, hs = lax.associative_scan(combine, (a, b), axis=1)
        h_new = hs[:, -1]
        y = jnp.einsum("bcns,bcs->bcn", hs[:, 1:], Cmat)  # (B,c,di)
        return h_new, y

    h_final, yb = lax.scan(chunk_step, h0, xcb)
    y = jnp.swapaxes(yb, 0, 1).reshape(B, n * c, di)[:, :S]
    y = y + cfg_D(p) * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = dense(p["out_proj"], y.astype(x.dtype))
    return out, (conv_state, h_final)


def cfg_D(p):
    return p["D"]


def mamba_decode_step(cfg, p, x, state):
    """Single-token step. x: (B, 1, d); state=(conv_state, ssm_state)."""
    B = x.shape[0]
    conv_state, h = state
    x_, z = _ssm_inputs(cfg, p, x)
    xc, conv_state = causal_conv1d(x_, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dA, dBx, Cmat = _selective_terms(cfg, p, xc)       # (B,1,di,ds)
    h = dA[:, 0] * h + dBx[:, 0]                       # (B,di,ds)
    y = jnp.einsum("bns,bs->bn", h, Cmat[:, 0])[:, None]  # (B,1,di)
    y = y + cfg_D(p) * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = dense(p["out_proj"], y.astype(x.dtype))
    return out, (conv_state, h)


def mamba_state_init(cfg, batch: int, dtype=jnp.bfloat16):
    di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return (jnp.zeros((batch, dc - 1, di), dtype),
            jnp.zeros((batch, di, ds), jnp.float32))
