"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, exact sequential recurrence).

mLSTM is linear-recurrent with exponential gating, so prefill/train uses a
chunkwise form: quadratic attention *within* a chunk + recurrent matrix-state
carry *across* chunks, all in stabilized log-space (running max ``m``).
Decode carries (C, n, m) per head — O(1) state, so long_500k runs.

sLSTM has nonlinear recurrence (h feeds back through R) — inherently
sequential; we scan over time. Exactness over speed: this matches the paper's
own characterization (sLSTM is not parallelizable).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import causal_conv1d, dense, dense_init, rmsnorm

# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(cfg, key):
    d = cfg.d_model
    di = 2 * d
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d, 2 * di),
        "conv_w": (jax.random.normal(ks[1], (4, di), jnp.float32) / 2.0
                   ).astype(jnp.bfloat16),
        "conv_b": jnp.zeros((di,), jnp.bfloat16),
        "wq": dense_init(ks[2], di, di),
        "wk": dense_init(ks[3], di, di),
        "wv": dense_init(ks[4], di, di),
        "w_if": dense_init(ks[5], di, 2 * H, bias=True),
        "skip": jnp.ones((di,), jnp.float32),
        "gn": {"scale": jnp.zeros((di,), jnp.float32)},
        "w_down": dense_init(ks[6], di, d, scale=1.0 / math.sqrt(di)),
    }


def _mlstm_chunk(q, k, v, lf, li, state):
    """One chunk, stabilized chunkwise-parallel mLSTM.

    q,k,v: (B,H,L,dh); lf/li: (B,H,L) log forget/input gates.
    state: (C (B,H,dh,dh), n (B,H,dh), m (B,H)). Returns (y, new_state).
    """
    B, H, L, dh = q.shape
    C0, n0, m0 = state
    b = jnp.cumsum(lf, axis=-1)                      # (B,H,L) cumulative decay
    # score[t,s] = b_t - b_s + li_s  (decay s->t times input gate), s <= t
    score = b[..., :, None] - b[..., None, :] + li[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    score = jnp.where(tri, score, -jnp.inf)
    m_intra = score.max(-1)                          # (B,H,L)
    m_t = jnp.maximum(m0[..., None] + b, m_intra)    # (B,H,L)
    # intra-chunk weights & inter-chunk carry factor
    w = jnp.exp(score - m_t[..., None])              # (B,H,L,L)
    carry = jnp.exp(m0[..., None] + b - m_t)         # (B,H,L)
    qs = q.astype(jnp.float32) / math.sqrt(dh)
    sim = jnp.einsum("bhtd,bhsd->bhts", qs, k.astype(jnp.float32))
    aw = w * sim                                     # gated attention weights
    num = (jnp.einsum("bhts,bhsd->bhtd", aw, v.astype(jnp.float32))
           + carry[..., None] * jnp.einsum("bhde,bhtd->bhte", C0, qs))
    den = (aw.sum(-1) + carry * jnp.einsum("bhd,bhtd->bht", n0, qs))
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
    y = num / den[..., None]                         # (B,H,L,dh)
    # chunk-end state
    mL = m_t[..., -1]
    wL = jnp.exp(b[..., -1:] - b + li - mL[..., None])  # (B,H,L)
    C = (jnp.exp(m0 + b[..., -1] - mL)[..., None, None] * C0
         + jnp.einsum("bhs,bhsd,bhse->bhde", wL, k.astype(jnp.float32),
                      v.astype(jnp.float32)))
    n = (jnp.exp(m0 + b[..., -1] - mL)[..., None] * n0
         + jnp.einsum("bhs,bhsd->bhd", wL, k.astype(jnp.float32)))
    return y, (C, n, mL)


def mlstm_cell(cfg, q, k, v, lf, li, state, chunk: int):
    """q,k,v: (B,S,H,dh). Scans chunks; returns (y (B,S,H,dh), state)."""
    B, S, H, dh = q.shape
    c = min(chunk, S)
    n = -(-S // c)
    pad = n * c - S

    def prep(x):
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        x = x.reshape((B, n, c) + x.shape[2:])
        return jnp.moveaxis(x, 1, 0)  # (n, B, c, ...)

    qb, kb, vb = (jnp.swapaxes(prep(t), -2, -3) for t in (q, k, v))
    # -> (n, B, H, c, dh)
    lfb, lib = (jnp.swapaxes(prep(t), -1, -2) for t in (lf, li))  # (n,B,H,c)

    def step(st, xs):
        qi, ki, vi, lfi, lii = xs
        y, st = _mlstm_chunk(qi, ki, vi, lfi, lii, st)
        return st, y

    state, yb = lax.scan(step, state, (qb, kb, vb, lfb, lib))
    y = jnp.moveaxis(yb, 0, 1).reshape(B, n, H, c, dh).swapaxes(2, 3)
    y = y.reshape(B, n * c, H, dh)[:, :S]
    return y, state


def mlstm_state_init(cfg, batch: int):
    H = cfg.num_heads
    dh = 2 * cfg.d_model // H
    return (jnp.zeros((batch, H, dh, dh), jnp.float32),
            jnp.zeros((batch, H, dh), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))


def mlstm_apply(cfg, p, x, *, state=None, conv_state=None):
    """Full mLSTM block. x: (B,S,d). Returns (out, (cell_state, conv_state))."""
    B, S, d = x.shape
    H = cfg.num_heads
    di = 2 * d
    dh = di // H
    u = dense(p["w_up"], x)
    xm, z = jnp.split(u, 2, axis=-1)
    xc, conv_state = causal_conv1d(xm, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    q = dense(p["wq"], xc).reshape(B, S, H, dh)
    k = dense(p["wk"], xc).reshape(B, S, H, dh)
    v = dense(p["wv"], xm).reshape(B, S, H, dh)
    gates = dense(p["w_if"], xm).astype(jnp.float32)   # (B,S,2H)
    li, lfraw = jnp.split(gates, 2, axis=-1)
    lf = jax.nn.log_sigmoid(lfraw)
    if state is None:
        state = mlstm_state_init(cfg, B)
    y, state = mlstm_cell(cfg, q, k, v, lf, li, state, cfg.xlstm_chunk)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y, p["gn"]["scale"])                   # per-block norm (GN-ish)
    y = y + p["skip"].astype(y.dtype) * xc
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return dense(p["w_down"], y), (state, conv_state)


def mlstm_decode_step(cfg, p, x, state, conv_state):
    """x: (B,1,d). Single-step recurrence (no chunking)."""
    B, _, d = x.shape
    H, di = cfg.num_heads, 2 * cfg.d_model
    dh = di // H
    u = dense(p["w_up"], x)
    xm, z = jnp.split(u, 2, axis=-1)
    xc, conv_state = causal_conv1d(xm, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    q = dense(p["wq"], xc).reshape(B, H, dh).astype(jnp.float32) / math.sqrt(dh)
    k = dense(p["wk"], xc).reshape(B, H, dh).astype(jnp.float32)
    v = dense(p["wv"], xm).reshape(B, H, dh).astype(jnp.float32)
    gates = dense(p["w_if"], xm)[:, 0].astype(jnp.float32)
    li, lfraw = jnp.split(gates, 2, axis=-1)           # (B,H)
    lf = jax.nn.log_sigmoid(lfraw)
    C, n, m = state
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)[..., None]
    iw = jnp.exp(li - m_new)[..., None]
    C = fw[..., None] * C + iw[..., None] * k[..., None] * v[..., None, :]
    n = fw * n + iw * k
    num = jnp.einsum("bhde,bhd->bhe", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, 1, di).astype(x.dtype)
    y = rmsnorm(y, p["gn"]["scale"])
    y = y + p["skip"].astype(y.dtype) * xc
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return dense(p["w_down"], y), (C, n, m_new), conv_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(cfg, key):
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 5)
    f_ff = int(d * 4 / 3 / 8) * 8 * 2  # GeGLU ffn at ~4/3 ratio (x2 for gate)
    return {
        "w_in": dense_init(ks[0], d, 4 * d, bias=True),  # z,i,f,o preacts
        "r": (jax.random.normal(ks[1], (4, H, dh, dh), jnp.float32)
              / math.sqrt(dh)).astype(jnp.float32),      # recurrent, per head
        "gn": {"scale": jnp.zeros((d,), jnp.float32)},
        "ffn_wi": dense_init(ks[2], d, f_ff),
        "ffn_wo": dense_init(ks[3], f_ff // 2, d),
    }


def _slstm_step(p, carry, x_pre):
    """carry: (c,n,h,m) each (B,H,dh); x_pre: (B,4,H,dh) input preacts."""
    c, n, h, m = carry
    r = p["r"]
    rec = jnp.einsum("bhd,ghde->bghe", h, r)           # (B,4,H,dh)
    za, ia, fa, oa = [x_pre[:, i] + rec[:, i] for i in range(4)]
    z = jnp.tanh(za)
    o = jax.nn.sigmoid(oa)
    lf = jax.nn.log_sigmoid(fa)
    m_new = jnp.maximum(lf + m, ia)
    fw = jnp.exp(lf + m - m_new)
    iw = jnp.exp(ia - m_new)
    c = fw * c + iw * z
    n = fw * n + iw
    h = o * c / jnp.maximum(n, 1e-6)
    return (c, n, h, m_new), h


def slstm_apply(cfg, p, x, *, state=None):
    """x: (B,S,d). Sequential scan over time. Returns (out, state)."""
    B, S, d = x.shape
    H = cfg.num_heads
    dh = d // H
    pre = dense(p["w_in"], x).astype(jnp.float32)      # (B,S,4d)
    pre = pre.reshape(B, S, 4, H, dh)
    if state is None:
        state = slstm_state_init(cfg, B)
    xs = jnp.moveaxis(pre, 1, 0)                       # (S,B,4,H,dh)
    state, hs = lax.scan(lambda cr, xp: _slstm_step(p, cr, xp), state, xs)
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    y = rmsnorm(y, p["gn"]["scale"])
    # gated FFN
    g, u = jnp.split(dense(p["ffn_wi"], y), 2, axis=-1)
    out = dense(p["ffn_wo"], jax.nn.gelu(g) * u)
    return out, state


def slstm_state_init(cfg, batch: int):
    H = cfg.num_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return (z, z, z, jnp.full((batch, H, dh), -1e30, jnp.float32))


def slstm_decode_step(cfg, p, x, state):
    out, state = slstm_apply(cfg, p, x, state=state)
    return out, state
