from repro.models.registry import ModelApi, build, build_for_cell  # noqa: F401
