"""Core JAX layers shared by every architecture family.

Pure-functional: params are nested dicts of jnp arrays. The attention here is
the jnp reference/production-CPU path; the Pallas TPU kernels in
``repro.kernels`` are selected by ``ops`` wrappers when running on TPU.

The blocked attention (`attention`) is flash-structured (online softmax over
KV chunks inside a scan) so the lowered HLO has flash-like memory behaviour —
this is what the dry-run rooflines see.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x, scale, bias=None, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def norm_apply(cfg, p, x):
    if cfg.norm_type == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p.get("bias"))


def norm_init(cfg, d):
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# Rotary position embeddings (with partial-rotary support)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, rotary_pct: float, theta: float):
    rot_dim = int(head_dim * rotary_pct) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv, rot_dim


def apply_rope(x, positions, *, rotary_pct: float = 1.0, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if theta <= 0.0:
        return x  # NoPE archs (jamba) / abs-pos archs (whisper, xlstm)
    hd = x.shape[-1]
    inv, rot_dim = rope_freqs(hd, rotary_pct, theta)
    if rot_dim == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = xr[..., : rot_dim // 2], xr[..., rot_dim // 2:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rot_dim < hd else out


def sinusoidal_pos(positions, d_model: int):
    half = d_model // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Dense / projection helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in, d_out, *, bias=False, scale=None, dtype=jnp.bfloat16):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_init(cfg, key, d_model=None, d_ff=None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2 = jax.random.split(key)
    gated = cfg.mlp_type in ("silu", "geglu")
    wi = dense_init(k1, d, (2 * f) if gated else f, bias=cfg.mlp_bias)
    wo = dense_init(k2, f, d, bias=cfg.mlp_bias, scale=1.0 / math.sqrt(f))
    return {"wi": wi, "wo": wo}


def mlp_apply(cfg, p, x):
    h = dense(p["wi"], x)
    if cfg.mlp_type in ("silu", "geglu"):
        g, u = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(g) if cfg.mlp_type == "silu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jax.nn.gelu(h)
    return dense(p["wo"], h)


# ---------------------------------------------------------------------------
# Blocked (flash-structured) attention — jnp path
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _mask_bias(qpos, kpos, *, causal, window, kv_len):
    """(..., Sq, Sk) additive bias from causal / sliding-window / length mask."""
    m = jnp.zeros(qpos.shape[:-1] + (qpos.shape[-1], kpos.shape[-1]),
                  jnp.float32)
    qp = qpos[..., :, None]
    kp = kpos[..., None, :]
    ok = jnp.ones_like(m, dtype=bool)
    if causal:
        ok &= kp <= qp
    if window and window > 0:
        ok &= kp > qp - window
    if kv_len is not None:
        ok &= kp < kv_len[..., None, None]
    return jnp.where(ok, m, NEG_INF)


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                  q_offset=None, kv_len=None):
    """Reference attention, materializes scores. q:(B,Sq,H,hd) k/v:(B,Sk,Hk,hd)."""
    B, Sq, H, hd = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    g = H // Hk
    qh = q.reshape(B, Sq, Hk, g, hd)
    # bf16 operands, f32 accumulation (MXU-native; avoids materializing f32
    # copies of Q/K/V — critical for the decode KV-cache memory roofline)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = (jnp.arange(Sq) if q_offset is None
            else q_offset[:, None] + jnp.arange(Sq)[None, :])
    if q_offset is None:
        qpos = jnp.broadcast_to(qpos, (B, Sq))
    kpos = jnp.broadcast_to(jnp.arange(Sk), (B, Sk))
    bias = _mask_bias(qpos, kpos, causal=causal, window=window, kv_len=kv_len)
    s = s + bias[:, None, None]
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def attention(q, k, v, *, causal=True, window=0, softcap=0.0,
              q_offset=None, kv_len=None, chunk_q=512, chunk_kv=1024):
    """Flash-structured blocked attention.

    Outer scan over query chunks, inner scan over KV chunks with an online
    softmax (running max / sum / accumulator), so no (Sq, Sk) score tensor is
    ever materialized. Used for both prefill (Sq large) and decode (Sq == 1).
    """
    B, Sq, H, hd = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    g = H // Hk
    if Sq * Sk <= 256 * 256:  # small: reference path is cheaper than scans
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, q_offset=q_offset, kv_len=kv_len)
    cq = min(chunk_q, Sq)
    ck = min(chunk_kv, Sk)
    nq, nk = -(-Sq // cq), -(-Sk // ck)
    pq, pk = nq * cq - Sq, nk * ck - Sk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    # effective kv length masks out kv padding
    eff_len = (jnp.full((B,), Sk, jnp.int32) if kv_len is None
               else kv_len.astype(jnp.int32))
    qoff = jnp.zeros((B,), jnp.int32) if q_offset is None else q_offset

    qb = qp.reshape(B, nq, cq, Hk, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = kp.reshape(B, nk, ck, Hk, hd).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, ck, Hk, hd).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / math.sqrt(hd)

    def q_step(_, qi_blk):
        qi, blk = qi_blk  # qi: scalar block idx; blk: (B, cq, Hk, g, hd)
        qpos = qoff[:, None] + qi * cq + jnp.arange(cq)[None, :]  # (B, cq)

        def kv_step(carry, kv_blk):
            m, l, acc = carry
            ki, kblk, vblk = kv_blk
            kpos = ki * ck + jnp.arange(ck)[None, :]  # (1, ck) -> broadcast
            kpos = jnp.broadcast_to(kpos, (B, ck))
            # bf16 operands + f32 accumulation (no f32 copies of K/V blocks)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", blk, kblk,
                           preferred_element_type=jnp.float32) * scale
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            bias = _mask_bias(qpos, kpos, causal=causal, window=window,
                              kv_len=eff_len)
            s = s + bias[:, None, None]
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hk, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, g, cq), jnp.float32)
        a0 = jnp.zeros((B, Hk, g, cq, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, o.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,cq,Hk,g,hd)

    _, ob = lax.scan(q_step, None, (jnp.arange(nq), qb))
    o = ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * cq, H, hd)
    return o[:, :Sq]


# ---------------------------------------------------------------------------
# Attention block (projections + rope + cache handling)
# ---------------------------------------------------------------------------


def attn_init(cfg, key, *, cross=False):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], d, qd, bias=cfg.attn_bias),
        "wk": dense_init(ks[1], d, kvd, bias=cfg.attn_bias),
        "wv": dense_init(ks[2], d, kvd, bias=cfg.attn_bias),
        "wo": dense_init(ks[3], qd, d, bias=cfg.attn_bias,
                         scale=1.0 / math.sqrt(qd)),
    }
    if cfg.qk_norm:
        p["qnorm"] = {"scale": jnp.zeros((cfg.head_dim,), jnp.float32)}
        p["knorm"] = {"scale": jnp.zeros((cfg.head_dim,), jnp.float32)}
    return p


def attn_qkv(cfg, p, x, positions):
    from repro.distributed.sharding import constrain
    B, S, _ = x.shape
    q = dense(p["wq"], x).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = dense(p["wk"], x).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = dense(p["wv"], x).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    # no-ops under DEFAULT rules; the ATTN_QSEQ variant shards Q on sequence
    # and force-replicates the (small) MQA/GQA KV — constrained both before
    # and after rope so SPMD never invents a head_dim split in between
    q = constrain(q, "batch", "qseq", "heads", "kseq")
    k = constrain(k, "batch", None, "kv_heads", "kseq")
    v = constrain(v, "batch", None, "kv_heads", "kseq")
    if cfg.qk_norm:
        q = rmsnorm(q, p["qnorm"]["scale"])
        k = rmsnorm(k, p["knorm"]["scale"])
    q = apply_rope(q, positions, rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)
    k = apply_rope(k, positions, rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)
    q = constrain(q, "batch", "qseq", "heads", "kseq")
    k = constrain(k, "batch", None, "kv_heads", "kseq")
    return q, k, v


def attn_apply(cfg, p, x, *, positions, causal=True, window=None,
               kv_cache=None, kv_len=None, q_offset=None):
    """Self-attention. If kv_cache=(K,V) given, attends over cache (decode).

    Returns (out, (k_new, v_new)) where k_new/v_new are this call's fresh KV
    (for cache insertion by the caller).
    """
    q, k, v = attn_qkv(cfg, p, x, positions)
    win = cfg.sliding_window if window is None else window
    if kv_cache is not None:
        K, V = kv_cache
        o = attention(q, K, V, causal=causal, window=win, kv_len=kv_len,
                      softcap=cfg.attn_logit_softcap, q_offset=q_offset,
                      chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
    else:
        o = attention(q, k, v, causal=causal, window=win, kv_len=kv_len,
                      softcap=cfg.attn_logit_softcap, q_offset=q_offset,
                      chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
    B, S = x.shape[:2]
    out = dense(p["wo"], o.reshape(B, S, cfg.q_dim))
    return out, (k, v)


# ---------------------------------------------------------------------------
# Depthwise causal conv1d (mamba / xlstm)
# ---------------------------------------------------------------------------


def causal_conv1d(x, w, b=None, state=None):
    """x: (B, S, C); w: (K, C) depthwise; state: (B, K-1, C) carried for decode.

    Returns (y, new_state).
    """
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)  # (B, S+K-1, C)
    y = jnp.zeros((B, S, C), jnp.float32)
    for i in range(K):  # K is tiny (4); unrolled taps
        y = y + xx[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    new_state = xx[:, S:]
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Chunked softmax cross-entropy (never materializes (B,S,V) logits)
# ---------------------------------------------------------------------------


def chunked_xent(x, w_unembed, targets, *, chunk: int = 2048, mask=None):
    """x: (B,S,d) final hiddens; w_unembed: (d,V); targets: (B,S) int32.

    Scans over sequence chunks; per-chunk logits (B,chunk,V) live only inside
    the scan body — this is what keeps the train-step memory roofline sane for
    256k-vocab models.
    """
    B, S, d = x.shape
    c = min(chunk, S)
    n = -(-S // c)
    pad = n * c - S
    xs = jnp.pad(x, ((0, 0), (0, pad), (0, 0))).reshape(B, n, c, d)
    ts = jnp.pad(targets, ((0, 0), (0, pad))).reshape(B, n, c)
    ms = (jnp.ones((B, S), jnp.float32) if mask is None else mask)
    ms = jnp.pad(ms, ((0, 0), (0, pad))).reshape(B, n, c)
    xs, ts, ms = (jnp.swapaxes(a, 0, 1) for a in (xs, ts, ms))

    def step(carry, xtm):
        tot, cnt = carry
        xc, tc, mc = xtm
        logits = jnp.einsum("bcd,dv->bcv", xc, w_unembed,
                            preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (tot + nll.sum(), cnt + mc.sum()), None

    (tot, cnt), _ = lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)),
                             (xs, ts, ms))
    return tot / jnp.maximum(cnt, 1.0)
