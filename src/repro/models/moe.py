"""Mixture-of-Experts with capacity-based token dispatch.

Two execution paths sharing one routing implementation:

* ``moe_apply`` — plain jnp; under pjit the expert dimension of the weights is
  sharded over the ``model`` axis and XLA SPMD inserts the all-to-alls. This
  is the path lowered by the dry-run (and the smoke path on 1 CPU device).
* ``moe_apply_shard_map`` — explicit expert parallelism: tokens are
  sequence-sharded, dispatched into per-expert capacity buffers, exchanged
  with ``lax.all_to_all`` over the expert (``model``) mesh axis, FFN'd by the
  expert owners, and returned. Used by the optimized (beyond-paper) configs;
  validated against ``moe_apply`` on a multi-device CPU mesh in tests.

Routing follows GShard/Switch: top-k gates, renormalized, position-in-expert
via per-choice cumulative sums, tokens beyond ``capacity`` dropped (residual
passes through untouched — standard behaviour).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def moe_init(cfg, key):
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.moe_num_experts
    gated = cfg.mlp_type in ("silu", "geglu")
    ks = jax.random.split(key, 4)
    wi_dim = 2 * f if gated else f
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32)
                   / math.sqrt(d)).astype(jnp.float32),
        "wi": (jax.random.normal(ks[1], (E, d, wi_dim), jnp.float32)
               / math.sqrt(d)).astype(jnp.bfloat16),
        "wo": (jax.random.normal(ks[2], (E, f, d), jnp.float32)
               / math.sqrt(f)).astype(jnp.bfloat16),
    }
    if cfg.moe_shared_expert:
        p["shared"] = layers.mlp_init(cfg, ks[3], d_ff=f)
    return p


# ---------------------------------------------------------------------------
# Routing (shared)
# ---------------------------------------------------------------------------


def route(cfg, router_w, x2d, capacity: int):
    """x2d: (T, d) tokens. Returns (expert_idx, slot_pos, keep, gates): (T,k).

    Position-in-expert computed choice-major (all first choices get slots
    before any second choice) so higher-priority routes are dropped last.
    """
    T = x2d.shape[0]
    k, E = cfg.moe_top_k, cfg.moe_num_experts
    logits = (x2d.astype(jnp.float32) @ router_w)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    pos_list, base = [], jnp.zeros((E,), jnp.int32)
    for c in range(k):  # k is small & static
        oh = jax.nn.one_hot(idx[:, c], E, dtype=jnp.int32)  # (T, E)
        pos_c = (jnp.cumsum(oh, axis=0) - 1) + base[None, :]
        pos_list.append((pos_c * oh).sum(-1))
        base = base + oh.sum(0)
    pos = jnp.stack(pos_list, axis=1)  # (T, k)
    keep = pos < capacity
    # router z-loss / aux load-balance loss (Switch) for training
    me = probs.mean(0)                       # (E,) mean gate prob
    ce = jnp.zeros((E,), jnp.float32)
    for c in range(k):
        ce = ce + jax.nn.one_hot(idx[:, c], E, dtype=jnp.float32).mean(0)
    aux_loss = E * jnp.sum(me * ce / k)
    return idx, pos, keep, gates.astype(jnp.float32), aux_loss


def _expert_ffn(cfg, wi, wo, buf):
    """buf: (E, C, d) -> (E, C, d) via per-expert gated FFN."""
    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    if cfg.mlp_type in ("silu", "geglu"):
        g, u = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(g) if cfg.mlp_type == "silu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, wo)


# ---------------------------------------------------------------------------
# Path 1: plain jnp (XLA SPMD handles expert sharding)
# ---------------------------------------------------------------------------


def moe_apply(cfg, p, x, *, capacity_factor=None):
    """x: (B, S, d). Returns (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    cf = capacity_factor or cfg.moe_capacity_factor
    T = B * S
    # floor at min(T, 32): decode-sized token counts must not drop on expert
    # collisions (a 2-token step would otherwise get capacity 1)
    capacity = max(min(T, 32), int(math.ceil(T * k * cf / E)))
    x2d = x.reshape(T, d)
    idx, pos, keep, gates, aux = route(cfg, p["router"], x2d, capacity)

    buf = jnp.zeros((E, capacity, d), x.dtype)
    tok = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k)).reshape(-1)
    ef = idx.reshape(-1)
    pf = jnp.clip(pos.reshape(-1), 0, capacity - 1)
    kf = keep.reshape(-1)
    buf = buf.at[ef, pf].add(x2d[tok] * kf[:, None].astype(x.dtype),
                             mode="drop")
    y_buf = _expert_ffn(cfg, p["wi"], p["wo"], buf)  # (E, C, d)
    y_tok = y_buf[ef, pf] * (kf[:, None] * gates.reshape(-1)[:, None]
                             ).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok].add(y_tok)
    if cfg.moe_shared_expert:
        y = y + layers.mlp_apply(cfg, p["shared"], x2d)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Path 2: explicit expert-parallel shard_map
# ---------------------------------------------------------------------------


def moe_apply_shard_map(cfg, p, x, *, mesh, expert_axis="model",
                        data_axis="data", capacity_factor=None):
    """Expert parallelism with explicit a2a. x: (B, S, d) global.

    Tokens are sharded (batch over ``data_axis``, sequence over
    ``expert_axis``); expert weights are sharded over ``expert_axis``.
    Each device routes its local tokens, builds an (E, C_loc, d) buffer,
    all_to_all's it so device j receives the slots of its own E/ep experts
    from every peer in its data row, runs the FFN, and reverses the exchange.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    ep = mesh.shape[expert_axis]
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    assert E % ep == 0, (E, ep)
    B, S, d = x.shape
    cf = capacity_factor or cfg.moe_capacity_factor
    seq_shard = S % ep == 0 and S >= ep

    data_axes = ("pod", data_axis) if "pod" in mesh.shape else (data_axis,)
    n_data = math.prod(mesh.shape[a] for a in data_axes)
    batch_shard = B % n_data == 0
    data_spec = data_axes if batch_shard else None
    b_loc = B // n_data if batch_shard else B

    if seq_shard:
        x_spec = P(data_spec, expert_axis, None)
        T_loc = b_loc * (S // ep)
    else:  # decode / tiny-S: tokens replicated over expert axis
        x_spec = P(data_spec, None, None)
        T_loc = b_loc * S
    capacity = max(min(T_loc, 32), int(math.ceil(T_loc * k * cf / E)))

    w_specs = {"router": P(None, None), "wi": P(expert_axis, None, None),
               "wo": P(expert_axis, None, None)}
    if cfg.moe_shared_expert:
        w_specs["shared"] = {"wi": {"w": P(None, expert_axis)},
                             "wo": {"w": P(expert_axis, None)}}

    def local_fn(router_w, wi, wo, xl):
        t = xl.shape[0] * xl.shape[1]
        x2d = xl.reshape(t, d)
        idx, pos, keep, gates, aux = route(cfg, router_w, x2d, capacity)
        tok = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
        ef, pf = idx.reshape(-1), jnp.clip(pos.reshape(-1), 0, capacity - 1)
        kf = keep.reshape(-1)

        if seq_shard:
            buf = jnp.zeros((E, capacity, d), xl.dtype)
            buf = buf.at[ef, pf].add(
                x2d[tok] * kf[:, None].astype(xl.dtype), mode="drop")
            # (E, C, d) -> (E/ep, ep*C, d): expert owners gather their slots
            # (peer-major along the capacity axis).
            buf = lax.all_to_all(buf, expert_axis, split_axis=0,
                                 concat_axis=1, tiled=True)
            y_buf = _expert_ffn(cfg, wi, wo, buf)
            # (E/ep, ep*C, d) -> (E, C, d): results return to token owners.
            y_buf = lax.all_to_all(y_buf, expert_axis, split_axis=1,
                                   concat_axis=0, tiled=True)
            y_tok = y_buf[ef, pf]
            y_tok = y_tok * (kf[:, None] * gates.reshape(-1)[:, None]
                             ).astype(xl.dtype)
            y = jnp.zeros((t, d), xl.dtype).at[tok].add(y_tok)
        else:
            # replicated tokens: each device serves only its local experts,
            # combine with a psum over the expert axis.
            eid = lax.axis_index(expert_axis)
            lo = eid * (E // ep)
            local = (ef >= lo) & (ef < lo + (E // ep))
            buf = jnp.zeros((E // ep, capacity, d), xl.dtype)
            buf = buf.at[jnp.where(local, ef - lo, 0), pf].add(
                x2d[tok] * (kf & local)[:, None].astype(xl.dtype), mode="drop")
            y_buf = _expert_ffn(cfg, wi, wo, buf)
            y_tok = y_buf[jnp.where(local, ef - lo, 0), pf]
            y_tok = y_tok * ((kf & local)[:, None]
                             * gates.reshape(-1)[:, None]).astype(xl.dtype)
            y = jnp.zeros((t, d), xl.dtype).at[tok].add(y_tok)
            y = lax.psum(y, expert_axis)
        aux = lax.pmean(aux, tuple(mesh.shape.keys()))
        return y.reshape(xl.shape), aux

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(w_specs["router"], w_specs["wi"], w_specs["wo"], x_spec),
        out_specs=(x_spec, P()), check_rep=False)
    y, aux = fn(p["router"], p["wi"], p["wo"], x)
    if cfg.moe_shared_expert:
        y = y + layers.mlp_apply(cfg, p["shared"], x)
    return y, aux
