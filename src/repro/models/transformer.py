"""Unified decoder-only transformer covering dense / MoE / VLM / hybrid / SSM
families, with scan-over-superblocks and a slotted state cache.

Layer structure is periodic (period = lcm of the interleave frequencies), so
the layer stack is a ``lax.scan`` over ``num_layers // period`` superblocks;
each superblock applies ``period`` slots with distinct param groups. This
keeps the HLO size O(period) regardless of depth — essential for compiling
the 94-layer MoE on a 512-device mesh.

Three entry points per model:
  * ``forward``  — full-sequence hidden states (training / loss)
  * ``prefill``  — forward + build the decode cache, return last-token logits
  * ``decode``   — one token against the cache (per-sequence positions)
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import constrain
from repro.models import layers, mamba, moe, xlstm
from repro.models.layers import dense, dense_init, norm_apply, norm_init


# ---------------------------------------------------------------------------
# Periodic structure
# ---------------------------------------------------------------------------


def _lcm(a, b):
    return a * b // math.gcd(a, b)


def period_of(cfg) -> int:
    p = 1
    if cfg.family == "hybrid" and cfg.attn_period:
        p = _lcm(p, cfg.attn_period)
    if cfg.moe_num_experts and cfg.moe_every > 1:
        p = _lcm(p, cfg.moe_every)
    if cfg.family == "ssm" and cfg.xlstm_slstm_every:
        p = _lcm(p, cfg.xlstm_slstm_every)
    if cfg.num_layers % p != 0:
        p = cfg.num_layers  # irregular: unroll everything
    return p


def slot_kinds(cfg):
    """Kinds of the first `period` layers (they repeat)."""
    kinds = cfg.layer_kinds()
    p = period_of(cfg)
    for i in range(p, cfg.num_layers):
        assert kinds[i] == kinds[i % p], (i, kinds[i], kinds[i % p])
    return kinds[:p]


# ---------------------------------------------------------------------------
# Per-slot block init/apply
# ---------------------------------------------------------------------------


def _block_init(cfg, kind: str, key):
    mix, ffn = kind.split("+")
    ks = jax.random.split(key, 4)
    p = {"norm1": norm_init(cfg, cfg.d_model)}
    if mix == "attn":
        p["attn"] = layers.attn_init(cfg, ks[0])
    elif mix == "mamba":
        p["mamba"] = mamba.mamba_init(cfg, ks[0])
    elif mix == "mlstm":
        p["mlstm"] = xlstm.mlstm_init(cfg, ks[0])
    elif mix == "slstm":
        p["slstm"] = xlstm.slstm_init(cfg, ks[0])
    if ffn == "moe":
        p["moe"] = moe.moe_init(cfg, ks[1])
        p["norm2"] = norm_init(cfg, cfg.d_model)
    elif ffn == "mlp":
        p["mlp"] = layers.mlp_init(cfg, ks[1])
        if not cfg.parallel_block:
            p["norm2"] = norm_init(cfg, cfg.d_model)
    return p


def _mix_cache_init(cfg, kind: str, batch: int, max_seq: int, dtype):
    """Abstract-safe cache slot for one layer of this kind."""
    mix = kind.split("+")[0]
    if mix == "attn":
        # sliding-window archs only ever need `window` cache slots
        s = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
        if cfg.decode_cache_layout == "hkv_s":
            shape = (batch, cfg.num_kv_heads, s, cfg.head_dim)
        else:
            shape = (batch, s, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if mix == "mamba":
        cs, ss = mamba.mamba_state_init(cfg, batch, dtype)
        return {"conv": cs, "ssm": ss}
    if mix == "mlstm":
        C, n, m = xlstm.mlstm_state_init(cfg, batch)
        conv = jnp.zeros((batch, 3, 2 * cfg.d_model), dtype)
        return {"C": C, "n": n, "m": m, "conv": conv}
    if mix == "slstm":
        c, n, h, m = xlstm.slstm_state_init(cfg, batch)
        return {"c": c, "n": n, "h": h, "m": m}
    raise ValueError(kind)


def _apply_ffn(cfg, p, kind, x, rt):
    """FFN half of a block. Returns (delta, aux_loss)."""
    ffn = kind.split("+")[1]
    if ffn == "none":
        return jnp.zeros_like(x), 0.0
    h = norm_apply(cfg, p.get("norm2", p["norm1"]), x)
    if ffn == "moe":
        if rt is not None and rt.moe_shard_map and rt.mesh is not None:
            return moe.moe_apply_shard_map(cfg, p["moe"], h, mesh=rt.mesh)
        return moe.moe_apply(cfg, p["moe"], h)
    return layers.mlp_apply(cfg, p["mlp"], h), 0.0


def _block_apply(cfg, p, kind, x, *, positions, mode, cache_slot, q_offset,
                 kv_len, window, rt):
    """Apply one block. Returns (x, new_cache_slot, aux_loss).

    mode: "full" (train/prefill over a whole sequence; new KV returned for
    cache construction) or "step" (decode: read+update cache).
    """
    mix = kind.split("+")[0]
    h = norm_apply(cfg, p["norm1"], x)
    aux = 0.0
    new_slot = cache_slot

    if mix == "attn":
        if mode == "full":
            out, (k_new, v_new) = layers.attn_apply(
                cfg, p["attn"], h, positions=positions, causal=True,
                window=window, kv_len=kv_len, q_offset=q_offset)
            new_slot = (k_new, v_new)
        else:  # decode step against cache
            K, V = cache_slot["k"], cache_slot["v"]
            s_cache = K.shape[1]
            q, k, v = layers.attn_qkv(cfg, p["attn"], h, positions)
            if cfg.sliding_window and s_cache == cfg.sliding_window:
                # ring-buffer write for windowed cache
                w_pos = positions[:, 0] % s_cache
            else:
                w_pos = positions[:, 0]
            bidx = jnp.arange(K.shape[0])
            K = K.at[bidx, w_pos].set(k[:, 0])
            V = V.at[bidx, w_pos].set(v[:, 0])
            if cfg.sliding_window and s_cache == cfg.sliding_window:
                klen = jnp.minimum(positions[:, 0] + 1, s_cache)
                o = layers.attention(q, K, V, causal=False, window=0,
                                     kv_len=klen,
                                     softcap=cfg.attn_logit_softcap,
                                     chunk_q=cfg.attn_chunk_q,
                                     chunk_kv=cfg.attn_chunk_kv)
            else:
                o = layers.attention(q, K, V, causal=True, window=window,
                                     kv_len=positions[:, 0] + 1,
                                     q_offset=positions[:, 0],
                                     softcap=cfg.attn_logit_softcap,
                                     chunk_q=cfg.attn_chunk_q,
                                     chunk_kv=cfg.attn_chunk_kv)
            B = x.shape[0]
            out = dense(p["attn"]["wo"], o.reshape(B, 1, cfg.q_dim))
            new_slot = {"k": K, "v": V}
    elif mix == "mamba":
        if mode == "full":
            out, (conv_s, ssm_s) = mamba.mamba_apply(cfg, p["mamba"], h)
            new_slot = {"conv": conv_s, "ssm": ssm_s}
        else:
            out, (conv_s, ssm_s) = mamba.mamba_decode_step(
                cfg, p["mamba"], h, (cache_slot["conv"], cache_slot["ssm"]))
            new_slot = {"conv": conv_s, "ssm": ssm_s}
    elif mix == "mlstm":
        if mode == "full":
            out, (st, conv_s) = xlstm.mlstm_apply(cfg, p["mlstm"], h)
            new_slot = {"C": st[0], "n": st[1], "m": st[2], "conv": conv_s}
        else:
            out, st, conv_s = xlstm.mlstm_decode_step(
                cfg, p["mlstm"], h,
                (cache_slot["C"], cache_slot["n"], cache_slot["m"]),
                cache_slot["conv"])
            new_slot = {"C": st[0], "n": st[1], "m": st[2], "conv": conv_s}
    elif mix == "slstm":
        st = (None if mode == "full" else
              (cache_slot["c"], cache_slot["n"], cache_slot["h"],
               cache_slot["m"]))
        out, st = xlstm.slstm_apply(cfg, p["slstm"], h, state=st)
        new_slot = {"c": st[0], "n": st[1], "h": st[2], "m": st[3]}
    else:
        raise ValueError(kind)

    out = constrain(out, "batch", "seq", None)
    if cfg.parallel_block and "mlp" in p:
        # parallel residual: x + attn(n(x)) + mlp(n(x)) (single shared norm)
        out = out + layers.mlp_apply(cfg, p["mlp"], h)
        x = x + out
    else:
        x = x + out
        if kind.split("+")[1] != "none" and not (
                cfg.parallel_block and "mlp" in p):
            delta, aux = _apply_ffn(cfg, p, kind, x, rt)
            x = x + delta
    x = constrain(x, "batch", "seq", None)
    return x, new_slot, aux


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Runtime:
    """Execution context: mesh + feature flags (plumbed, not global)."""

    def __init__(self, mesh=None, moe_shard_map=False, inplace_decode=False):
        self.mesh = mesh
        self.moe_shard_map = moe_shard_map
        self.inplace_decode = inplace_decode


def init_params(cfg, key):
    kinds = slot_kinds(cfg)
    p_blocks = {}
    n_super = cfg.num_layers // len(kinds)
    key, *slot_keys = jax.random.split(key, len(kinds) + 1)
    for s, kind in enumerate(kinds):
        sub = jax.random.split(slot_keys[s], n_super)
        stacked = jax.vmap(lambda k: _block_init(cfg, kind, k))(sub)
        p_blocks[f"slot{s}"] = stacked
    key, k_embed, k_unembed, k_proj = jax.random.split(key, 4)
    params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(jnp.bfloat16),
        "blocks": p_blocks,
        "final_norm": norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(k_unembed, (cfg.d_model, cfg.vocab_size),
                              jnp.float32) / math.sqrt(cfg.d_model)
        ).astype(jnp.bfloat16)
    if cfg.family == "vlm":
        params["mm_projector"] = dense_init(k_proj, cfg.d_model, cfg.d_model)
    return params


def unembed_matrix(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def _embed_inputs(cfg, params, tokens, patch_embeds=None):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.family == "vlm" and patch_embeds is not None:
        pe = dense(params["mm_projector"], patch_embeds.astype(x.dtype))
        x = jnp.concatenate([pe, x], axis=1)
    return x


def _stack_scan(cfg, params, x, body, *, remat=None):
    """Scan over superblocks; body(carry_x, slot_params_for_one_super)
    returns (x, per_super_outputs)."""
    remat = cfg.remat if remat is None else remat
    kinds = slot_kinds(cfg)
    n_super = cfg.num_layers // len(kinds)
    blocks = params["blocks"]
    fn = body
    if remat:
        fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers and n_super > 1:
        x, ys = lax.scan(fn, x, blocks)
        return x, ys
    # unrolled
    ys = []
    for i in range(n_super):
        blk_i = jax.tree.map(lambda a: a[i], blocks)
        x, y = fn(x, blk_i)
        ys.append(y)
    ys = jax.tree.map(lambda *a: jnp.stack(a), *ys) if ys else None
    return x, ys


def forward(cfg, params, tokens, *, patch_embeds=None, rt=None,
            collect_cache=False, window=None):
    """Full-sequence forward. Returns (hidden, stacked_new_cache, aux_loss)."""
    kinds = slot_kinds(cfg)
    x = _embed_inputs(cfg, params, tokens, patch_embeds)
    x = constrain(x, "batch", "seq", None)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.rope_theta <= 0 and cfg.family in ("dense", "vlm", "moe"):
        pass  # NoPE
    aux_total = jnp.float32(0.0)

    def body(carry, blk):
        x = carry
        aux_sum = jnp.float32(0.0)
        slots_out = {}
        for s, kind in enumerate(kinds):
            x, new_slot, aux = _block_apply(
                cfg, blk[f"slot{s}"], kind, x, positions=positions,
                mode="full", cache_slot=None, q_offset=None, kv_len=None,
                window=window, rt=rt)
            aux_sum = aux_sum + aux
            if collect_cache:
                slots_out[f"slot{s}"] = new_slot
        return x, (slots_out, aux_sum) if collect_cache else aux_sum

    x, ys = _stack_scan(cfg, params, x, body,
                        remat=cfg.remat and not collect_cache)
    if collect_cache:
        caches, auxs = ys
        aux_total = jnp.sum(auxs)
    else:
        caches = None
        aux_total = jnp.sum(ys) if ys is not None else jnp.float32(0.0)
    x = norm_apply(cfg, params["final_norm"], x)
    return x, caches, aux_total


def loss_fn(cfg, params, batch, *, rt=None):
    """batch: {"tokens": (B,S), "targets": (B,S), optional "patch_embeds",
    "mask"}. Returns scalar loss."""
    x, _, aux = forward(cfg, params, batch["tokens"],
                        patch_embeds=batch.get("patch_embeds"), rt=rt)
    S_t = batch["targets"].shape[1]
    x = x[:, -S_t:]  # VLM: loss only over text positions
    w = unembed_matrix(cfg, params)
    nll = layers.chunked_xent(x, w, batch["targets"], chunk=cfg.vocab_chunk,
                              mask=batch.get("mask"))
    return nll + 0.01 * aux


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    kinds = slot_kinds(cfg)
    n_super = cfg.num_layers // len(kinds)

    def stack(t):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_super,) + a.shape), t)

    cache = {f"slot{s}": stack(_mix_cache_init(cfg, kind, batch, max_seq,
                                               dtype))
             for s, kind in enumerate(kinds)}
    cache["lengths"] = jnp.zeros((batch,), jnp.int32)
    return cache


def prefill(cfg, params, tokens, *, max_seq: int, patch_embeds=None, rt=None,
            window=None, last_pos=None, true_len=None):
    """Process the prompt; build the decode cache. Returns (logits_last, cache).

    ``last_pos``/``true_len`` support right-padded (bucketed) prompts:
    logits are gathered at each sequence's own last real position instead of
    ``S-1``, and the cache lengths record the real (unpadded) lengths.
    Causal attention makes right padding invisible to the real positions."""
    x, caches, _ = forward(cfg, params, tokens, patch_embeds=patch_embeds,
                           rt=rt, collect_cache=True, window=window)
    B, S = x.shape[:2]
    kinds = slot_kinds(cfg)
    cache = init_cache(cfg, B, max_seq,
                       jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    for s, kind in enumerate(kinds):
        mix = kind.split("+")[0]
        got = caches[f"slot{s}"]
        if mix == "attn":
            k_new, v_new = got  # (n_super, B, S, Hkv, hd)
            hkv_s = cfg.decode_cache_layout == "hkv_s"
            s_cache = cache[f"slot{s}"]["k"].shape[3 if hkv_s else 2]
            if cfg.sliding_window and s_cache == cfg.sliding_window and \
                    S > s_cache:
                # keep last `window` tokens, ROTATED so token at absolute
                # position p lands at ring index p % window (decode's
                # ring-buffer writes overwrite the oldest entry).
                k_new = jnp.roll(k_new[:, :, -s_cache:], S % s_cache, axis=2)
                v_new = jnp.roll(v_new[:, :, -s_cache:], S % s_cache, axis=2)
            upd_len = min(S, s_cache)
            k_upd, v_upd = k_new[:, :, :upd_len], v_new[:, :, :upd_len]
            if hkv_s:  # one transpose at the phase handoff, amortized
                k_upd = k_upd.transpose(0, 1, 3, 2, 4)
                v_upd = v_upd.transpose(0, 1, 3, 2, 4)
            cache[f"slot{s}"]["k"] = lax.dynamic_update_slice(
                cache[f"slot{s}"]["k"],
                k_upd.astype(cache[f"slot{s}"]["k"].dtype), (0, 0, 0, 0, 0))
            cache[f"slot{s}"]["v"] = lax.dynamic_update_slice(
                cache[f"slot{s}"]["v"],
                v_upd.astype(cache[f"slot{s}"]["v"].dtype), (0, 0, 0, 0, 0))
        else:
            cache[f"slot{s}"] = got
    cache["lengths"] = (jnp.full((B,), S, jnp.int32) if true_len is None
                        else true_len.astype(jnp.int32))
    w = unembed_matrix(cfg, params)
    if last_pos is None:
        h_last = x[:, -1:]
    else:
        h_last = x[jnp.arange(B), last_pos][:, None]
    logits = (h_last @ w).astype(jnp.float32)
    return logits, cache


def prefill_suffix(cfg, params, tokens, prefix_kv, prefix_len, *,
                   max_seq: int, rt=None, last_pos=None, true_len=None):
    """Prefill only a prompt SUFFIX against already-computed prefix KV
    (the prefix-cache partial-hit path). Returns (logits_last, cache).

    ``tokens (B, S)`` are the suffix tokens (right-padded to the bucket);
    ``prefix_kv`` maps ``slot{s}`` -> (k, v) of shape
    ``(n_super, B, P, Hkv, hd)`` — the shared prefix's KV, dequantized
    from resident pages and right-padded to ``P``; ``prefix_len (B,)``
    gives each sequence's true prefix length. Suffix queries sit at
    absolute positions ``prefix_len + i`` (RoPE included) and attend over
    [prefix ++ suffix] with prefix padding masked. The returned cache
    holds ONLY the suffix KV at rows ``0..S-1`` (lengths = suffix true
    lengths), so the bucketed wire extraction is unchanged — wire token
    ``t`` is absolute position ``prefix_len + t``, spliced onto the
    shared chain at a page boundary by the decode side.

    Pure-attention stacks only (the same families the paged pool
    serves); recurrent state cannot be sliced at a position boundary.
    """
    kinds = slot_kinds(cfg)
    assert all(k.split("+")[0] == "attn" for k in kinds), \
        "prefill_suffix requires a pure-attention stack"
    assert not cfg.sliding_window, "suffix prefill assumes full attention"
    x = _embed_inputs(cfg, params, tokens)
    x = constrain(x, "batch", "seq", None)
    B, S = x.shape[:2]
    positions = prefix_len[:, None] + jnp.arange(S)[None, :]
    n_super = cfg.num_layers // len(kinds)

    def body(carry, blk_and_pkv):
        x = carry
        blk, pkv = blk_and_pkv
        slots_out = {}
        for s, kind in enumerate(kinds):
            p = blk[f"slot{s}"]
            pk, pv = pkv[f"slot{s}"]
            h = norm_apply(cfg, p["norm1"], x)
            q, k, v = layers.attn_qkv(cfg, p["attn"], h, positions)
            K = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
            V = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
            o = _suffix_attention(cfg, q, K, V, prefix_len)
            out = dense(p["attn"]["wo"], o.reshape(B, S, cfg.q_dim))
            out = constrain(out, "batch", "seq", None)
            if cfg.parallel_block and "mlp" in p:
                x = x + out + layers.mlp_apply(cfg, p["mlp"], h)
            else:
                x = x + out
                if kind.split("+")[1] != "none":
                    delta, _ = _apply_ffn(cfg, p, kind, x, rt)
                    x = x + delta
            x = constrain(x, "batch", "seq", None)
            slots_out[f"slot{s}"] = (k, v)
        return x, slots_out

    blocks = params["blocks"]
    if cfg.scan_layers and n_super > 1:
        x, caches = lax.scan(body, x, (blocks, prefix_kv))
    else:
        ys = []
        for i in range(n_super):
            blk_i = jax.tree.map(lambda a: a[i], blocks)
            pkv_i = jax.tree.map(lambda a: a[i], prefix_kv)
            x, y = body(x, (blk_i, pkv_i))
            ys.append(y)
        caches = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    x = norm_apply(cfg, params["final_norm"], x)

    cache = init_cache(cfg, B, max_seq,
                       jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    hkv_s = cfg.decode_cache_layout == "hkv_s"
    for s in range(len(kinds)):
        k_new, v_new = caches[f"slot{s}"]     # (n_super, B, S, Hkv, hd)
        s_cache = cache[f"slot{s}"]["k"].shape[3 if hkv_s else 2]
        upd_len = min(S, s_cache)
        k_upd, v_upd = k_new[:, :, :upd_len], v_new[:, :, :upd_len]
        if hkv_s:
            k_upd = k_upd.transpose(0, 1, 3, 2, 4)
            v_upd = v_upd.transpose(0, 1, 3, 2, 4)
        cache[f"slot{s}"]["k"] = lax.dynamic_update_slice(
            cache[f"slot{s}"]["k"],
            k_upd.astype(cache[f"slot{s}"]["k"].dtype), (0, 0, 0, 0, 0))
        cache[f"slot{s}"]["v"] = lax.dynamic_update_slice(
            cache[f"slot{s}"]["v"],
            v_upd.astype(cache[f"slot{s}"]["v"].dtype), (0, 0, 0, 0, 0))
    cache["lengths"] = (jnp.full((B,), S, jnp.int32) if true_len is None
                        else true_len.astype(jnp.int32))
    w = unembed_matrix(cfg, params)
    if last_pos is None:
        h_last = x[:, -1:]
    else:
        h_last = x[jnp.arange(B), last_pos][:, None]
    logits = (h_last @ w).astype(jnp.float32)
    return logits, cache


def _suffix_attention(cfg, q, K, V, prefix_len):
    """Attention of suffix queries over [padded prefix ++ suffix] keys.

    q: (B, S, H, hd); K/V: (B, P+S, Hkv, hd). Prefix key j is absolute
    position j, valid iff j < prefix_len; suffix key j >= P is absolute
    position prefix_len + (j - P), causally visible to query i iff
    (j - P) <= i. Scores materialize — suffix buckets are small by
    construction (that is the point of the partial hit)."""
    B, S, H, hd = q.shape
    Sk, Hk = K.shape[1], K.shape[2]
    P = Sk - S
    g = H // Hk
    qh = q.reshape(B, S, Hk, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, K,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    j = jnp.arange(Sk)[None, None, :]
    i = jnp.arange(S)[None, :, None]
    plen = prefix_len[:, None, None]
    ok = jnp.where(j < P, j < plen, (j - P) <= i)
    bias = jnp.where(ok, 0.0, layers.NEG_INF)
    s = s + bias[:, None, None]
    w = jax.nn.softmax(s, axis=-1).astype(V.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, V,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, S, H, hd).astype(q.dtype)


def _decode_attn_hkv(cfg, q, K, V, kv_len):
    """Decode attention over a (B, Hkv, S, hd) cache — contraction dim
    innermost on both operands, so no transposed KV copy materializes
    (mirrors the Pallas flash-decode kernel's access pattern)."""
    B = q.shape[0]
    Hkv, g = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    S = K.shape[2]
    qr = q.reshape(B, Hkv, g, cfg.head_dim)
    s = jnp.einsum("bhgd,bhkd->bhgk", qr, K,
                   preferred_element_type=jnp.float32)
    s = s / math.sqrt(cfg.head_dim)
    if cfg.attn_logit_softcap:
        s = cfg.attn_logit_softcap * jnp.tanh(s / cfg.attn_logit_softcap)
    mask = jnp.arange(S)[None, None, None, :] < kv_len[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(V.dtype)
    o = jnp.einsum("bhgk,bhkd->bhgd", w, V,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, cfg.num_heads, cfg.head_dim).astype(q.dtype)


def decode_step_inplace(cfg, params, cache, tokens, *, rt=None, window=None):
    """One decode step with IN-PLACE cache updates (§Perf optimization).

    The scan-based ``decode_step`` re-materializes each layer's full KV slice
    through the ys-stacking mechanism (~2x full-cache traffic per step). This
    variant runs a ``fori_loop`` over superblocks and scatters the new token
    DIRECTLY into the stacked cache buffer (donated by the launcher), so the
    per-step HBM traffic collapses to: params + one cache READ + one-token
    writes — the true decode roofline.

    Restriction: attention slots only take this fast path; recurrent slots
    (mamba/xlstm states) are small and use slice+update, which XLA keeps
    in-place on the loop carry.
    """
    kinds = slot_kinds(cfg)
    x = _embed_inputs(cfg, params, tokens)
    B = x.shape[0]
    positions = cache["lengths"][:, None]
    x = constrain(x, "batch", None, None)
    n_super = cfg.num_layers // len(kinds)
    block_cache = {k: v for k, v in cache.items() if k != "lengths"}
    bidx = jnp.arange(B)

    def body(i, carry):
        x, bc = carry
        blk = jax.tree.map(lambda a: lax.dynamic_index_in_dim(
            a, i, 0, keepdims=False), params["blocks"])
        for s, kind in enumerate(kinds):
            mix = kind.split("+")[0]
            p = blk[f"slot{s}"]
            h = norm_apply(cfg, p["norm1"], x)
            if mix == "attn":
                slot = bc[f"slot{s}"]
                hkv_s = cfg.decode_cache_layout == "hkv_s"
                s_cache = slot["k"].shape[3 if hkv_s else 2]
                q, k, v = layers.attn_qkv(cfg, p["attn"], h, positions)
                ring = cfg.sliding_window and s_cache == cfg.sliding_window
                w_pos = (positions[:, 0] % s_cache) if ring \
                    else positions[:, 0]
                if hkv_s:
                    # flash-decode layout: scatter at [layer, b, :, pos]
                    slot["k"] = slot["k"].at[i, bidx, :, w_pos].set(k[:, 0])
                    slot["v"] = slot["v"].at[i, bidx, :, w_pos].set(v[:, 0])
                    K = lax.dynamic_index_in_dim(slot["k"], i, 0,
                                                 keepdims=False)
                    V = lax.dynamic_index_in_dim(slot["v"], i, 0,
                                                 keepdims=False)
                    klen = (jnp.minimum(positions[:, 0] + 1, s_cache)
                            if ring else positions[:, 0] + 1)
                    o = _decode_attn_hkv(cfg, q, K, V, klen)
                else:
                    # scatter ONE token straight into the stacked buffer
                    slot["k"] = slot["k"].at[i, bidx, w_pos].set(k[:, 0])
                    slot["v"] = slot["v"].at[i, bidx, w_pos].set(v[:, 0])
                    K = lax.dynamic_index_in_dim(slot["k"], i, 0,
                                                 keepdims=False)
                    V = lax.dynamic_index_in_dim(slot["v"], i, 0,
                                                 keepdims=False)
                    if ring:
                        klen = jnp.minimum(positions[:, 0] + 1, s_cache)
                        o = layers.attention(q, K, V, causal=False, window=0,
                                             kv_len=klen,
                                             softcap=cfg.attn_logit_softcap,
                                             chunk_q=cfg.attn_chunk_q,
                                             chunk_kv=cfg.attn_chunk_kv)
                    else:
                        o = layers.attention(q, K, V, causal=True,
                                             window=window or
                                             cfg.sliding_window,
                                             kv_len=positions[:, 0] + 1,
                                             q_offset=positions[:, 0],
                                             softcap=cfg.attn_logit_softcap,
                                             chunk_q=cfg.attn_chunk_q,
                                             chunk_kv=cfg.attn_chunk_kv)
                out = dense(p["attn"]["wo"], o.reshape(B, 1, cfg.q_dim))
                bc[f"slot{s}"] = slot
                x_new = x + out
                if cfg.parallel_block and "mlp" in p:
                    x_new = x_new + layers.mlp_apply(cfg, p["mlp"], h)
                    x = x_new
                else:
                    x = x_new
                    if kind.split("+")[1] != "none":
                        delta, _ = _apply_ffn(cfg, p, kind, x, rt)
                        x = x + delta
            else:
                csl = jax.tree.map(lambda a: lax.dynamic_index_in_dim(
                    a, i, 0, keepdims=False), bc[f"slot{s}"])
                x, new_slot, _ = _block_apply(
                    cfg, p, kind, x, positions=positions, mode="step",
                    cache_slot=csl, q_offset=None, kv_len=None,
                    window=window, rt=rt)
                bc[f"slot{s}"] = jax.tree.map(
                    lambda buf, ns: lax.dynamic_update_index_in_dim(
                        buf, ns.astype(buf.dtype), i, 0),
                    bc[f"slot{s}"], new_slot)
        return (x, bc)

    x, block_cache = lax.fori_loop(0, n_super, body, (x, block_cache))
    x = norm_apply(cfg, params["final_norm"], x)
    w = unembed_matrix(cfg, params)
    logits = (x @ w).astype(jnp.float32)
    new_cache = dict(block_cache)
    new_cache["lengths"] = cache["lengths"] + 1
    return logits, new_cache


def decode_step(cfg, params, cache, tokens, *, rt=None, window=None):
    """One decode step. tokens: (B, 1). Returns (logits, new_cache)."""
    if (rt is not None and rt.inplace_decode) or \
            cfg.decode_cache_layout == "hkv_s":
        return decode_step_inplace(cfg, params, cache, tokens, rt=rt,
                                   window=window)
    kinds = slot_kinds(cfg)
    x = _embed_inputs(cfg, params, tokens)
    B = x.shape[0]
    positions = cache["lengths"][:, None]  # (B,1) absolute positions
    x = constrain(x, "batch", None, None)

    def body(carry, blk_and_cache):
        x = carry
        blk, csl = blk_and_cache
        new_slots = {}
        for s, kind in enumerate(kinds):
            x, new_slot, _ = _block_apply(
                cfg, blk[f"slot{s}"], kind, x, positions=positions,
                mode="step", cache_slot=csl[f"slot{s}"], q_offset=None,
                kv_len=None, window=window, rt=rt)
            new_slots[f"slot{s}"] = new_slot
        return x, new_slots

    block_cache = {k: v for k, v in cache.items() if k != "lengths"}
    kinds_n = len(kinds)
    n_super = cfg.num_layers // kinds_n
    if cfg.scan_layers and n_super > 1:
        x, new_cache = lax.scan(body, x, (params["blocks"], block_cache))
    else:
        ys = []
        for i in range(n_super):
            blk_i = jax.tree.map(lambda a: a[i], params["blocks"])
            csl_i = jax.tree.map(lambda a: a[i], block_cache)
            x, y = body(x, (blk_i, csl_i))
            ys.append(y)
        new_cache = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    x = norm_apply(cfg, params["final_norm"], x)
    w = unembed_matrix(cfg, params)
    logits = (x @ w).astype(jnp.float32)
    new_cache["lengths"] = cache["lengths"] + 1
    return logits, new_cache
