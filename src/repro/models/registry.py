"""Uniform model API over all families + abstract input specs for the dry-run.

``build(cfg)`` returns a ``ModelApi`` with:
  init(key) -> params
  loss(params, batch) -> scalar            (train_4k)
  prefill(params, batch) -> (logits, cache) (prefill_32k)
  decode(params, cache, batch) -> (logits, cache) (decode_32k / long_500k)
  input_specs(shape) -> batch of ShapeDtypeStructs (no allocation)
  cache_specs(shape) -> abstract decode cache
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import paged, transformer, whisper


@dataclass
class ModelApi:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    input_specs: Callable
    cache_specs: Callable
    decode_chunk: Optional[Callable] = None
    # partial-prefix-hit path: prefill only a suffix against dequantized
    # prefix KV (transformer.prefill_suffix). None for stacks that can't
    # slice their state at a position boundary (recurrent, audio, SWA).
    prefill_suffix: Optional[Callable] = None
    # factory for the paged (page-table, int4-at-rest) decode path:
    # paged_decode_fns(page_size, backend) -> (step_fn, chunk_fn) with the
    # layout knobs closed over (they must be static under jit). None when
    # the arch can't page its cache (recurrent state, SWA ring buffers,
    # audio, softcap).
    paged_decode_fns: Optional[Callable] = None


def make_decode_chunk(decode_fn: Callable) -> Callable:
    """Build a device-resident greedy multi-token decode loop around a
    single-step ``decode(params, cache, batch) -> (logits, cache)``.

    The returned function runs ``n_steps`` decode steps as one ``lax.scan``
    — current tokens, per-slot EOS / max-token / max-seq done-flags, and the
    emitted token chunk all stay on device, so a serving engine pays one
    host round-trip per *chunk* instead of per token (DESIGN.md §3).

    state pytree: {"cur": (B,) int32 current token per slot,
                   "active": (B,) bool slot-occupied & not finished,
                   "n_out": (B,) int32 tokens emitted so far (incl. first),
                   "max_new": (B,) int32 per-request budget}.
    Returns (tokens (n_steps, B), valid (n_steps, B) bool, cache, state).
    Inactive slots keep their cache lengths frozen so free slots never
    advance; a slot's final token is emitted on the step that finishes it.
    """

    def decode_chunk(params, cache, state, *, n_steps: int, eos_id: int,
                     max_seq: int):
        max_new = state["max_new"]

        def step(carry, _):
            cache, cur, active, n_out = carry
            logits, new_cache = decode_fn(params, cache,
                                          {"tokens": cur[:, None]})
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            n_out = n_out + active.astype(jnp.int32)
            new_cache = dict(new_cache)
            new_cache["lengths"] = jnp.where(active, new_cache["lengths"],
                                             cache["lengths"])
            done = active & ((nxt == eos_id) | (n_out >= max_new)
                             | (new_cache["lengths"] >= max_seq - 1))
            emit = jnp.where(active, nxt, -1)
            cur = jnp.where(active, nxt, cur)
            return (new_cache, cur, active & ~done, n_out), (emit, active)

        carry = (cache, state["cur"], state["active"], state["n_out"])
        (cache, cur, active, n_out), (toks, valid) = jax.lax.scan(
            step, carry, None, length=n_steps)
        return toks, valid, cache, {"cur": cur, "active": active,
                                    "n_out": n_out, "max_new": max_new}

    return decode_chunk


def _effective_cfg(cfg: ModelConfig, shape: Optional[ShapeSpec]) -> ModelConfig:
    """Per-cell adjustments (documented in DESIGN.md):

    * jamba long_500k: its 4 attention layers fall back to SWA(4096) so the
      decode state is bounded (Mamba layers already are O(1)).
    """
    if shape is None:
        return cfg
    if shape.name == "long_500k" and cfg.family == "hybrid" \
            and not cfg.sliding_window:
        return cfg.replace(sliding_window=4096)
    return cfg


def _token_specs(cfg, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.family == "audio":
            return {"tokens": sds((B, S), i32),
                    "targets": sds((B, S), i32),
                    "frame_embeds": sds((B, cfg.encoder_seq, cfg.d_model),
                                        jnp.bfloat16)}
        if cfg.family == "vlm":
            s_text = S - cfg.num_patches
            return {"tokens": sds((B, s_text), i32),
                    "targets": sds((B, s_text), i32),
                    "patch_embeds": sds((B, cfg.num_patches, cfg.d_model),
                                        jnp.bfloat16)}
        return {"tokens": sds((B, S), i32), "targets": sds((B, S), i32)}
    if shape.kind == "prefill":
        if cfg.family == "audio":
            return {"tokens": sds((B, S), i32),
                    "frame_embeds": sds((B, cfg.encoder_seq, cfg.d_model),
                                        jnp.bfloat16)}
        if cfg.family == "vlm":
            return {"tokens": sds((B, S - cfg.num_patches), i32),
                    "patch_embeds": sds((B, cfg.num_patches, cfg.d_model),
                                        jnp.bfloat16)}
        return {"tokens": sds((B, S), i32)}
    # decode: one new token; the KV/state cache is a separate argument
    return {"tokens": sds((B, 1), i32)}


def build(cfg: ModelConfig, *, rt: Optional[transformer.Runtime] = None
          ) -> ModelApi:
    rt = rt or transformer.Runtime()

    if cfg.family == "audio":
        def init(key):
            return whisper.init_params(cfg, key)

        def loss(params, batch):
            return whisper.loss_fn(cfg, params, batch, rt=rt)

        def prefill_fn(params, batch, *, max_seq=None):
            return whisper.prefill(cfg, params, batch["tokens"],
                                   batch["frame_embeds"],
                                   max_seq=max_seq or batch["tokens"].shape[1],
                                   rt=rt, last_pos=batch.get("last_pos"),
                                   true_len=batch.get("true_len"))

        def decode_fn(params, cache, batch):
            return whisper.decode_step(cfg, params, cache, batch["tokens"],
                                       rt=rt)

        def cache_init(batch_size, max_seq):
            return whisper.init_cache(cfg, batch_size, max_seq)
    else:
        def init(key):
            return transformer.init_params(cfg, key)

        def loss(params, batch):
            return transformer.loss_fn(cfg, params, batch, rt=rt)

        def prefill_fn(params, batch, *, max_seq=None):
            S = batch["tokens"].shape[1]
            last_pos = batch.get("last_pos")
            true_len = batch.get("true_len")
            if cfg.family == "vlm" and "patch_embeds" in batch:
                n_patch = batch["patch_embeds"].shape[1]
                S += n_patch
                # token-indexed positions shift past the patch prefix
                if last_pos is not None:
                    last_pos = last_pos + n_patch
                if true_len is not None:
                    true_len = true_len + n_patch
            return transformer.prefill(
                cfg, params, batch["tokens"], max_seq=max_seq or S,
                patch_embeds=batch.get("patch_embeds"), rt=rt,
                last_pos=last_pos, true_len=true_len)

        def decode_fn(params, cache, batch):
            return transformer.decode_step(cfg, params, cache,
                                           batch["tokens"], rt=rt)

        def cache_init(batch_size, max_seq):
            return transformer.init_cache(cfg, batch_size, max_seq)

    suffix_fn = None
    if cfg.family != "audio" and paged.paged_supported(cfg):
        def suffix_fn(params, batch, *, max_seq=None):
            S = batch["tokens"].shape[1]
            return transformer.prefill_suffix(
                cfg, params, batch["tokens"], batch["prefix_kv"],
                batch["prefix_len"], max_seq=max_seq or S, rt=rt,
                last_pos=batch.get("last_pos"),
                true_len=batch.get("true_len"))

    def input_specs(shape: ShapeSpec):
        ecfg = _effective_cfg(cfg, shape)
        return _token_specs(ecfg, shape)

    def cache_specs(shape: ShapeSpec):
        ecfg = _effective_cfg(cfg, shape)
        init_fn = (whisper.init_cache if ecfg.family == "audio"
                   else transformer.init_cache)
        return jax.eval_shape(
            lambda: init_fn(ecfg, shape.global_batch, shape.seq_len))

    paged_fns = None
    if paged.paged_supported(cfg):
        def paged_fns(page_size: int, backend: str = "auto"):
            def step_fn(params, cache, batch):
                return paged.decode_step_paged(
                    cfg, params, cache, batch["tokens"], rt=rt,
                    page_size=page_size, backend=backend)
            return step_fn, make_decode_chunk(step_fn)

    return ModelApi(cfg=cfg, init=init, loss=loss, prefill=prefill_fn,
                    decode=decode_fn, input_specs=input_specs,
                    cache_specs=cache_specs,
                    decode_chunk=make_decode_chunk(decode_fn),
                    prefill_suffix=suffix_fn,
                    paged_decode_fns=paged_fns)


def build_for_cell(cfg: ModelConfig, shape: ShapeSpec,
                   rt: Optional[transformer.Runtime] = None) -> ModelApi:
    """Model with per-cell config adjustments applied (see _effective_cfg)."""
    return build(_effective_cfg(cfg, shape), rt=rt)
