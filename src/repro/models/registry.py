"""Uniform model API over all families + abstract input specs for the dry-run.

``build(cfg)`` returns a ``ModelApi`` with:
  init(key) -> params
  loss(params, batch) -> scalar            (train_4k)
  prefill(params, batch) -> (logits, cache) (prefill_32k)
  decode(params, cache, batch) -> (logits, cache) (decode_32k / long_500k)
  input_specs(shape) -> batch of ShapeDtypeStructs (no allocation)
  cache_specs(shape) -> abstract decode cache
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import transformer, whisper


@dataclass
class ModelApi:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    input_specs: Callable
    cache_specs: Callable


def _effective_cfg(cfg: ModelConfig, shape: Optional[ShapeSpec]) -> ModelConfig:
    """Per-cell adjustments (documented in DESIGN.md):

    * jamba long_500k: its 4 attention layers fall back to SWA(4096) so the
      decode state is bounded (Mamba layers already are O(1)).
    """
    if shape is None:
        return cfg
    if shape.name == "long_500k" and cfg.family == "hybrid" \
            and not cfg.sliding_window:
        return cfg.replace(sliding_window=4096)
    return cfg


def _token_specs(cfg, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.family == "audio":
            return {"tokens": sds((B, S), i32),
                    "targets": sds((B, S), i32),
                    "frame_embeds": sds((B, cfg.encoder_seq, cfg.d_model),
                                        jnp.bfloat16)}
        if cfg.family == "vlm":
            s_text = S - cfg.num_patches
            return {"tokens": sds((B, s_text), i32),
                    "targets": sds((B, s_text), i32),
                    "patch_embeds": sds((B, cfg.num_patches, cfg.d_model),
                                        jnp.bfloat16)}
        return {"tokens": sds((B, S), i32), "targets": sds((B, S), i32)}
    if shape.kind == "prefill":
        if cfg.family == "audio":
            return {"tokens": sds((B, S), i32),
                    "frame_embeds": sds((B, cfg.encoder_seq, cfg.d_model),
                                        jnp.bfloat16)}
        if cfg.family == "vlm":
            return {"tokens": sds((B, S - cfg.num_patches), i32),
                    "patch_embeds": sds((B, cfg.num_patches, cfg.d_model),
                                        jnp.bfloat16)}
        return {"tokens": sds((B, S), i32)}
    # decode: one new token; the KV/state cache is a separate argument
    return {"tokens": sds((B, 1), i32)}


def build(cfg: ModelConfig, *, rt: Optional[transformer.Runtime] = None
          ) -> ModelApi:
    rt = rt or transformer.Runtime()

    if cfg.family == "audio":
        def init(key):
            return whisper.init_params(cfg, key)

        def loss(params, batch):
            return whisper.loss_fn(cfg, params, batch, rt=rt)

        def prefill_fn(params, batch, *, max_seq=None):
            return whisper.prefill(cfg, params, batch["tokens"],
                                   batch["frame_embeds"],
                                   max_seq=max_seq or batch["tokens"].shape[1],
                                   rt=rt)

        def decode_fn(params, cache, batch):
            return whisper.decode_step(cfg, params, cache, batch["tokens"],
                                       rt=rt)

        def cache_init(batch_size, max_seq):
            return whisper.init_cache(cfg, batch_size, max_seq)
    else:
        def init(key):
            return transformer.init_params(cfg, key)

        def loss(params, batch):
            return transformer.loss_fn(cfg, params, batch, rt=rt)

        def prefill_fn(params, batch, *, max_seq=None):
            S = batch["tokens"].shape[1]
            if cfg.family == "vlm" and "patch_embeds" in batch:
                S += batch["patch_embeds"].shape[1]
            return transformer.prefill(
                cfg, params, batch["tokens"], max_seq=max_seq or S,
                patch_embeds=batch.get("patch_embeds"), rt=rt)

        def decode_fn(params, cache, batch):
            return transformer.decode_step(cfg, params, cache,
                                           batch["tokens"], rt=rt)

        def cache_init(batch_size, max_seq):
            return transformer.init_cache(cfg, batch_size, max_seq)

    def input_specs(shape: ShapeSpec):
        ecfg = _effective_cfg(cfg, shape)
        return _token_specs(ecfg, shape)

    def cache_specs(shape: ShapeSpec):
        ecfg = _effective_cfg(cfg, shape)
        init_fn = (whisper.init_cache if ecfg.family == "audio"
                   else transformer.init_cache)
        return jax.eval_shape(
            lambda: init_fn(ecfg, shape.global_batch, shape.seq_len))

    return ModelApi(cfg=cfg, init=init, loss=loss, prefill=prefill_fn,
                    decode=decode_fn, input_specs=input_specs,
                    cache_specs=cache_specs)


def build_for_cell(cfg: ModelConfig, shape: ShapeSpec,
                   rt: Optional[transformer.Runtime] = None) -> ModelApi:
    """Model with per-cell config adjustments applied (see _effective_cfg)."""
    return build(_effective_cfg(cfg, shape), rt=rt)
