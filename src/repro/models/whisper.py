"""Whisper-style encoder-decoder backbone.

The conv/audio frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, encoder_seq, d_model). The
transformer backbone (encoder self-attn, decoder causal self-attn +
cross-attn) is fully implemented. Sinusoidal absolute positions.

Phase mapping for the serving system: "prefill" = encoder + cross-KV build +
decoder prompt ingestion; "decode" = one decoder token against both caches.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.layers import (attention, attn_init, attn_qkv, dense,
                                 mlp_apply, mlp_init, norm_apply, norm_init,
                                 sinusoidal_pos)


def _enc_layer_init(cfg, key):
    k1, k2 = jax.random.split(key)
    return {"norm1": norm_init(cfg, cfg.d_model),
            "attn": attn_init(cfg, k1),
            "norm2": norm_init(cfg, cfg.d_model),
            "mlp": mlp_init(cfg, k2)}


def _dec_layer_init(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": norm_init(cfg, cfg.d_model),
            "self_attn": attn_init(cfg, k1),
            "norm2": norm_init(cfg, cfg.d_model),
            "cross_attn": attn_init(cfg, k2),
            "norm3": norm_init(cfg, cfg.d_model),
            "mlp": mlp_init(cfg, k3)}


def init_params(cfg, key):
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.num_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": (jax.random.normal(ks[2], (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(jnp.bfloat16),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(cfg, k))(enc_keys),
        "enc_norm": norm_init(cfg, cfg.d_model),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(cfg, k))(dec_keys),
        "final_norm": norm_init(cfg, cfg.d_model),
        "unembed": (jax.random.normal(ks[3], (cfg.d_model, cfg.vocab_size),
                                      jnp.float32)
                    / math.sqrt(cfg.d_model)).astype(jnp.bfloat16),
    }


def encode(cfg, params, frame_embeds):
    """frame_embeds: (B, S_enc, d) from the stubbed frontend."""
    B, S, d = frame_embeds.shape
    x = frame_embeds.astype(jnp.bfloat16)
    x = x + sinusoidal_pos(jnp.arange(S), d).astype(x.dtype)[None]
    x = constrain(x, "batch", "seq", None)

    def body(x, p):
        h = norm_apply(cfg, p["norm1"], x)
        o, _ = layers.attn_apply(cfg, p["attn"], h,
                                 positions=jnp.broadcast_to(
                                     jnp.arange(S)[None], (B, S)),
                                 causal=False)
        x = x + o
        x = x + mlp_apply(cfg, p["mlp"], norm_apply(cfg, p["norm2"], x))
        return x, None

    x, _ = lax.scan(body, x, params["enc_layers"])
    return norm_apply(cfg, params["enc_norm"], x)


def _cross_kv(cfg, p_layer, enc_out):
    B, S, _ = enc_out.shape
    k = dense(p_layer["cross_attn"]["wk"], enc_out).reshape(
        B, S, cfg.num_kv_heads, cfg.head_dim)
    v = dense(p_layer["cross_attn"]["wv"], enc_out).reshape(
        B, S, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def build_cross_cache(cfg, params, enc_out):
    def body(_, p):
        return None, _cross_kv(cfg, p, enc_out)
    _, kv = lax.scan(body, None, params["dec_layers"])
    return kv  # (k, v) each (L, B, S_enc, Hkv, hd)


def _dec_layer(cfg, p, x, positions, *, self_kv, cross_kv, kv_len,
               mode):
    """One decoder layer. self_kv: (K, V) cache (mode=step) or None (full)."""
    B, S, _ = x.shape
    h = norm_apply(cfg, p["norm1"], x)
    if mode == "full":
        q, k_new, v_new = attn_qkv(cfg, p["self_attn"], h, positions)
        o = attention(q, k_new, v_new, causal=True,
                      chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
        new_self = (k_new, v_new)
    else:
        K, V = self_kv
        q, k, v = attn_qkv(cfg, p["self_attn"], h, positions)
        bidx = jnp.arange(B)
        K = K.at[bidx, positions[:, 0]].set(k[:, 0])
        V = V.at[bidx, positions[:, 0]].set(v[:, 0])
        o = attention(q, K, V, causal=True, kv_len=positions[:, 0] + 1,
                      q_offset=positions[:, 0], chunk_q=cfg.attn_chunk_q,
                      chunk_kv=cfg.attn_chunk_kv)
        new_self = (K, V)
    x = x + dense(p["self_attn"]["wo"], o.reshape(B, S, cfg.q_dim))
    # cross attention (bidirectional over encoder output)
    h = norm_apply(cfg, p["norm2"], x)
    q = dense(p["cross_attn"]["wq"], h).reshape(B, S, cfg.num_heads,
                                                cfg.head_dim)
    kc, vc = cross_kv
    o = attention(q, kc, vc, causal=False, chunk_q=cfg.attn_chunk_q,
                  chunk_kv=cfg.attn_chunk_kv)
    x = x + dense(p["cross_attn"]["wo"], o.reshape(B, S, cfg.q_dim))
    x = x + mlp_apply(cfg, p["mlp"], norm_apply(cfg, p["norm3"], x))
    return x, new_self


def forward(cfg, params, tokens, frame_embeds):
    """Training forward: encoder + full decoder pass. Returns hidden (B,S,d)."""
    enc_out = encode(cfg, params, frame_embeds)
    B, S = tokens.shape
    x = params["embed"][tokens]
    x = x + sinusoidal_pos(jnp.arange(S), cfg.d_model).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, p):
        ckv = _cross_kv(cfg, p, enc_out)
        x, _ = _dec_layer(cfg, p, x, positions, self_kv=None, cross_kv=ckv,
                          kv_len=None, mode="full")
        return x, None

    x, _ = lax.scan(body, x, params["dec_layers"])
    return norm_apply(cfg, params["final_norm"], x)


def loss_fn(cfg, params, batch, *, rt=None):
    x = forward(cfg, params, batch["tokens"], batch["frame_embeds"])
    return layers.chunked_xent(x, params["unembed"], batch["targets"],
                               chunk=cfg.vocab_chunk, mask=batch.get("mask"))


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    L = cfg.num_layers
    return {
        "self_k": jnp.zeros((L, batch, max_seq, cfg.num_kv_heads,
                             cfg.head_dim), dtype),
        "self_v": jnp.zeros((L, batch, max_seq, cfg.num_kv_heads,
                             cfg.head_dim), dtype),
        "cross_k": jnp.zeros((L, batch, cfg.encoder_seq, cfg.num_kv_heads,
                              cfg.head_dim), dtype),
        "cross_v": jnp.zeros((L, batch, cfg.encoder_seq, cfg.num_kv_heads,
                              cfg.head_dim), dtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def prefill(cfg, params, tokens, frame_embeds, *, max_seq: int, rt=None,
            last_pos=None, true_len=None):
    """``last_pos``/``true_len`` support right-padded (bucketed) prompts —
    the decoder self-attention is causal, so padding is invisible to real
    positions; logits are gathered at each sequence's last real token."""
    enc_out = encode(cfg, params, frame_embeds)
    B, S = tokens.shape
    x = params["embed"][tokens]
    x = x + sinusoidal_pos(jnp.arange(S), cfg.d_model).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, p):
        ckv = _cross_kv(cfg, p, enc_out)
        x, new_self = _dec_layer(cfg, p, x, positions, self_kv=None,
                                 cross_kv=ckv, kv_len=None, mode="full")
        return x, (new_self, ckv)

    x, (self_kv, cross_kv) = lax.scan(body, x, params["dec_layers"])
    x = norm_apply(cfg, params["final_norm"], x)
    cache = init_cache(cfg, B, max_seq)
    cache["self_k"] = lax.dynamic_update_slice(
        cache["self_k"], self_kv[0].astype(cache["self_k"].dtype),
        (0, 0, 0, 0, 0))
    cache["self_v"] = lax.dynamic_update_slice(
        cache["self_v"], self_kv[1].astype(cache["self_v"].dtype),
        (0, 0, 0, 0, 0))
    cache["cross_k"] = cross_kv[0].astype(cache["cross_k"].dtype)
    cache["cross_v"] = cross_kv[1].astype(cache["cross_v"].dtype)
    cache["lengths"] = (jnp.full((B,), S, jnp.int32) if true_len is None
                        else true_len.astype(jnp.int32))
    if last_pos is None:
        h_last = x[:, -1:]
    else:
        h_last = x[jnp.arange(B), last_pos][:, None]
    logits = (h_last @ params["unembed"]).astype(jnp.float32)
    return logits, cache


def decode_step(cfg, params, cache, tokens, *, rt=None):
    B = tokens.shape[0]
    positions = cache["lengths"][:, None]
    x = params["embed"][tokens]
    x = x + jax.vmap(lambda p: sinusoidal_pos(p, cfg.d_model))(
        cache["lengths"])[:, None].astype(x.dtype)

    def body(x, xs):
        p, sk, sv, ck, cv = xs
        x, (K, V) = _dec_layer(cfg, p, x, positions, self_kv=(sk, sv),
                               cross_kv=(ck, cv), kv_len=None, mode="step")
        return x, (K, V)

    x, (new_k, new_v) = lax.scan(
        body, x, (params["dec_layers"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    x = norm_apply(cfg, params["final_norm"], x)
    logits = (x @ params["unembed"]).astype(jnp.float32)
    new_cache = dict(cache)
    new_cache["self_k"], new_cache["self_v"] = new_k, new_v
    new_cache["lengths"] = cache["lengths"] + 1
    return logits, new_cache
