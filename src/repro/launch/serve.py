"""Production serving launcher: ClusterSpec -> schedule -> engines ->
coordinator -> serve a request stream (the paper's overall routine, §4 ①-④).

  PYTHONPATH=src python -m repro.launch.serve --arch llama-30b \\
      --cluster paper_cloud --workload conversation --rate 2 --duration 20

On this CPU container the engines run the reduced config of the chosen arch
(real computation); the deployment plan itself is computed for the FULL
model on the requested cluster — the same split the paper deploys.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.core import scheduler
from repro.core.cluster import make_cluster
from repro.core.orchestrator import SloSpec
from repro.core.workload import WORKLOADS, generate
from repro.models import build
from repro.serving.coordinator import Coordinator
from repro.serving.engine import DecodeEngine, GenRequest, PrefillEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-30b")
    ap.add_argument("--cluster", default="paper_cloud",
                    choices=("paper_cloud", "inhouse", "tpu_fleet"))
    ap.add_argument("--workload", default="conversation",
                    choices=tuple(WORKLOADS))
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--duration", type=float, default=15.0)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--no-compress", action="store_true")
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    wl = WORKLOADS[args.workload]
    cluster = make_cluster(args.cluster)
    cfg_full = get_config(args.arch)
    slo = SloSpec(ttft_s=2.0, tpot_s=0.15, e2e_s=30.0)

    print(f"[1/4] scheduling {cfg_full.name} on {args.cluster} "
          f"({cluster.types()})...")
    plan = scheduler.schedule(cluster, cfg_full, wl, args.rate, slo,
                              n_step=args.steps, seed=0)
    print(plan.describe())

    print("[2/4] instantiating engines (reduced config, real compute)...")
    cfg = get_reduced(args.arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n_pre = max(1, len(plan.prefill_replicas))
    n_dec = max(1, len(plan.decode_replicas))
    pres = [PrefillEngine(cfg, params, max_seq=96)
            for _ in range(min(n_pre, 4))]
    decs = [DecodeEngine(cfg, params, max_slots=4, max_seq=96)
            for _ in range(min(n_dec, 4))]
    coord = Coordinator(pres, decs, orchestration=plan.orchestration,
                        compress=not args.no_compress, backend="ref")

    print("[3/4] serving the request stream...")
    trace = generate(wl, rate=args.rate, duration=args.duration, seed=0)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for r in trace:
        coord.submit(GenRequest(
            r.rid, rng.integers(1, cfg.vocab_size,
                                min(r.n_in // 32 + 8, 48)).astype(np.int32),
            max_new_tokens=min(args.max_new, max(r.n_out // 16, 2))))
    done = coord.run_until_drained()
    wall = time.time() - t0

    print("[4/4] results")
    toks = sum(len(r.out_tokens) for r in done)
    e2e = [r.t_done - r.t_submit for r in done]
    print(f"  {len(done)} requests, {toks} tokens in {wall:.1f}s "
          f"({toks/wall:.1f} tok/s)")
    print(f"  E2E p50={np.percentile(e2e, 50)*1e3:.0f}ms "
          f"p99={np.percentile(e2e, 99)*1e3:.0f}ms")
    if coord.events:
        print("  events:", coord.events[:5])


if __name__ == "__main__":
    main()
