"""Production serving launcher: ClusterSpec -> schedule -> engines ->
gateway -> serve an OPEN-LOOP request stream (the paper's overall routine,
§4 ①-④, driven the way a real service is driven: requests arrive over
time, tokens stream back under TTFT/TPOT deadlines).

  PYTHONPATH=src python -m repro.launch.serve --arch llama-30b \\
      --cluster paper_cloud --workload conversation --rate 2 --duration 20

On this CPU container the engines run the reduced config of the chosen arch
(real computation); the deployment plan itself is computed for the FULL
model on the requested cluster — the same split the paper deploys. With
``--transport sim`` the prefill->decode KV hop pays the FULL model's wire
bytes over the plan's actual inter-replica links (alpha-beta model from
the cluster's bandwidth matrix).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.core import scheduler
from repro.core.cluster import make_cluster
from repro.core.orchestrator import SloSpec
from repro.core.workload import WORKLOADS, generate
from repro.models import build
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.gateway import (Gateway, SchedulerConfig, ServeRequest,
                                   drive_open_loop, gateway_from_plan,
                                   summarize_handles, warmup_engines)
from repro.serving.profiler import WorkloadProfiler
from repro.serving.transport import InProcessTransport, SimNetworkTransport

# trace -> engine scale of the reduced-config requests built below
# (prompts ~ n_in/IN_SCALE, outputs ~ n_out/OUT_SCALE); the profiler is
# configured with the same factors so the cost model sees the full-model
# workload shape
IN_SCALE, OUT_SCALE = 32, 16


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-30b")
    ap.add_argument("--cluster", default="paper_cloud",
                    choices=("paper_cloud", "inhouse", "tpu_fleet"))
    ap.add_argument("--workload", default="conversation",
                    choices=tuple(WORKLOADS))
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--duration", type=float, default=15.0)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--no-compress", action="store_true")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--transport", choices=("inproc", "sim"),
                    default="inproc")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="multiply trace arrival times (e.g. 0.5 = 2x "
                         "faster arrivals)")
    ap.add_argument("--ttft-slo", type=float, default=0.0,
                    help="per-request TTFT deadline in s (0 = none); "
                         "queued requests that provably miss it are shed")
    ap.add_argument("--e2e-slo", type=float, default=0.0)
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged decode cache)")
    ap.add_argument("--pages", type=int, default=0,
                    help="page-pool budget per decode engine (0 = parity "
                         "with the dense max_slots x max_seq budget)")
    ap.add_argument("--no-paged", action="store_true",
                    help="dense slotted decode cache instead of the paged "
                         "int4-resident pool")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable the radix prefix cache (refcounted "
                         "copy-on-write page sharing + prefill skip)")
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="prefill chunk token budget per scheduler tick "
                         "(0 = one-shot prefill); SARATHI-style chunking "
                         "bounds how long any prompt can head-of-line-"
                         "block TTFT")
    ap.add_argument("--decode-chunk-steps", type=int, default=0,
                    help="decode steps per scheduler tick (0 = engine "
                         "default)")
    ap.add_argument("--max-prefill-batch", type=int, default=4,
                    help="max prompts per prefill dispatch/chunk tick")
    ap.add_argument("--live-reschedule", action="store_true",
                    help="shift the workload mid-trace and let the "
                         "control plane apply a lightweight reschedule to "
                         "the RUNNING gateway (phase flips, no reload)")
    ap.add_argument("--shift-to", default="",
                    help="workload for the second half of the trace "
                         "(default: the other one)")
    args = ap.parse_args()

    wl = WORKLOADS[args.workload]
    cluster = make_cluster(args.cluster)
    cfg_full = get_config(args.arch)
    slo = SloSpec(ttft_s=2.0, tpot_s=0.15, e2e_s=30.0)

    print(f"[1/4] scheduling {cfg_full.name} on {args.cluster} "
          f"({cluster.types()})...")
    plan = scheduler.schedule(cluster, cfg_full, wl, args.rate, slo,
                              n_step=args.steps, seed=0)
    print(plan.describe())

    print("[2/4] instantiating engines (reduced config, real compute)...")
    cfg = get_reduced(args.arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    if args.transport == "sim":
        # the reduced engine computes, but the wire hop pays the FULL
        # model's KV bytes over the plan's inter-replica links
        scale = ((cfg_full.num_layers * cfg_full.d_model)
                 / (cfg.num_layers * cfg.d_model))
        transport = SimNetworkTransport.from_plan(cluster, plan,
                                                  bytes_scale=scale)
    else:
        transport = InProcessTransport()
    paged_kw = dict(paged=not args.no_paged, page_size=args.page_size,
                    num_pages=args.pages or None,
                    prefix_sharing=not (args.no_paged
                                        or args.no_prefix_sharing))
    sched_cfg = SchedulerConfig(prefill_chunk_tokens=args.chunk_tokens,
                                max_prefill_batch=args.max_prefill_batch,
                                decode_chunk_steps=args.decode_chunk_steps)
    if args.live_reschedule:
        # one phase-switchable Replica per plan replica, so the control
        # plane can re-designate the running fleet without a reload; the
        # page pool is the DECODE-phase-owned buffer (flips drop/rebuild
        # it with the cached engine, params stay resident)
        gw = gateway_from_plan(plan, cfg, params, transport=transport,
                               max_seq=96, max_slots=4,
                               decode_kw=paged_kw, scheduler=sched_cfg,
                               profiler=WorkloadProfiler(
                                   in_scale=IN_SCALE, out_scale=OUT_SCALE),
                               compress=not args.no_compress, backend="ref")
        pres = [h.engine for h in gw.pre]
        decs = [h.engine for h in gw.dec]
    else:
        n_pre = max(1, len(plan.prefill_replicas))
        n_dec = max(1, len(plan.decode_replicas))
        pres = [PrefillEngine(cfg, params, max_seq=96)
                for _ in range(min(n_pre, 4))]
        decs = [DecodeEngine(cfg, params, max_slots=4, max_seq=96,
                             **paged_kw)
                for _ in range(min(n_dec, 4))]
        gw = Gateway(pres, decs, transport=transport,
                     orchestration=plan.orchestration,
                     scheduler=sched_cfg,
                     compress=not args.no_compress, backend="ref")

    print("[3/4] serving the request stream (open loop, "
          f"{args.transport} transport)...")
    warmup_engines(pres, decs, cfg.vocab_size,
                   compress=not args.no_compress, backend="ref",
                   prompt_lens=(16, 32, 48))
    rng = np.random.default_rng(0)

    def to_serve(r, t_offset=0.0):
        return (t_offset + r.t_arrive, ServeRequest(
            r.rid, rng.integers(
                1, cfg.vocab_size,
                min(r.n_in // IN_SCALE + 8, 48)).astype(np.int32),
            max_new_tokens=min(args.max_new, max(r.n_out // OUT_SCALE, 2)),
            ttft_deadline_s=args.ttft_slo or float("inf"),
            e2e_deadline_s=args.e2e_slo or float("inf")))

    tick = None
    if args.live_reschedule:
        wl2 = WORKLOADS[args.shift_to or
                        ("conversation" if args.workload == "coding"
                         else "coding")]
        half = args.duration / 2
        first = generate(wl, rate=args.rate, duration=half, seed=0)
        second = generate(wl2, rate=args.rate, duration=half, seed=1)
        for i, r in enumerate(second):
            r.rid = len(first) + i
        arrivals = [to_serve(r) for r in first] + \
            [to_serve(r, t_offset=half) for r in second]
        print(f"    trace shifts {wl.name} -> {wl2.name} at t={half:.1f}s")
        printed = [0]

        def tick(g):
            if not g.profiler.has_baseline:
                g.profiler.set_baseline()
            else:
                g.maybe_reschedule(cluster, cfg_full, rate=args.rate,
                                   slo=slo)
            for e in g.events[printed[0]:]:
                print(f"    | {e}")
            printed[0] = len(g.events)
    else:
        trace = generate(wl, rate=args.rate, duration=args.duration, seed=0)
        arrivals = [to_serve(r) for r in trace]
    t0 = time.time()
    handles = drive_open_loop(gw, arrivals, time_scale=args.time_scale,
                              tick=tick)
    wall = time.time() - t0

    print("[4/4] results")
    s = summarize_handles(handles)
    print(f"  {s['n_done']}/{s['n_submitted']} requests done "
          f"(states {s['states']}), {s['tokens']} tokens in {wall:.1f}s "
          f"({s['tokens']/max(wall, 1e-9):.1f} tok/s)")
    print(f"  TTFT p50={s['ttft_p50_s']*1e3:.0f}ms "
          f"p99={s['ttft_p99_s']*1e3:.0f}ms  "
          f"TPOT p50={s['tpot_p50_s']*1e3:.0f}ms")
    print(f"  E2E  p50={s['e2e_p50_s']*1e3:.0f}ms "
          f"p99={s['e2e_p99_s']*1e3:.0f}ms  "
          f"goodput={s['goodput']*100:.0f}%")
    if isinstance(transport, SimNetworkTransport):
        print(f"  sim network: {transport.transfers} transfers, "
              f"{transport.bytes_sent/1e6:.1f}MB, "
              f"mean hop {transport.mean_delay_s*1e3:.1f}ms")
    # read the LIVE decode list: a mid-trace plan flip re-designates
    # replicas, so the construction-time snapshot would miss new pools
    live_decs = [h.engine for h in gw.dec] if args.live_reschedule else decs
    paged_decs = [d for d in live_decs
                  if isinstance(d, DecodeEngine) and d.paged]
    if paged_decs:
        st = [d.page_stats() for d in paged_decs]
        print(f"  paged KV: {sum(s['pages'] for s in st)} pages x "
              f"{st[0]['page_size']} tok (int4 at rest), peak "
              f"{sum(s['peak_in_use'] for s in st)} in use, "
              f"{sum(s['zero_copy_inserts'] for s in st)} zero-dequant "
              f"wire inserts, "
              f"{sum(s['reencoded_inserts'] for s in st)} re-encoded")
    if args.live_reschedule:
        requeued = sum(h.restarts for h in handles)
        resident = all(h.engine.params is params
                       for h in gw.pre + gw.dec)
        print(f"  live reschedule: epoch {gw.epoch}, "
              f"P:{len(gw.pre)} D:{len(gw.dec)}, {requeued} requeued "
              f"through flips, params resident (no reload) = {resident}")
    st = gw.stats()
    c = st["counters"]
    print(f"  gateway: epoch={st['epoch']} retries={c['retries']} "
          f"requeues={c['requeues']} migrations={c['migrations']} "
          f"preemptions={c['preemptions']} failed={c['failed']}")
    if args.chunk_tokens > 0:
        print(f"  chunked prefill: {c['chunk_ticks']} chunk ticks, "
              f"{c['chunked_prefills']} prompts chunked "
              f"(budget {args.chunk_tokens} tok/tick)")
    if st["page_pool"]:
        print(f"  page pool (fleet): "
              f"{st['page_pool']['alloc_failures']:.0f} admission stalls, "
              f"{st['page_pool']['in_use']:.0f} pages still in use")
    pfx = st["prefix"]
    if pfx["hits"] or pfx["partial_hits"] or pfx["misses"]:
        pool = st["page_pool"] or {}
        print(f"  prefix cache: {pfx['hits']} full hits (prefill skipped), "
              f"{pfx['partial_hits']} partial (suffix prefill), "
              f"{pfx['misses']} misses "
              f"(hit rate {pfx['hit_rate']*100:.0f}%, "
              f"{pfx['hit_tokens']} prompt tokens reused, "
              f"{pool.get('cow_copies', 0):.0f} COW copies, "
              f"{pool.get('prefix_evictions', 0):.0f} evictions)")
    print("  replicas:", "  ".join(
        f"{r['phase']}:{r['idx']}={r['status']}"
        + (f"({r['suspect_why']})" if r["suspect_why"] else "")
        for r in st["replicas"]))
    if gw.events:
        print("  events:", gw.events[:5])


if __name__ == "__main__":
    main()
