"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run sets XLA_FLAGS for 512 host devices *before*
importing jax; everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def model_axis(mesh) -> str:
    return "model"
