import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the very first two lines, before ANY other import: jax locks the
# device count on first init.
#
# Multi-pod dry-run: lower + compile every (arch x shape) cell on the
# production meshes, record memory/cost analysis + roofline terms.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh multi
#
# Results are cached one JSON per cell under results/dryrun/ so reruns are
# incremental (--force to recompute).

import argparse
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ASSIGNED_ARCHS, all_cells, get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import data_axes, make_production_mesh
from repro.models import registry
from repro.models.transformer import Runtime
from repro.roofline import analysis
from repro.training import optimizer as opt

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


# ---------------------------------------------------------------------------
# Sharding assembly
# ---------------------------------------------------------------------------


def _fits(shape, spec, mesh):
    """Zero out spec axes that do not divide the dimension."""
    out = []
    for i, s in enumerate(spec):
        if s is None:
            out.append(None)
            continue
        names = s if isinstance(s, tuple) else (s,)
        size = 1
        for nm in names:
            size *= mesh.shape[nm]
        out.append(s if shape[i] % size == 0 else None)
    return P(*out)


def ns(mesh, shape, spec):
    return NamedSharding(mesh, _fits(shape, spec, mesh))


def batch_shardings(mesh, specs):
    dax = data_axes(mesh)
    out = {}
    for k, v in specs.items():
        spec = [dax] + [None] * (len(v.shape) - 1)
        out[k] = ns(mesh, v.shape, P(*spec))
    return out


def params_shardings(mesh, params_shapes):
    pspecs = shd.params_pspec_tree(
        params_shapes,
        stacked_prefixes=("blocks", "enc_layers", "dec_layers"))
    return jax.tree.map(
        lambda leaf, spec: ns(mesh, leaf.shape, spec), params_shapes, pspecs)


def cache_shardings(mesh, cache_shapes, cfg):
    dax = data_axes(mesh)
    paths = shd.tree_paths(cache_shapes)

    def spec_for(path, leaf):
        nd = len(leaf.shape)
        if path.endswith("lengths"):
            return P(dax)
        tail = path.rsplit("/", 1)[-1]
        if tail in ("k", "v") or tail in ("self_k", "self_v", "cross_k",
                                          "cross_v"):
            if cfg.decode_cache_layout == "hkv_s" and tail in ("k", "v"):
                # (L, B, Hkv, S, hd): cache seq is dim 3
                return P(None, dax, None, "model", None)
            # (L, B, S, Hkv, hd): batch->data, cache seq->model
            return P(None, dax, "model", None, None)
        if tail == "ssm":      # (L, B, di, ds)
            return P(None, dax, "model", None)
        if tail == "conv":     # (L, B, K-1, di)
            return P(None, dax, None, "model")
        if tail in ("C",):     # (L, B, H, dh, dh)
            return P(*([None, dax] + [None] * (nd - 2)))
        return P(*([None, dax] + [None] * (nd - 2))) if nd >= 2 else P(None)

    flat = {p: spec_for(p, l) for p, l in paths.items()}

    def rec(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rec(v, f"{prefix}/{k}" if prefix else str(k))
                    for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            t = type(tree)
            return t(rec(v, f"{prefix}/{i}") for i, v in enumerate(tree))
        return ns(mesh, tree.shape, flat[prefix])

    return rec(cache_shapes)


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


def lower_cell(arch: str, shape_name: str, mesh, *, moe_shard_map=True,
               rules=None, donate=True, inplace_decode=False,
               kv_layout=None, overrides=None):
    """Returns (lowered, aux_info). Everything abstract — no allocation."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ecfg = registry._effective_cfg(cfg, shape)
    if kv_layout:
        ecfg = ecfg.replace(decode_cache_layout=kv_layout)
    if overrides:
        ecfg = ecfg.replace(**overrides)
    rt = Runtime(mesh=mesh, moe_shard_map=moe_shard_map and bool(
        ecfg.moe_num_experts), inplace_decode=inplace_decode)
    api = registry.build(ecfg, rt=rt)

    specs = api.input_specs(shape)
    b_shard = batch_shardings(mesh, specs)
    p_shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    p_shard = params_shardings(mesh, p_shapes)

    with shd.use_rules(mesh, rules or shd.DEFAULT_RULES):
        if shape.kind == "train":
            ocfg = opt.AdamWConfig()
            step_fn = opt.make_train_step(api, ocfg)
            o_shapes = jax.eval_shape(opt.adamw_init, p_shapes)
            o_shard = {"m": p_shard, "v": p_shard,
                       "step": NamedSharding(mesh, P())}
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1) if donate else ())
            args = (p_shapes, o_shapes, specs)
        elif shape.kind == "prefill":
            def prefill_fn(params, batch):
                return api.prefill(params, batch, max_seq=shape.seq_len)
            jitted = jax.jit(prefill_fn, in_shardings=(p_shard, b_shard))
            args = (p_shapes, specs)
        else:  # decode
            c_shapes = api.cache_specs(shape)
            c_shard = cache_shardings(mesh, c_shapes, ecfg)
            def decode_fn(params, cache, batch):
                return api.decode(params, cache, batch)
            jitted = jax.jit(decode_fn,
                             in_shardings=(p_shard, c_shard, b_shard),
                             out_shardings=(None, c_shard),
                             donate_argnums=(1,) if donate else ())
            args = (p_shapes, c_shapes, specs)
        lowered = jitted.lower(*args)
    return lowered, {"cfg": ecfg, "shape": shape}


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, force=False,
             moe_shard_map=True, rules=None, tag="baseline",
             inplace_decode=False, kv_layout=None, overrides=None) -> dict:
    RESULTS.mkdir(parents=True, exist_ok=True)
    out_path = RESULTS / f"{mesh_kind}_{arch}_{shape_name}_{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    t0 = time.time()
    try:
        lowered, info = lower_cell(arch, shape_name, mesh,
                                   moe_shard_map=moe_shard_map, rules=rules,
                                   inplace_decode=inplace_decode,
                                   kv_layout=kv_layout, overrides=overrides)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mf = analysis.model_flops_for(info["cfg"], info["shape"])
        roof = analysis.analyze(
            compiled, arch=arch, shape=shape_name,
            mesh_desc=f"{mesh_kind}:{dict(mesh.shape)}", n_chips=n_chips,
            model_flops=mf)
        mem = {}
        try:
            ma = compiled.memory_analysis()
            mem = {k: int(getattr(ma, k)) for k in
                   ("temp_size_in_bytes", "argument_size_in_bytes",
                    "output_size_in_bytes", "generated_code_size_in_bytes")
                   if hasattr(ma, k)}
        except Exception:
            pass
        rec = {"status": "ok", "arch": arch, "shape": shape_name,
               "mesh": mesh_kind, "tag": tag, "n_chips": n_chips,
               "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
               "memory_analysis": mem, **roof.to_json()}
    except Exception as e:  # record failures — they are bugs to fix
        rec = {"status": "error", "arch": arch, "shape": shape_name,
               "mesh": mesh_kind, "tag": tag,
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-4000:]}
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--moe-dense", action="store_true",
                    help="use the pjit MoE path instead of shard_map EP")
    ap.add_argument("--inplace-decode", action="store_true",
                    help="fori_loop in-place KV decode (§Perf optimization)")
    ap.add_argument("--kv-layout", default=None,
                    help="decode cache layout override, e.g. hkv_s")
    args = ap.parse_args()

    cells, skipped = all_cells()
    if not args.all:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    ok = fail = 0
    for arch, shape_name in cells:
        t0 = time.time()
        rec = run_cell(arch, shape_name, args.mesh, force=args.force,
                       moe_shard_map=not args.moe_dense, tag=args.tag,
                       inplace_decode=args.inplace_decode,
                       kv_layout=args.kv_layout)
        dt = time.time() - t0
        if rec["status"] == "ok":
            ok += 1
            print(f"[ok]   {args.mesh:6s} {arch:28s} {shape_name:12s} "
                  f"flops/dev={rec['hlo_flops']:.3e} "
                  f"bytes/dev={rec['hlo_bytes']:.3e} "
                  f"coll={rec['collective_wire']:.3e}B "
                  f"bottleneck={rec['bottleneck']:10s} ({dt:.0f}s)",
                  flush=True)
        else:
            fail += 1
            print(f"[FAIL] {args.mesh:6s} {arch:28s} {shape_name:12s} "
                  f"{rec['error']}", flush=True)
    if args.all:
        for arch, shape_name, why in skipped:
            print(f"[skip] {arch:28s} {shape_name:12s} {why}")
    print(f"done: {ok} ok, {fail} failed, {len(skipped) if args.all else 0} "
          f"documented skips")
    sys.exit(1 if fail else 0)


if __name__ == "__main__":
    main()
