"""Training launcher: data pipeline -> sharded train loop -> async
checkpoints, with optional mesh (on a pod this runs under pjit with the
same shardings the dry-run compiles).

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, load_checkpoint
from repro.configs import get_reduced
from repro.models import build
from repro.training import optimizer as opt
from repro.training.data import DataConfig, PackedLM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-30b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_reduced(args.arch).replace(vocab_chunk=args.seq)
    api = build(cfg)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"batch {args.batch} x seq {args.seq}")

    params = api.init(jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=10,
                           total_steps=args.steps)
    ostate = opt.adamw_init(params)
    data = PackedLM(DataConfig(cfg.vocab_size, args.seq, args.batch))
    start = 0
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, s, extra = load_checkpoint(args.ckpt_dir,
                                          {"p": params, "o": ostate})
        params, ostate = state["p"], state["o"]
        data.restore(extra["data"])
        start = s + 1
        print(f"resumed from step {s}")

    step_fn = jax.jit(opt.make_train_step(api, ocfg))
    t0 = time.time()
    losses = []
    for i in range(start, args.steps):
        batch = data.batch_at(i)
        data.step = i + 1
        params, ostate, stats = step_fn(
            params, ostate, {k: jnp.asarray(v) for k, v in batch.items()})
        losses.append(float(stats["loss"]))
        if i % 10 == 0:
            print(f"step {i:4d} loss={losses[-1]:.4f} "
                  f"lr={float(stats['lr']):.2e}")
        if ckpt and i and i % args.ckpt_every == 0:
            ckpt.save({"p": params, "o": ostate}, i,
                      extra={"data": data.state()})
    if ckpt:
        ckpt.wait()
    dt = time.time() - t0
    k = max(len(losses) // 5, 1)
    print(f"{len(losses)} steps in {dt:.1f}s; "
          f"loss {np.mean(losses[:k]):.3f} -> {np.mean(losses[-k:]):.3f}")


if __name__ == "__main__":
    main()
