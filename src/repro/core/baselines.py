"""Baseline serving policies the paper compares against (§5.1):

* vLLM-like     — colocated continuous batching, homogeneous in-house 8xA100.
* DistServe-like— phase splitting on the homogeneous in-house cluster,
                  NVLink KV transfer, no compression, no hetero scheduling.
* HexGen-like   — heterogeneous cloud, asymmetric parallelism via our Alg. 2,
                  but NO phase splitting (colocated groups) and generic
                  (capacity-proportional) routing.

All three produce (replicas, orchestration, colocated?) consumable by the
simulator, so every benchmark compares identical workloads end-to-end.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costmodel as cm
from repro.core import orchestrator as orch
from repro.core import parallel as par
from repro.core.cluster import ClusterSpec, make_inhouse
from repro.core.workload import Workload


@dataclass
class BaselinePlan:
    name: str
    cluster: ClusterSpec
    replicas: List[orch.ReplicaPlan]
    orchestration: Optional[orch.Orchestration]
    colocated: bool
    compress: bool


def _even_groups(cluster: ClusterSpec, cfg: ModelConfig, per_group: int
                 ) -> List[List[int]]:
    idxs = [d.idx for d in cluster.devices]
    groups = [idxs[i:i + per_group] for i in range(0, len(idxs), per_group)]
    return [g for g in groups if len(g) == per_group]


def _min_group_size(cluster: ClusterSpec, cfg: ModelConfig) -> int:
    need = cfg.param_count() * cm.BYTES * 1.1
    sizes = sorted(d.chip.hbm_bytes for d in cluster.devices)
    for k in (1, 2, 4, 8):
        if sizes[0] * k >= need:
            return k
    return 8


def vllm_like(cfg: ModelConfig, wl: Workload, rate: float,
              slo: orch.SloSpec, seed: int = 0) -> BaselinePlan:
    cluster = make_inhouse(seed)
    k = _min_group_size(cluster, cfg)
    groups = _even_groups(cluster, cfg, k)
    replicas = []
    for g in groups:
        got = par.deduce(cluster, cfg, g, "decode",
                         mean_ctx=int(wl.mean_in + wl.mean_out))
        if got:
            replicas.append(orch.ReplicaPlan(g, "decode", *got))
    pre = replicas  # colocated: same replicas serve both phases
    o = orch.orchestrate(cluster, cfg, pre, replicas, wl, rate, slo,
                         compress=False)
    return BaselinePlan("vllm", cluster, replicas, o, colocated=True,
                        compress=False)


def distserve_like(cfg: ModelConfig, wl: Workload, rate: float,
                   slo: orch.SloSpec, seed: int = 0) -> BaselinePlan:
    cluster = make_inhouse(seed)
    k = _min_group_size(cluster, cfg)
    groups = _even_groups(cluster, cfg, k)
    # phase split proportional to workload demand (DistServe heuristic)
    demand_p = wl.mean_in / (wl.mean_in + 4.0 * wl.mean_out)
    n_pre = max(1, min(len(groups) - 1, round(len(groups) * demand_p)))
    replicas = []
    for gi, g in enumerate(groups):
        phase = "prefill" if gi < n_pre else "decode"
        got = par.deduce(cluster, cfg, g, phase,
                         mean_ctx=int(wl.mean_in + wl.mean_out))
        if got:
            replicas.append(orch.ReplicaPlan(g, phase, *got))
    pre = [r for r in replicas if r.phase == "prefill"]
    dec = [r for r in replicas if r.phase == "decode"]
    o = orch.orchestrate(cluster, cfg, pre, dec, wl, rate, slo,
                         compress=False)
    return BaselinePlan("distserve", cluster, replicas, o, colocated=False,
                        compress=False)


def hexgen_like(cluster: ClusterSpec, cfg: ModelConfig, wl: Workload,
                rate: float, slo: orch.SloSpec) -> BaselinePlan:
    """Heterogeneous groups via clustering init, asymmetric parallelism, but
    colocated phases + capacity-proportional routing (no TSTP)."""
    import random
    from repro.core import tabu
    sol = tabu.initial_solution(cluster, cfg, random.Random(0))
    replicas = []
    for g in sol.groups:
        got = par.deduce(cluster, cfg, list(g), "decode",
                         mean_ctx=int(wl.mean_in + wl.mean_out))
        if got:
            replicas.append(orch.ReplicaPlan(list(g), "decode", *got))
    o = orch.orchestrate(cluster, cfg, replicas, replicas, wl, rate, slo,
                         compress=False)
    return BaselinePlan("hexgen", cluster, replicas, o, colocated=True,
                        compress=False)
