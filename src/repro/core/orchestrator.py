"""Orchestration of prefill & decode replicas (paper §3.3, lower level).

Builds the SLO-attainment matrix D[i,j] from analytic queueing estimates
(service times from the cost model, alpha-beta KV transfer from Eq. 1), then
solves the two-stage transportation problem (TSTP) as an LP:

    max  sum_ij Z_ij D_ij
    s.t. sum_j Z_ij <= prefill_capacity_i / rate       (row caps)
         sum_i Z_ij <= decode_capacity_j / rate        (col caps)
         sum_ij Z_ij <= 1,  Z >= 0

X_i = sum_j Z*_ij and Y_ij = Z*_ij / X_i recover the paper's routing split.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np
from scipy.optimize import linprog
from scipy.stats import lognorm

from repro.configs.base import ModelConfig
from repro.core import costmodel as cm
from repro.core.cluster import ClusterSpec
from repro.core.workload import Workload


@dataclass(frozen=True)
class SloSpec:
    ttft_s: float
    tpot_s: float
    e2e_s: float

    def scaled(self, k: float) -> "SloSpec":
        return SloSpec(self.ttft_s * k, self.tpot_s * k, self.e2e_s * k)


@dataclass
class ReplicaPlan:
    devices: List[int]
    phase: str                       # "prefill" | "decode"
    pc: cm.ParallelConfig
    cost: cm.ReplicaCost


@dataclass
class Orchestration:
    X: np.ndarray                    # (m,) prefill split
    Y: np.ndarray                    # (m, n) decode split per prefill
    Z: np.ndarray                    # (m, n) joint mass
    D: np.ndarray                    # (m, n) per-pair SLO attainment
    attainment: float                # expected overall SLO attainment
    served_frac: float


def _lognorm_cdf(x: float, mean: float, cv: float) -> float:
    if mean <= 0:
        return 1.0
    if x <= 0:
        return 0.0
    sigma2 = math.log(1 + cv * cv)
    mu = math.log(mean) - sigma2 / 2
    return float(lognorm.cdf(x, math.sqrt(sigma2), scale=math.exp(mu)))


def estimate_pair_slo(cluster: ClusterSpec, cfg: ModelConfig,
                      pre: ReplicaPlan, dec: ReplicaPlan, wl: Workload,
                      rate_i: float, rate_j: float, slo: SloSpec, *,
                      compress: bool = True,
                      chunk_tokens: int = 0) -> float:
    """Analytic SLO attainment for requests taking path (pre -> dec).

    ``chunk_tokens > 0`` models SARATHI-style chunked prefill: a request's
    own prefill takes slightly LONGER (per-chunk overheads +
    cross-attention against the resident prefix), but the head-of-line
    quantum a queued request waits behind shrinks from a whole prompt to
    one chunk — the knob the tabu search can now trade off."""
    # prefix-cache credit: only the unshared suffix of the mean prompt is
    # prefilled (full hits skip the prefill stage entirely)
    eff_in = cm.effective_prefill_tokens(wl)
    s_p = cm.prefill_latency(cluster, cfg,
                             pre.pc, max(int(eff_in), 1))
    if chunk_tokens > 0:
        s_own = cm.chunked_prefill_latency(cluster, cfg, pre.pc,
                                           max(int(eff_in), 1),
                                           chunk_tokens)
        s_chunk = cm.prefill_latency(
            cluster, cfg, pre.pc, max(min(chunk_tokens, int(eff_in)), 1))
        # server utilization follows the (slightly inflated) chunked
        # service time; the HOL quantum in the waiting term is one chunk
        rho_p = min(rate_i * s_own, 0.999)
        wait_p = min(s_p, s_chunk) * rho_p / (1 - rho_p)
        ttft_mean = wait_p + s_own
    else:
        rho_p = min(rate_i * s_p, 0.999)
        wait_p = s_p * rho_p / (1 - rho_p)      # M/M/1-ish queue
        ttft_mean = wait_p + s_p

    # decode: fixed-point on concurrent batch. Shared prefix pages let the
    # same page budget admit more concurrent sequences (capacity credit);
    # the attention read cost keeps the FULL context — shared pages are
    # still dequantized and read every step.
    bmax = cm.prefix_shared_decode_batch(dec.cost.max_decode_batch, wl)
    B = 8.0
    for _ in range(8):
        tpot = cm.decode_step_latency(cluster, cfg, dec.pc,
                                      max(int(B), 1),
                                      int(wl.mean_in + wl.mean_out / 2))
        B_new = rate_j * wl.mean_out * tpot
        B = 0.5 * B + 0.5 * min(max(B_new, 1.0), bmax)
    tpot = cm.decode_step_latency(cluster, cfg, dec.pc, max(int(B), 1),
                                  int(wl.mean_in + wl.mean_out / 2))
    overload = rate_j * wl.mean_out * tpot > bmax * 1.05

    # only freshly prefilled suffix KV transits the wire on the hot path
    # (full hits skip the transfer stage; page handles move, not tensors)
    t_kv = cm.kv_transfer_time(cluster, cfg, pre.devices, dec.devices,
                               max(int(eff_in), 1), compress=compress)
    e2e_mean = ttft_mean + t_kv + wl.mean_out * tpot

    p_ttft = _lognorm_cdf(slo.ttft_s, ttft_mean, wl.cv_in)
    p_tpot = 1.0 if tpot <= slo.tpot_s else \
        max(0.0, 1.0 - (tpot - slo.tpot_s) / max(slo.tpot_s, 1e-9))
    p_e2e = _lognorm_cdf(slo.e2e_s, e2e_mean, wl.cv_out)
    att = p_ttft * p_tpot * p_e2e
    if overload:
        att *= 0.1
    return att


def build_matrix(cluster: ClusterSpec, cfg: ModelConfig,
                 prefills: List[ReplicaPlan], decodes: List[ReplicaPlan],
                 wl: Workload, rate: float, slo: SloSpec, *,
                 compress: bool = True, chunk_tokens: int = 0) -> np.ndarray:
    m, n = len(prefills), len(decodes)
    D = np.zeros((m, n))
    cap_p = np.array([p.cost.prefill_tokens_per_s
                      / cm.effective_prefill_tokens(wl)
                      for p in prefills])
    cap_d = np.array([d.cost.decode_tokens_per_s / wl.mean_out
                      for d in decodes])
    lam_p = rate * cap_p / max(cap_p.sum(), 1e-9)
    lam_d = rate * cap_d / max(cap_d.sum(), 1e-9)
    for i in range(m):
        for j in range(n):
            D[i, j] = estimate_pair_slo(cluster, cfg, prefills[i],
                                        decodes[j], wl, lam_p[i], lam_d[j],
                                        slo, compress=compress,
                                        chunk_tokens=chunk_tokens)
    return D


def solve_tstp(D: np.ndarray, cap_p: np.ndarray, cap_d: np.ndarray,
               rate: float) -> Orchestration:
    """LP over joint mass Z (m*n vars)."""
    m, n = D.shape
    c = -D.reshape(-1)
    A_ub, b_ub = [], []
    for i in range(m):  # row caps
        row = np.zeros(m * n)
        row[i * n:(i + 1) * n] = 1.0
        A_ub.append(row)
        b_ub.append(min(cap_p[i] / max(rate, 1e-9), 1.0))
    for j in range(n):  # col caps
        row = np.zeros(m * n)
        row[j::n] = 1.0
        A_ub.append(row)
        b_ub.append(min(cap_d[j] / max(rate, 1e-9), 1.0))
    A_ub.append(np.ones(m * n))
    b_ub.append(1.0)
    res = linprog(c, A_ub=np.array(A_ub), b_ub=np.array(b_ub),
                  bounds=(0, None), method="highs")
    Z = res.x.reshape(m, n) if res.success else np.zeros((m, n))
    X = Z.sum(axis=1)
    served = float(Z.sum())
    Y = np.where(X[:, None] > 1e-12, Z / np.maximum(X[:, None], 1e-12), 0.0)
    att = float((Z * D).sum())  # unserved requests contribute 0
    return Orchestration(X=X, Y=Y, Z=Z, D=D, attainment=att,
                         served_frac=served)


def orchestrate(cluster: ClusterSpec, cfg: ModelConfig,
                prefills: List[ReplicaPlan], decodes: List[ReplicaPlan],
                wl: Workload, rate: float, slo: SloSpec, *,
                compress: bool = True,
                chunk_tokens: int = 0) -> Optional[Orchestration]:
    if not prefills or not decodes:
        return None
    D = build_matrix(cluster, cfg, prefills, decodes, wl, rate, slo,
                     compress=compress, chunk_tokens=chunk_tokens)
    cap_p = np.array([p.cost.prefill_tokens_per_s
                      / cm.effective_prefill_tokens(wl)
                      for p in prefills])
    cap_d = np.array([d.cost.decode_tokens_per_s / wl.mean_out
                      for d in decodes])
    return solve_tstp(D, cap_p, cap_d, rate)
