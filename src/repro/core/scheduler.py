"""Two-level scheduling driver (paper §3): tabu (upper) x {Alg. 2 parallel
deduction + TSTP orchestration} (lower) -> DeploymentPlan.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costmodel as cm
from repro.core import orchestrator as orch
from repro.core import parallel as par
from repro.core import tabu
from repro.core.cluster import ClusterSpec
from repro.core.workload import Workload


@dataclass
class DeploymentPlan:
    solution: tabu.Solution
    replicas: List[orch.ReplicaPlan]
    orchestration: Optional[orch.Orchestration]
    score: float
    search_seconds: float = 0.0
    search_history: List[float] = field(default_factory=list)
    evals: int = 0

    @property
    def prefill_replicas(self) -> List[orch.ReplicaPlan]:
        return [r for r in self.replicas if r.phase == "prefill"]

    @property
    def decode_replicas(self) -> List[orch.ReplicaPlan]:
        return [r for r in self.replicas if r.phase == "decode"]

    def describe(self) -> str:
        lines = []
        for r in self.replicas:
            lines.append(f"  {r.phase:8s} {r.pc.describe():12s} "
                         f"devices={list(r.devices)}")
        lines.append(f"  score={self.score:.4f} "
                     f"(P:{len(self.prefill_replicas)} "
                     f"D:{len(self.decode_replicas)})")
        return "\n".join(lines)


class LowerLevelSolver:
    """Caches Alg. 2 deductions per (group, phase) — the tabu inner loop
    re-visits the same groups constantly."""

    def __init__(self, cluster: ClusterSpec, cfg: ModelConfig, wl: Workload,
                 rate: float, slo: orch.SloSpec, *, compress: bool = True,
                 chunk_tokens: int = 0):
        self.cluster, self.cfg = cluster, cfg
        self.wl, self.rate, self.slo = wl, rate, slo
        self.compress = compress
        self.chunk_tokens = chunk_tokens
        self._cache: Dict[Tuple, Optional[Tuple]] = {}

    def seed(self, plan: "DeploymentPlan") -> None:
        """Freeze a live plan's parallel configs into the deduction cache.

        Every group keeps its deployed ParallelConfig for BOTH phase
        designations (the paper's no-reload constraint: a flip re-uses the
        resident sharded parameters, so the parallel config cannot change
        with the phase). After seeding, ``deduce`` never re-runs Alg. 2
        for a live group, and ``solve`` scores flip-only neighbors against
        the frozen configs."""
        for r in plan.replicas:
            for ph in ("prefill", "decode"):
                self._cache[(tuple(r.devices), ph)] = (r.pc, r.cost)

    def deduce(self, group: Tuple[int, ...], phase: str):
        key = (group, phase)
        if key not in self._cache:
            self._cache[key] = par.deduce(
                self.cluster, self.cfg, list(group), phase,
                mean_ctx=int(self.wl.mean_in + self.wl.mean_out))
        return self._cache[key]

    def solve(self, sol: tabu.Solution
              ) -> Tuple[float, List[orch.ReplicaPlan],
                         Optional[orch.Orchestration]]:
        replicas: List[orch.ReplicaPlan] = []
        for group, phase in zip(sol.groups, sol.phases):
            got = self.deduce(group, phase)
            if got is None:
                return 0.0, [], None
            pc, rc = got
            replicas.append(orch.ReplicaPlan(list(group), phase, pc, rc))
        pre = [r for r in replicas if r.phase == "prefill"]
        dec = [r for r in replicas if r.phase == "decode"]
        o = orch.orchestrate(self.cluster, self.cfg, pre, dec, self.wl,
                             self.rate, self.slo, compress=self.compress,
                             chunk_tokens=self.chunk_tokens)
        if o is None:
            return 0.0, replicas, None
        return o.attainment, replicas, o

    def score(self, sol: tabu.Solution) -> float:
        return self.solve(sol)[0]


def schedule(cluster: ClusterSpec, cfg: ModelConfig, wl: Workload,
             rate: float, slo: orch.SloSpec, *, n_step: int = 100,
             n_nghb: int = 10, n_mem: int = 5, seed: int = 0,
             compress: bool = True, patience: int = 25,
             chunk_tokens: int = 0) -> DeploymentPlan:
    """Full scheduling from scratch (paper Fig. 3 workflow).

    ``chunk_tokens`` exposes the serving scheduler's chunked-prefill
    budget to the cost model, so the tabu search scores phase splits
    against the TTFT the token-budget gateway will actually deliver."""
    t0 = time.time()
    solver = LowerLevelSolver(cluster, cfg, wl, rate, slo, compress=compress,
                              chunk_tokens=chunk_tokens)
    res = tabu.tabu_search(cluster, cfg, solver.score, n_step=n_step,
                           n_nghb=n_nghb, n_mem=n_mem, seed=seed,
                           patience=patience)
    score, replicas, o = solver.solve(res.best)
    return DeploymentPlan(solution=res.best, replicas=replicas,
                          orchestration=o, score=score,
                          search_seconds=time.time() - t0,
                          search_history=res.history, evals=res.evals)


def reschedule_lightweight(cluster: ClusterSpec, cfg: ModelConfig,
                           plan: DeploymentPlan, wl: Workload, rate: float,
                           slo: orch.SloSpec, *, n_step: int = 30,
                           n_nghb: int = 8, seed: int = 1,
                           compress: bool = True, chunk_tokens: int = 0,
                           init_solution: Optional[tabu.Solution] = None
                           ) -> DeploymentPlan:
    """Paper §3.4: flip-only tabu + re-orchestration.

    Group construction and parallel configurations are FROZEN (no parameter
    reloading); only phase designation and the TSTP routing change. Handles
    workload shifts and node failures (pass init_solution = drop_nodes(...)).
    """
    t0 = time.time()
    solver = LowerLevelSolver(cluster, cfg, wl, rate, slo, compress=compress,
                              chunk_tokens=chunk_tokens)
    # freeze parallel configs: seed the deduction cache from the live plan
    solver.seed(plan)
    res = tabu.tabu_search(cluster, cfg, solver.score, n_step=n_step,
                           n_nghb=n_nghb, seed=seed, moves=(tabu._flip,),
                           init=init_solution or plan.solution, patience=10)
    score, replicas, o = solver.solve(res.best)
    return DeploymentPlan(solution=res.best, replicas=replicas,
                          orchestration=o, score=score,
                          search_seconds=time.time() - t0,
                          search_history=res.history, evals=res.evals)


def drop_nodes(cluster: ClusterSpec, plan: DeploymentPlan,
               dead_devices: List[int]) -> tabu.Solution:
    """Remove failed devices: any group that lost a device is dropped
    OUTRIGHT — its surviving devices leave the solution entirely rather
    than being folded into other groups. Re-absorbing survivors would
    change those groups' parallel configs and force a parameter
    reload/re-shard; the paper instead drops the affected replicas and
    reflows their traffic onto the untouched groups (lightweight
    rescheduling then re-designates phases among the survivors)."""
    dead = set(dead_devices)
    groups, phases = [], []
    for g, p in zip(plan.solution.groups, plan.solution.phases):
        if not (set(g) & dead):
            groups.append(g)
            phases.append(p)
    return tabu.Solution(tuple(groups), tuple(phases))


# -- plan epochs: structural diff between two deployment plans ---------------


@dataclass
class PlanDelta:
    """What changed between two deployment plans, keyed by device group.

    Groups are the stable identity across an epoch transition: lightweight
    rescheduling never changes group construction (params stay resident),
    so a group either keeps its phase, flips it, dies (node failure), or —
    only after a FULL reschedule — appears anew. ``Gateway.apply_plan``
    consumes this to drain/flip live replicas and atomically install the
    new routing masses."""
    old_plan: DeploymentPlan
    new_plan: DeploymentPlan
    flips: List[Tuple[Tuple[int, ...], str, str]]   # (group, old, new phase)
    kept: List[Tuple[Tuple[int, ...], str]]         # (group, phase)
    dropped: List[Tuple[Tuple[int, ...], str]]      # in old only
    added: List[Tuple[Tuple[int, ...], str]]        # in new only

    @property
    def is_noop(self) -> bool:
        return not (self.flips or self.dropped or self.added)

    def describe(self) -> str:
        parts = [f"{len(self.flips)} flip(s)", f"{len(self.kept)} kept"]
        if self.dropped:
            parts.append(f"{len(self.dropped)} dropped")
        if self.added:
            parts.append(f"{len(self.added)} added")
        for g, a, b in self.flips:
            parts.append(f"{list(g)}: {a}->{b}")
        return ", ".join(parts)


def _group_key(devices) -> Tuple[int, ...]:
    return tuple(sorted(int(d) for d in devices))


def plan_diff(old: DeploymentPlan, new: DeploymentPlan) -> PlanDelta:
    """Structural diff old -> new, matching replicas by device group."""
    old_by = {_group_key(r.devices): r.phase for r in old.replicas}
    new_by = {_group_key(r.devices): r.phase for r in new.replicas}
    flips, kept = [], []
    for g, ph in new_by.items():
        if g not in old_by:
            continue
        if old_by[g] == ph:
            kept.append((g, ph))
        else:
            flips.append((g, old_by[g], ph))
    dropped = [(g, ph) for g, ph in old_by.items() if g not in new_by]
    added = [(g, ph) for g, ph in new_by.items() if g not in old_by]
    return PlanDelta(old_plan=old, new_plan=new, flips=flips, kept=kept,
                     dropped=dropped, added=added)
