"""Analytic cost model for replica latency/throughput (HexGen-style, paper
Appendix B) with alpha-beta communication terms (Eq. 1).

All estimates are roofline-based per stage: max(compute, HBM) + TP collective
+ PP transfer. Used by (a) Alg. 2 parallel-config deduction, (b) the SLO
simulator, (c) the TSTP orchestration matrix.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core.cluster import ClusterSpec

BYTES = 2  # bf16/fp16 weights+activations

# efficiency knobs (calibrated once; only relative accuracy matters)
MFU = 0.5                # achievable fraction of peak flops, prefill
HBM_EFF = 0.75           # achievable fraction of HBM bandwidth, decode
KERNEL_OVERHEAD = 8e-6   # fixed per-layer launch/dispatch overhead (s)

# int4 wire format: 4 bits/elem + (scale+zero = 8B f32) per 128-elem group
INT4_WIRE_FACTOR = (4.0 / 16.0) + 8.0 / (128 * BYTES)
# int4-RESIDENT decode cache (paged pool, DESIGN.md §7): same encoding at
# rest, so both the capacity term and decode's KV read traffic shrink by it
INT4_RESIDENT_FACTOR = INT4_WIRE_FACTOR
# fixed-size KV pages (tokens per page) for the paged capacity arithmetic;
# matches serving's DEFAULT_PAGE_SIZE
PAGE_SIZE = 16


@dataclass
class ParallelConfig:
    tp: int
    pp: int
    stages: List[List[int]]          # device idxs per stage (len == pp)
    layer_partition: List[int]       # layers per stage (sums to num_layers)

    def describe(self) -> str:
        return f"TP={self.tp},PP={self.pp}"


def model_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * BYTES


_KV_CACHE: dict = {}
_STATE_CACHE: dict = {}


def kv_bytes_per_token(cfg: ModelConfig, *, resident: str = "bf16") -> float:
    """KV (or recurrent-state amortized) bytes per token across all layers.

    ``resident="int4"`` applies the paged pool's at-rest compression
    (group-wise int4 + scale/zero overhead) — the footprint decode
    capacity and decode HBM reads actually pay with the paged cache."""
    key = (cfg, resident)
    hit = _KV_CACHE.get(key)
    if hit is not None:
        return hit
    att_layers = sum(1 for l in range(cfg.num_layers) if cfg.is_attn_layer(l)
                     and cfg.family != "ssm")
    raw = 2 * att_layers * cfg.kv_dim * BYTES
    _KV_CACHE[key] = raw * (INT4_RESIDENT_FACTOR if resident == "int4"
                            else 1.0)
    return _KV_CACHE[key]


def paged_kv_supported(cfg: ModelConfig) -> bool:
    """Mirror of ``models.paged.paged_supported`` without importing the
    model stack: the cost model credits int4-resident paging only to
    pure-attention archs (recurrent/SWA/audio keep dense arithmetic)."""
    if cfg.family in ("ssm", "hybrid", "audio") or cfg.sliding_window \
            or cfg.attn_logit_softcap:
        return False
    return True


def state_bytes(cfg: ModelConfig, batch: int) -> float:
    """Recurrent state bytes (SSM/hybrid archs): O(1) in sequence length."""
    hit = _STATE_CACHE.get(cfg)
    if hit is None:
        total = 0.0
        kinds = cfg.layer_kinds()
        for l in range(cfg.num_layers):
            kind = kinds[l]
            if "mamba" in kind:
                total += cfg.mamba_d_inner * (cfg.mamba_d_state * 4
                                              + (cfg.mamba_d_conv - 1) * BYTES)
            elif "mlstm" in kind:
                dh = 2 * cfg.d_model // cfg.num_heads
                total += cfg.num_heads * (dh * dh + dh + 1) * 4
            elif "slstm" in kind:
                total += 4 * cfg.d_model * 4
        _STATE_CACHE[cfg] = total
        hit = total
    return hit * batch


@dataclass
class ReplicaCost:
    """Latency/throughput estimates for one (group, parallel cfg, phase)."""
    prefill_latency_1k: float    # TTFT for one 1024-token request (s)
    decode_tpot: float           # per-token decode latency at ref batch (s)
    max_decode_batch: int
    prefill_tokens_per_s: float
    decode_tokens_per_s: float


def _stage_devices(cluster: ClusterSpec, stage: Sequence[int]):
    return [cluster.devices[i] for i in stage]


def _intra_bw(cluster: ClusterSpec, stage: Sequence[int]) -> float:
    if len(stage) <= 1:
        return float("inf")
    return min(cluster.bw[i, j] for i in stage for j in stage if i != j)


def _allreduce_time(nbytes: float, n: int, bw: float, alpha: float) -> float:
    if n <= 1:
        return 0.0
    return alpha * math.log2(max(n, 2)) + 2.0 * nbytes * (n - 1) / (n * bw)


def prefill_latency(cluster: ClusterSpec, cfg: ModelConfig,
                    pc: ParallelConfig, tokens: int) -> float:
    """TTFT for a prompt batch of `tokens` total tokens (latency mode)."""
    n_act = cfg.active_param_count()
    total = 0.0
    d = cfg.d_model
    for s, stage in enumerate(pc.stages):
        devs = _stage_devices(cluster, stage)
        layers = pc.layer_partition[s]
        frac = layers / cfg.num_layers
        flops = 2.0 * n_act * frac * tokens
        # attention quadratic term (cheap estimate, causal halved)
        eff_ctx = min(tokens, cfg.sliding_window or tokens)
        flops += 2.0 * layers * tokens * eff_ctx * cfg.q_dim
        peak = sum(dv.chip.peak_flops for dv in devs) * MFU
        t_comp = flops / peak
        t_mem = (n_act * frac * BYTES) / (
            sum(dv.chip.hbm_bw for dv in devs) * HBM_EFF)
        # TP collectives: 2 all-reduces of activations per layer
        bw = _intra_bw(cluster, stage)
        t_tp = layers * 2 * _allreduce_time(tokens * d * BYTES, pc.tp, bw,
                                            cluster.alpha)
        total += max(t_comp, t_mem) + t_tp + layers * KERNEL_OVERHEAD
        if s + 1 < len(pc.stages):
            link = cluster.min_bw_between(stage, pc.stages[s + 1])
            total += cluster.alpha + tokens * d * BYTES / link
    return total


def chunked_prefill_latency(cluster: ClusterSpec, cfg: ModelConfig,
                            pc: ParallelConfig, tokens: int,
                            chunk_tokens: int) -> float:
    """Total prefill time for one prompt run as SARATHI-style fixed-token
    chunks of ``chunk_tokens`` (0 or >= tokens degenerates to one-shot).

    Each chunk pays the normal prefill cost for its own tokens plus the
    cross-attention of its queries against the KV already resident from
    earlier chunks (the suffix-prefill path attends over the dequantized
    prefix). Per-chunk kernel overheads repeat, so the total is strictly
    above the one-shot latency — the win is scheduling (TTFT of OTHER
    requests), not this prompt's completion time."""
    if chunk_tokens <= 0 or chunk_tokens >= tokens:
        return prefill_latency(cluster, cfg, pc, tokens)
    total, done = 0.0, 0
    while done < tokens:
        take = min(chunk_tokens, tokens - done)
        total += prefill_latency(cluster, cfg, pc, take)
        if done > 0:
            # queries of this chunk x resident prefix KV, across stages
            extra = 2.0 * cfg.num_layers * take * done * cfg.q_dim
            peak = sum(sum(dv.chip.peak_flops
                           for dv in _stage_devices(cluster, stage))
                       for stage in pc.stages) * MFU
            total += extra / peak
        done += take
    return total


def decode_step_latency(cluster: ClusterSpec, cfg: ModelConfig,
                        pc: ParallelConfig, batch: int, ctx: int) -> float:
    """One decode step (one token per sequence, batch sequences).

    Archs served by the paged pool read int4-at-rest KV (the fused-dequant
    kernel never materializes 16-bit), so their memory-bound KV term
    shrinks by the residency factor."""
    n_act = cfg.active_param_count()
    kv_tok = kv_bytes_per_token(
        cfg, resident="int4" if paged_kv_supported(cfg) else "bf16")
    eff_ctx = min(ctx, cfg.sliding_window or ctx)
    d = cfg.d_model
    total = 0.0
    for s, stage in enumerate(pc.stages):
        devs = _stage_devices(cluster, stage)
        layers = pc.layer_partition[s]
        frac = layers / cfg.num_layers
        hbm = sum(dv.chip.hbm_bw for dv in devs) * HBM_EFF
        bytes_params = n_act * frac * BYTES
        bytes_kv = batch * eff_ctx * kv_tok * frac
        bytes_state = state_bytes(cfg, batch) * frac
        t_mem = (bytes_params + bytes_kv + bytes_state) / hbm
        flops = 2.0 * n_act * frac * batch
        t_comp = flops / (sum(dv.chip.peak_flops for dv in devs) * MFU)
        bw = _intra_bw(cluster, stage)
        t_tp = layers * 2 * _allreduce_time(batch * d * BYTES, pc.tp, bw,
                                            cluster.alpha)
        total += max(t_comp, t_mem) + t_tp + layers * KERNEL_OVERHEAD
        if s + 1 < len(pc.stages):
            link = cluster.min_bw_between(stage, pc.stages[s + 1])
            total += cluster.alpha + batch * d * BYTES / link
    return total


def decode_page_budget(cluster: ClusterSpec, cfg: ModelConfig,
                       pc: ParallelConfig, *,
                       page_size: int = PAGE_SIZE) -> int:
    """Pages of int4-resident KV the group's remaining HBM can hold (the
    stage with the least headroom per hosted layer governs)."""
    page_bytes_full = page_size * kv_bytes_per_token(cfg, resident="int4")
    worst = math.inf
    for s, stage in enumerate(pc.stages):
        devs = _stage_devices(cluster, stage)
        mem = sum(dv.chip.hbm_bytes for dv in devs) * 0.9
        frac = pc.layer_partition[s] / cfg.num_layers
        avail = mem - cfg.active_param_count() * frac * BYTES \
            - cfg.vocab_size * cfg.d_model * BYTES
        worst = min(worst, avail / max(page_bytes_full * frac, 1.0))
    return max(0, int(worst))


def max_decode_batch(cluster: ClusterSpec, cfg: ModelConfig,
                     pc: ParallelConfig, ctx: int, *,
                     page_size: int = PAGE_SIZE) -> int:
    """Largest concurrent decode batch the group's remaining memory admits.

    Paged-capable archs use PAGE-BUDGET arithmetic: capacity = the
    group's int4-resident page budget divided by ``ceil(ctx/page_size)``
    pages per sequence — so the scheduler credits at-rest compression
    with its real (~7x) concurrency gain instead of assuming a dense
    bf16 ``batch x max_seq`` slab. Everything else keeps the dense
    worst-case arithmetic."""
    eff_ctx = min(ctx, cfg.sliding_window or ctx)
    if paged_kv_supported(cfg):
        pages_per_seq = -(-eff_ctx // page_size)
        budget = decode_page_budget(cluster, cfg, pc, page_size=page_size)
        return max(1, budget // max(pages_per_seq, 1))
    per_seq = (eff_ctx * kv_bytes_per_token(cfg) + state_bytes(cfg, 1))
    worst = math.inf
    for s, stage in enumerate(pc.stages):
        devs = _stage_devices(cluster, stage)
        mem = sum(dv.chip.hbm_bytes for dv in devs) * 0.9
        frac = pc.layer_partition[s] / cfg.num_layers
        avail = mem - cfg.active_param_count() * frac * BYTES \
            - cfg.vocab_size * cfg.d_model * BYTES
        worst = min(worst, avail / max(per_seq * frac, 1.0))
    return max(1, int(worst))


def effective_prefill_tokens(wl) -> float:
    """Mean prompt tokens a request actually PREFILLS once the shared radix
    prefix cache (serving/prefix_cache.py) serves ``wl.prefix_hit_rate`` of
    prompt tokens: full hits skip prefill outright and partial hits prefill
    only the suffix past the matched page boundary. Clamped to keep 5% of
    the prompt even at a measured hit rate of 1.0 — the first occurrence of
    every prefix is always a cold miss, so planning for literally zero
    prefill work would starve the prefill fleet of the capacity that
    *creates* cache entries."""
    hr = min(max(float(getattr(wl, "prefix_hit_rate", 0.0)), 0.0), 0.95)
    return max(wl.mean_in * (1.0 - hr), 1.0)


def prefix_shared_decode_batch(base_batch: int, wl, *,
                               page_size: int = PAGE_SIZE) -> int:
    """Concurrency credit from prefix sharing: the hit fraction of each
    sequence's PROMPT rides on refcounted pages shared with other residents,
    so only ``mean_in*(1-hr) + mean_out`` tokens' worth of pages are freshly
    allocated per admitted sequence. The page budget divided by that smaller
    per-sequence footprint admits proportionally more concurrent decodes at
    fixed cache bytes. Generated tokens never share; the same 0.95 clamp as
    ``effective_prefill_tokens`` keeps the first-occurrence cost real."""
    hr = min(max(float(getattr(wl, "prefix_hit_rate", 0.0)), 0.0), 0.95)
    if hr <= 0.0 or base_batch <= 0:
        return base_batch
    ctx_full = wl.mean_in + wl.mean_out / 2.0
    ctx_fresh = wl.mean_in * (1.0 - hr) + wl.mean_out / 2.0
    pages_full = max(-(-int(ctx_full) // page_size), 1)
    pages_fresh = max(-(-int(ctx_fresh) // page_size), 1)
    return max(base_batch,
               int(base_batch * pages_full / max(pages_fresh, 1)))


def kv_transfer_time(cluster: ClusterSpec, cfg: ModelConfig,
                     src: Sequence[int], dst: Sequence[int],
                     n_tokens: int, *, compress: bool = True) -> float:
    """Paper Eq. 1: T = alpha + 2*b*s*h*N_bytes / beta, with one-shot int4
    compression shrinking N_bytes 4x (+small scale overhead)."""
    kv = n_tokens * kv_bytes_per_token(cfg)
    if cfg.family in ("ssm",):
        kv = state_bytes(cfg, 1)          # recurrent snapshot, not KV
    if cfg.family == "hybrid":
        kv = n_tokens * kv_bytes_per_token(cfg) + state_bytes(cfg, 1)
    if compress:
        kv *= INT4_WIRE_FACTOR
    beta = cluster.min_bw_between(list(src), list(dst))
    return cluster.alpha + kv / beta


def replica_cost(cluster: ClusterSpec, cfg: ModelConfig, pc: ParallelConfig,
                 *, mean_ctx: int = 1024) -> ReplicaCost:
    lat1k = prefill_latency(cluster, cfg, pc, 1024)
    bmax = max_decode_batch(cluster, cfg, pc, mean_ctx * 2)
    bref = max(1, min(bmax, 64))
    tpot = decode_step_latency(cluster, cfg, pc, bref, mean_ctx)
    # pipeline throughput: pp microbatches in flight amortize stages
    pp_boost = min(pc.pp, 2.0) if pc.pp > 1 else 1.0
    return ReplicaCost(
        prefill_latency_1k=lat1k,
        decode_tpot=tpot / pp_boost,
        max_decode_batch=bmax,
        prefill_tokens_per_s=1024.0 / lat1k * pp_boost,
        decode_tokens_per_s=bref / (tpot / pp_boost),
    )
