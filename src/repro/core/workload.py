"""Serving workloads (paper §5.1): coding and conversation traces from the
Azure LLM inference dataset statistics, plus Poisson arrival generation.

Paper/Appendix E: both workloads have median prompt > 1000 tokens; coding
generates a median of 13 output tokens, conversation 129.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

import numpy as np


@dataclass(frozen=True)
class Workload:
    name: str
    mean_in: float
    mean_out: float
    cv_in: float = 0.6          # coefficient of variation (lognormal-ish)
    cv_out: float = 0.9
    # fraction of prompt TOKENS expected to be served from the shared
    # radix prefix cache (serving/prefix_cache.py). The cost model credits
    # this against prefill load and the decode page budget; 0 = no sharing.
    prefix_hit_rate: float = 0.0


CODING = Workload("coding", mean_in=1024, mean_out=16, cv_in=0.5, cv_out=0.8)
CONVERSATION = Workload("conversation", mean_in=1024, mean_out=129,
                        cv_in=0.6, cv_out=0.9)

WORKLOADS = {"coding": CODING, "conversation": CONVERSATION}


@dataclass
class Request:
    rid: int
    t_arrive: float
    n_in: int
    n_out: int
    # filled by the simulator / runtime
    t_prefill_start: float = -1.0
    t_first_token: float = -1.0
    t_done: float = -1.0
    prefill_replica: int = -1
    decode_replica: int = -1

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_arrive

    @property
    def e2e(self) -> float:
        return self.t_done - self.t_arrive

    @property
    def tpot(self) -> float:
        if self.n_out <= 1:
            return 0.0
        return (self.t_done - self.t_first_token) / max(self.n_out - 1, 1)


def _lognormal(rng, mean, cv, size):
    sigma2 = math.log(1.0 + cv * cv)
    mu = math.log(mean) - sigma2 / 2.0
    return rng.lognormal(mu, math.sqrt(sigma2), size)


def generate(workload: Workload, *, rate: float, duration: float,
             seed: int = 0, max_len: int = 8192) -> List[Request]:
    """Poisson arrivals at `rate` req/s for `duration` seconds."""
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    t = 0.0
    rid = 0
    while True:
        t += rng.exponential(1.0 / rate)
        if t > duration:
            break
        n_in = int(np.clip(_lognormal(rng, workload.mean_in,
                                      workload.cv_in, 1)[0], 8, max_len))
        n_out = int(np.clip(_lognormal(rng, workload.mean_out,
                                       workload.cv_out, 1)[0], 1, max_len))
        reqs.append(Request(rid, t, n_in, n_out))
        rid += 1
    return reqs


def mix(w1: Workload, w2: Workload, frac1: float, name: str = "mix"
        ) -> Workload:
    """Blend two workloads (used to model workload shifts)."""
    f2 = 1.0 - frac1
    return Workload(name,
                    mean_in=frac1 * w1.mean_in + f2 * w2.mean_in,
                    mean_out=frac1 * w1.mean_out + f2 * w2.mean_out,
                    cv_in=max(w1.cv_in, w2.cv_in),
                    cv_out=max(w1.cv_out, w2.cv_out))
