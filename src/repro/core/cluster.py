"""Cluster description: heterogeneous device pool + bandwidth matrix.

Ships both the paper's GPU cloud (2x4xA6000, 2x4xA5000, 1x8xA40, 2x4x3090Ti
rented from vast.ai, §5.1) for faithful reproduction, and TPU fleet profiles
(mixed v5e/v4 slices over DCN) for the deployment target — the scheduler is
agnostic: it only sees (peak_flops, hbm_bw, memory, price, bw matrix).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.roofline.hw import GPU_SPECS, TPU_V5E, ChipSpec


@dataclass(frozen=True)
class DeviceSpec:
    idx: int
    chip: ChipSpec
    node: int              # devices on the same node share fast links

    @property
    def type_name(self) -> str:
        return self.chip.name


@dataclass
class ClusterSpec:
    devices: List[DeviceSpec]
    bw: np.ndarray                      # (N, N) bytes/s, symmetric
    alpha: float = 5e-5                 # network latency (s) for alpha-beta

    def __post_init__(self):
        assert self.bw.shape == (len(self.devices),) * 2

    @property
    def n(self) -> int:
        return len(self.devices)

    def types(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for d in self.devices:
            out[d.type_name] = out.get(d.type_name, 0) + 1
        return out

    def price_per_hr(self) -> float:
        return sum(d.chip.price_per_hr for d in self.devices)

    def subset(self, idxs: Sequence[int]) -> "ClusterSpec":
        idxs = list(idxs)
        remap = {old: new for new, old in enumerate(idxs)}
        devs = [DeviceSpec(remap[d.idx], d.chip, d.node)
                for d in self.devices if d.idx in remap]
        devs.sort(key=lambda d: d.idx)
        return ClusterSpec(devs, self.bw[np.ix_(idxs, idxs)], self.alpha)

    def remove_nodes(self, nodes: Sequence[int]) -> "ClusterSpec":
        keep = [d.idx for d in self.devices if d.node not in set(nodes)]
        return self.subset(keep)

    def min_bw_between(self, a: Sequence[int], b: Sequence[int]) -> float:
        if not a or not b:
            return float("inf")
        return float(min(self.bw[i, j] for i in a for j in b))


def _build(nodes: List[Tuple[str, int]], *, intra_bw, inter_bw, seed=0,
           jitter=0.15, alpha=5e-5) -> ClusterSpec:
    """nodes: list of (chip_type_name, num_devices)."""
    rng = np.random.default_rng(seed)
    devices: List[DeviceSpec] = []
    idx = 0
    for node_id, (tname, cnt) in enumerate(nodes):
        chip = GPU_SPECS.get(tname) or TPU_PROFILES[tname]
        for _ in range(cnt):
            devices.append(DeviceSpec(idx, chip, node_id))
            idx += 1
    n = len(devices)
    bw = np.zeros((n, n))
    for i in range(n):
        for j in range(i + 1, n):
            same = devices[i].node == devices[j].node
            base = intra_bw if same else inter_bw
            val = base * (1.0 + jitter * (rng.random() * 2 - 1))
            bw[i, j] = bw[j, i] = val
        bw[i, i] = 1e15
    return ClusterSpec(devices, bw, alpha)


# TPU fleet profiles for the deployment target (beyond-paper): mixed
# generations, preemptible slices with degraded DCN.
TPU_PROFILES = {
    "tpu-v5e": TPU_V5E,
    "tpu-v4": ChipSpec("tpu-v4", 275e12, 1228e9, 50e9, 32e9, 3.22),
    "tpu-v5p": ChipSpec("tpu-v5p", 459e12, 2765e9, 90e9, 95e9, 4.2),
}


def make_paper_cloud(seed: int = 0) -> ClusterSpec:
    """The paper's §5.1 heterogeneous rental: 32 GPUs, $13.542/hr.

    PCIe intra-node (~12 GB/s effective), shared-ethernet inter-node
    (~5 Gbit/s = 0.6 GB/s with heterogeneity), cf. paper Fig. 13 heatmap.
    """
    nodes = [("A6000", 4), ("A6000", 4), ("A5000", 4), ("A5000", 4),
             ("A40", 8), ("3090Ti", 4), ("3090Ti", 4)]
    return _build(nodes, intra_bw=12e9, inter_bw=0.6e9, seed=seed,
                  jitter=0.4)


def make_inhouse(seed: int = 0) -> ClusterSpec:
    """The paper's homogeneous baseline: 8xA100-80G with NVLink."""
    return _build([("A100", 8)], intra_bw=300e9, inter_bw=25e9, seed=seed,
                  jitter=0.0)


def make_tpu_fleet(seed: int = 0) -> ClusterSpec:
    """Mixed TPU fleet: two v5e-8 slices + one v4-8 + preempt-degraded v5e-8.

    ICI within a slice, DCN across slices — the same heterogeneity structure
    the paper exploits (compute-rich parts -> prefill, HBM-rich -> decode).
    """
    nodes = [("tpu-v5e", 8), ("tpu-v5e", 8), ("tpu-v4", 8), ("tpu-v5e", 8)]
    return _build(nodes, intra_bw=100e9, inter_bw=3e9, seed=seed, jitter=0.2)


def make_cluster(name: str, seed: int = 0) -> ClusterSpec:
    return {"paper_cloud": make_paper_cloud, "inhouse": make_inhouse,
            "tpu_fleet": make_tpu_fleet}[name](seed)
