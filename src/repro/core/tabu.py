"""Algorithm 1: tabu search over (group construction, phase designation).

Initialization: hierarchical clustering on the inter-device bandwidth matrix
(groups avoid ultra-low-bandwidth cuts). Neighbor moves (paper Fig. 4):
flip / split / merge / move. Early feasibility check: a group must hold at
least one copy of the model parameters.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field, replace
from typing import Callable, List, Optional, Tuple

import numpy as np
from scipy.cluster.hierarchy import fcluster, linkage
from scipy.spatial.distance import squareform

from repro.configs.base import ModelConfig
from repro.core import costmodel as cm
from repro.core.cluster import ClusterSpec


@dataclass(frozen=True)
class Solution:
    """Upper-level solution: device groups + phase designation."""
    groups: Tuple[Tuple[int, ...], ...]
    phases: Tuple[str, ...]            # "prefill" | "decode" per group

    def key(self) -> Tuple:
        return (tuple(sorted((tuple(sorted(g)), p)
                             for g, p in zip(self.groups, self.phases))))


def group_memory_ok(cluster: ClusterSpec, cfg: ModelConfig,
                    group: Tuple[int, ...]) -> bool:
    mem = sum(cluster.devices[i].chip.hbm_bytes for i in group) * 0.92
    return mem >= cfg.param_count() * cm.BYTES * 1.05


def feasible(cluster: ClusterSpec, cfg: ModelConfig, sol: Solution) -> bool:
    if not sol.groups:
        return False
    phases = set(sol.phases)
    if "prefill" not in phases or "decode" not in phases:
        return False
    return all(group_memory_ok(cluster, cfg, g) for g in sol.groups)


def initial_solution(cluster: ClusterSpec, cfg: ModelConfig,
                     rng: random.Random) -> Solution:
    """Hierarchical clustering on the bandwidth matrix (paper §3.2 init)."""
    n = cluster.n
    with np.errstate(divide="ignore"):
        dist = 1.0 / np.maximum(cluster.bw, 1.0)
    np.fill_diagonal(dist, 0.0)
    cond = squareform(dist, checks=False)
    Z = linkage(cond, method="average")
    # pick the largest cluster count whose every group still fits the model
    for k in range(n, 0, -1):
        labels = fcluster(Z, k, criterion="maxclust")
        groups = [tuple(int(i) for i in np.where(labels == c)[0])
                  for c in sorted(set(labels))]
        if all(group_memory_ok(cluster, cfg, g) for g in groups) \
                and len(groups) >= 2:
            phases = ["prefill" if rng.random() < 0.5 else "decode"
                      for _ in groups]
            phases[0] = "prefill"
            phases[-1] = "decode"
            return Solution(tuple(groups), tuple(phases))
    return Solution((tuple(range(n)),), ("prefill",))


# ---------------------------------------------------------------------------
# Neighbor moves
# ---------------------------------------------------------------------------


def _flip(sol: Solution, rng) -> Optional[Solution]:
    if not sol.groups:
        return None
    i = rng.randrange(len(sol.groups))
    phases = list(sol.phases)
    phases[i] = "decode" if phases[i] == "prefill" else "prefill"
    return Solution(sol.groups, tuple(phases))


def _split(sol: Solution, rng) -> Optional[Solution]:
    cand = [i for i, g in enumerate(sol.groups) if len(g) >= 2]
    if not cand:
        return None
    i = rng.choice(cand)
    g = list(sol.groups[i])
    rng.shuffle(g)
    r = rng.uniform(0.25, 0.75)
    cut = max(1, min(len(g) - 1, int(len(g) * r)))
    g1, g2 = tuple(sorted(g[:cut])), tuple(sorted(g[cut:]))
    groups = list(sol.groups)
    phases = list(sol.phases)
    groups[i] = g1
    groups.append(g2)
    phases.append(rng.choice(("prefill", "decode")))
    phases[i] = rng.choice(("prefill", "decode"))
    return Solution(tuple(groups), tuple(phases))


def _merge(sol: Solution, rng) -> Optional[Solution]:
    if len(sol.groups) < 2:
        return None
    i, j = rng.sample(range(len(sol.groups)), 2)
    groups = list(sol.groups)
    phases = list(sol.phases)
    merged = tuple(sorted(groups[i] + groups[j]))
    for idx in sorted((i, j), reverse=True):
        del groups[idx]
        del phases[idx]
    groups.append(merged)
    phases.append(rng.choice(("prefill", "decode")))
    return Solution(tuple(groups), tuple(phases))


def _move(sol: Solution, rng) -> Optional[Solution]:
    cand = [i for i, g in enumerate(sol.groups) if len(g) >= 2]
    if not cand or len(sol.groups) < 2:
        return None
    i = rng.choice(cand)
    j = rng.choice([x for x in range(len(sol.groups)) if x != i])
    g_i = list(sol.groups[i])
    m = rng.randrange(1, len(g_i))
    rng.shuffle(g_i)
    moved, rest = g_i[:m], g_i[m:]
    groups = list(sol.groups)
    groups[i] = tuple(sorted(rest))
    groups[j] = tuple(sorted(list(groups[j]) + moved))
    return Solution(tuple(groups), sol.phases)


MOVES = (_flip, _split, _merge, _move)


def neighbors(cluster: ClusterSpec, cfg: ModelConfig, sol: Solution,
              n_nghb: int, rng, moves=MOVES) -> List[Solution]:
    out, seen = [], set()
    tries = 0
    while len(out) < n_nghb and tries < n_nghb * 12:
        tries += 1
        mv = rng.choice(moves)
        cand = mv(sol, rng)
        if cand is None or not feasible(cluster, cfg, cand):
            continue
        k = cand.key()
        if k in seen:
            continue
        seen.add(k)
        out.append(cand)
    return out


@dataclass
class TabuResult:
    best: Solution
    best_score: float
    history: List[float] = field(default_factory=list)
    evals: int = 0


def tabu_search(cluster: ClusterSpec, cfg: ModelConfig,
                f: Callable[[Solution], float], *, n_step: int = 100,
                n_nghb: int = 10, n_mem: int = 5, seed: int = 0,
                moves=MOVES, init: Optional[Solution] = None,
                patience: int = 25) -> TabuResult:
    """Paper Algorithm 1 (plus early stopping on `patience` flat steps)."""
    rng = random.Random(seed)
    x = init or initial_solution(cluster, cfg, rng)
    tabu: List[Tuple] = []
    best, best_score = x, f(x)
    res = TabuResult(best, best_score, [best_score], 1)
    flat = 0
    for _ in range(n_step):
        nbrs = [c for c in neighbors(cluster, cfg, x, n_nghb, rng, moves)
                if c.key() not in tabu]
        if not nbrs:
            break
        scored = [(f(c), c) for c in nbrs]
        res.evals += len(scored)
        score, x = max(scored, key=lambda t: t[0])
        if score > best_score:
            best, best_score = x, score
            flat = 0
        else:
            flat += 1
        tabu.append(x.key())
        if len(tabu) > n_mem:
            tabu = tabu[-n_mem:]
        res.history.append(best_score)
        if flat >= patience:
            break
    res.best, res.best_score = best, best_score
    return res
