"""ThunderServe core: two-level scheduling of phase-split LLM serving over
heterogeneous device pools (the paper's contribution).

Pipeline: ClusterSpec -> tabu search (group construction + phase designation)
x { parallel-config deduction + TSTP orchestration } -> DeploymentPlan ->
event simulator (SLO attainment) / serving runtime.
"""
from repro.core.cluster import ClusterSpec, DeviceSpec, make_paper_cloud  # noqa: F401
from repro.core.scheduler import DeploymentPlan, schedule  # noqa: F401
