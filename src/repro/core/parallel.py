"""Algorithm 2: deduction of the optimal parallel configuration per group.

Heuristics (paper §3.3):
  1. TP never spans nodes (cloud inter-node links are too slow) and never
     mixes device types.
  2. Non-uniform pipeline layer partition proportional to stage capability
     (memory+compute), respecting per-device memory limits.
  3. Bitmask DP over pipeline stage ordering maximizing the minimum
     inter-stage bandwidth (Appendix B).

Prefill groups pick the latency-optimal plan; decode groups the
throughput-optimal plan.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core import costmodel as cm
from repro.core.cluster import ClusterSpec


def _group_by_node_type(cluster: ClusterSpec, devices: Sequence[int]):
    """Partition a group's devices into (node, type) buckets."""
    buckets: Dict[Tuple[int, str], List[int]] = {}
    for i in devices:
        d = cluster.devices[i]
        buckets.setdefault((d.node, d.type_name), []).append(i)
    return buckets


def _route_stages_dp(cluster: ClusterSpec, stages: List[List[int]]
                     ) -> List[List[int]]:
    """Order pipeline stages to maximize the min inter-stage bandwidth.

    Bitmask DP over stage subsets: state (mask, last) -> best bottleneck bw.
    """
    k = len(stages)
    if k <= 2:
        return stages
    bw = [[cluster.min_bw_between(stages[i], stages[j]) if i != j else 0.0
           for j in range(k)] for i in range(k)]
    if k > 10:
        # bitmask DP is O(2^k k^2): beyond ~10 stages fall back to a greedy
        # max-bottleneck chain (start at the best edge, extend greedily)
        order = [0]
        left = set(range(1, k))
        while left:
            last = order[-1]
            nxt = max(left, key=lambda j: bw[last][j])
            order.append(nxt)
            left.remove(nxt)
        return [stages[i] for i in order]
    best: Dict[Tuple[int, int], Tuple[float, Tuple[int, ...]]] = {}
    for i in range(k):
        best[(1 << i, i)] = (math.inf, (i,))
    for mask in range(1, 1 << k):
        for last in range(k):
            if (mask, last) not in best:
                continue
            cur, path = best[(mask, last)]
            for nxt in range(k):
                if mask & (1 << nxt):
                    continue
                nb = min(cur, bw[last][nxt])
                key = (mask | (1 << nxt), nxt)
                if key not in best or best[key][0] < nb:
                    best[key] = (nb, path + (nxt,))
    full = (1 << k) - 1
    cand = [(v[0], v[1]) for (m, _), v in best.items() if m == full]
    _, order = max(cand)
    return [stages[i] for i in order]


def _partition_layers(cluster: ClusterSpec, cfg: ModelConfig,
                      stages: List[List[int]]) -> Optional[List[int]]:
    """Layers per stage proportional to capability, memory-feasible."""
    L = cfg.num_layers
    caps, mems = [], []
    for stage in stages:
        devs = [cluster.devices[i] for i in stage]
        caps.append(sum(d.chip.peak_flops for d in devs)
                    + 2e-9 * sum(d.chip.hbm_bw for d in devs))
        mems.append(sum(d.chip.hbm_bytes for d in devs) * 0.9)
    total_cap = sum(caps)
    part = [max(1, round(L * c / total_cap)) for c in caps]
    # fix rounding to sum exactly L
    while sum(part) > L:
        part[part.index(max(part))] -= 1
    while sum(part) < L:
        part[part.index(min(part))] += 1
    if any(p <= 0 for p in part):
        return None
    # memory feasibility: shift layers away from over-committed stages
    per_layer = cfg.param_count() * cm.BYTES / L
    embed = cfg.vocab_size * cfg.d_model * cm.BYTES
    for _ in range(4 * len(stages)):
        over = [i for i in range(len(stages))
                if part[i] * per_layer + embed > mems[i]]
        if not over:
            break
        i = over[0]
        if part[i] <= 1:
            return None  # stage can't hold even one layer
        part[i] -= 1
        j = min((x for x in range(len(stages)) if x not in over),
                key=lambda x: part[x] * per_layer / mems[x], default=None)
        if j is None:
            return None
        part[j] += 1
    if any(part[i] * per_layer + embed > mems[i] for i in range(len(stages))):
        return None
    return part


def enumerate_configs(cluster: ClusterSpec, cfg: ModelConfig,
                      devices: Sequence[int]) -> List[cm.ParallelConfig]:
    """All feasible (TP, PP) plans for a device group (Alg. 2 steps 1-3)."""
    buckets = _group_by_node_type(cluster, devices)
    if not devices:
        return []
    out: List[cm.ParallelConfig] = []
    sizes = [len(v) for v in buckets.values()]
    max_tp = min(sizes)  # heuristic 1: TP within single-type, single-node
    n = len(devices)
    for tp in [t for t in (1, 2, 4, 8) if t <= max_tp]:
        # carve each bucket into tp-sized cells; cells become PP stages
        stages: List[List[int]] = []
        ok = True
        for bucket in buckets.values():
            if len(bucket) % tp != 0:
                ok = False
                break
            for c in range(len(bucket) // tp):
                stages.append(bucket[c * tp:(c + 1) * tp])
        if not ok or not stages:
            continue
        pp = len(stages)
        if pp > cfg.num_layers:
            continue
        stages = _route_stages_dp(cluster, stages)
        part = _partition_layers(cluster, cfg, stages)
        if part is None:
            continue
        out.append(cm.ParallelConfig(tp=tp, pp=pp, stages=stages,
                                     layer_partition=part))
    return out


def deduce(cluster: ClusterSpec, cfg: ModelConfig, devices: Sequence[int],
           phase: str, *, mean_ctx: int = 1024
           ) -> Optional[Tuple[cm.ParallelConfig, cm.ReplicaCost]]:
    """Pick latency-optimal (prefill) or throughput-optimal (decode) plan."""
    best, best_score = None, -math.inf
    for pc in enumerate_configs(cluster, cfg, devices):
        rc = cm.replica_cost(cluster, cfg, pc, mean_ctx=mean_ctx)
        if phase == "prefill":
            score = -rc.prefill_latency_1k
        else:
            score = rc.decode_tokens_per_s
        if score > best_score:
            best, best_score = (pc, rc), score
    return best
