"""Discrete-event serving simulator (DistServe-style, paper §3.3 + §5).

Models: Poisson arrivals -> TSTP routing (X, Y) -> prefill replica queues
(token-budget batching, latency from the cost model) -> alpha-beta KV
transfer (Eq. 1, optional int4 compression) -> decode replicas (continuous
batching; per-step latency re-evaluated as the running batch changes).

Produces per-request TTFT / TPOT / E2E -> SLO attainment & throughput.
This is the measurement tool behind Figs. 6-12 reproductions.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import costmodel as cm
from repro.core.cluster import ClusterSpec
from repro.core.orchestrator import Orchestration, ReplicaPlan, SloSpec
from repro.core.workload import Request, Workload


@dataclass
class SimResult:
    requests: List[Request]
    duration: float
    ttft_attain: float
    tpot_attain: float
    e2e_attain: float
    throughput_tokens: float      # generated tokens / s
    throughput_reqs: float
    p50_e2e: float
    p99_e2e: float
    kv_comm_frac: float           # mean fraction of E2E spent in KV transfer

    def attainment(self, kind: str = "e2e") -> float:
        return {"ttft": self.ttft_attain, "tpot": self.tpot_attain,
                "e2e": self.e2e_attain}[kind]


class _PrefillReplica:
    def __init__(self, idx, cluster, cfg, plan: ReplicaPlan,
                 max_batch_tokens=4096):
        self.idx = idx
        self.cluster, self.cfg, self.plan = cluster, cfg, plan
        self.queue: List[Request] = []
        self.busy_until = 0.0
        self.max_batch_tokens = max_batch_tokens

    def latency(self, tokens: int) -> float:
        return cm.prefill_latency(self.cluster, self.cfg, self.plan.pc,
                                  max(tokens, 16))


class _DecodeReplica:
    def __init__(self, idx, cluster, cfg, plan: ReplicaPlan):
        self.idx = idx
        self.cluster, self.cfg, self.plan = cluster, cfg, plan
        self.active: List[Tuple[Request, int]] = []   # (req, tokens left)
        self.pending: List[Request] = []              # waiting for KV room
        self.max_batch = max(1, plan.cost.max_decode_batch)
        self.next_step = math.inf

    def step_latency(self) -> float:
        b = max(len(self.active), 1)
        ctx = int(np.mean([r.n_in for r, _ in self.active])) if self.active \
            else int(self.cfg.d_model and 1024)
        return cm.decode_step_latency(self.cluster, self.cfg, self.plan.pc,
                                      b, ctx)


def simulate(cluster: ClusterSpec, cfg: ModelConfig,
             replicas: List[ReplicaPlan], o: Orchestration,
             requests: List[Request], slo: SloSpec, *,
             compress: bool = True, seed: int = 0,
             colocated: bool = False,
             prefill_interference: float = 1.0) -> SimResult:
    """Run the event simulation.

    colocated=True models vLLM-style co-located serving: every replica is
    both prefill and decode; a prefill batch stalls that replica's decoding
    (interference), reproducing the paper's phase-splitting motivation.
    """
    rng = np.random.default_rng(seed)
    pre_plans = [r for r in replicas if r.phase == "prefill" or colocated]
    dec_plans = [r for r in replicas if r.phase == "decode" or colocated]
    pres = [_PrefillReplica(i, cluster, cfg, p)
            for i, p in enumerate(pre_plans)]
    decs = [_DecodeReplica(j, cluster, cfg, p)
            for j, p in enumerate(dec_plans)]

    X = o.X if o is not None and o.X.sum() > 1e-9 else \
        np.ones(len(pres)) / max(len(pres), 1)
    X = X / X.sum()
    Y = o.Y if o is not None else np.ones((len(pres), len(decs)))
    Y = np.where(Y.sum(axis=1, keepdims=True) > 1e-9,
                 Y / np.maximum(Y.sum(axis=1, keepdims=True), 1e-9),
                 np.ones_like(Y) / max(len(decs), 1))

    # event heap: (time, seq, kind, payload)
    ev: List[Tuple[float, int, str, object]] = []
    seq = 0

    def push(t, kind, payload):
        nonlocal seq
        heapq.heappush(ev, (t, seq, kind, payload))
        seq += 1

    for r in requests:
        push(r.t_arrive, "arrive", r)

    done: List[Request] = []
    kv_frac: List[float] = []

    def start_prefill(p: _PrefillReplica, now: float):
        if not p.queue or p.busy_until > now:
            return
        batch, toks = [], 0
        while p.queue and (not batch
                           or toks + p.queue[0].n_in <= p.max_batch_tokens):
            r = p.queue.pop(0)
            batch.append(r)
            toks += r.n_in
        lat = p.latency(toks) * prefill_interference
        p.busy_until = now + lat
        for r in batch:
            r.t_prefill_start = now
        if colocated and p.idx < len(decs):
            # phase interference: a prefill batch stalls this replica's
            # decode loop (the paper's motivation for phase splitting)
            d = decs[p.idx]
            if d.next_step != math.inf:
                d.next_step += lat
                push(d.next_step, "decode_step", p.idx)
        push(now + lat, "prefill_done", (p.idx, batch, toks))

    while ev:
        now, _, kind, payload = heapq.heappop(ev)
        if kind == "arrive":
            r = payload
            i = int(rng.choice(len(pres), p=X))
            r.prefill_replica = i
            pres[i].queue.append(r)
            start_prefill(pres[i], now)
        elif kind == "prefill_done":
            pidx, batch, toks = payload
            p = pres[pidx]
            for r in batch:
                r.t_first_token = now
                j = int(rng.choice(len(decs), p=Y[pidx]))
                r.decode_replica = j
                if colocated and j == pidx:
                    t_kv = 0.0
                else:
                    t_kv = cm.kv_transfer_time(
                        cluster, cfg, p.plan.devices, decs[j].plan.devices,
                        r.n_in, compress=compress)
                kv_frac.append(t_kv)
                push(now + t_kv, "join_decode", (j, r))
            start_prefill(p, now)
        elif kind == "join_decode":
            j, r = payload
            d = decs[j]
            if len(d.active) >= d.max_batch:
                d.pending.append(r)   # KV memory full: queue at the replica
            else:
                d.active.append((r, r.n_out))
            if d.next_step == math.inf and d.active:
                d.next_step = now + d.step_latency()
                push(d.next_step, "decode_step", j)
        elif kind == "decode_step":
            j = payload
            d = decs[j]
            if now < d.next_step - 1e-12:
                continue  # stale event
            still = []
            for r, left in d.active:
                left -= 1
                if left <= 0:
                    r.t_done = now
                    done.append(r)
                else:
                    still.append((r, left))
            d.active = still
            while d.pending and len(d.active) < d.max_batch:
                nxt = d.pending.pop(0)
                d.active.append((nxt, nxt.n_out))
            if d.active:
                d.next_step = now + d.step_latency()
                push(d.next_step, "decode_step", j)
            else:
                d.next_step = math.inf

    # metrics
    finished = [r for r in done if r.t_done >= 0]
    if not finished:
        return SimResult([], 0.0, 0, 0, 0, 0, 0, 0, 0, 0)
    t_end = max(r.t_done for r in finished)
    t0 = min(r.t_arrive for r in finished)
    dur = max(t_end - t0, 1e-9)
    ttft = np.array([r.ttft for r in finished])
    tpot = np.array([r.tpot for r in finished])
    e2e = np.array([r.e2e for r in finished])
    toks = sum(r.n_out for r in finished)
    return SimResult(
        requests=finished, duration=dur,
        ttft_attain=float((ttft <= slo.ttft_s).mean()),
        tpot_attain=float((tpot <= slo.tpot_s).mean()),
        e2e_attain=float((e2e <= slo.e2e_s).mean()),
        throughput_tokens=toks / dur,
        throughput_reqs=len(finished) / dur,
        p50_e2e=float(np.percentile(e2e, 50)),
        p99_e2e=float(np.percentile(e2e, 99)),
        kv_comm_frac=float(np.mean(np.array(kv_frac)
                                   / np.maximum(e2e[:len(kv_frac)], 1e-9)))
        if kv_frac else 0.0)


def min_slo_scale_for(cluster, cfg, replicas, o, requests, base: SloSpec,
                      target: float = 0.9, *, kind: str = "e2e",
                      compress: bool = True,
                      scales=(1.0, 1.25, 1.5, 2.0, 2.5, 3.0, 4.0, 5.0, 6.0,
                              8.0, 10.0, 12.0, 16.0)) -> float:
    """Paper metric: minimum latency deadline (SLO scale) reaching the
    attainment target."""
    for s in scales:
        res = simulate(cluster, cfg, replicas, o, requests, base.scaled(s),
                       compress=compress)
        if res.attainment(kind) >= target:
            return s
    return float("inf")
