"""Hardware constants for the roofline target (TPU v5e) and the paper's GPUs.

The container is CPU-only; these constants convert compiled-artifact counts
(FLOPs / bytes / collective payloads) into roofline seconds on the target.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops: float        # bf16/fp16 FLOP/s
    hbm_bw: float            # bytes/s
    link_bw: float           # bytes/s per ICI link (one direction)
    hbm_bytes: float
    price_per_hr: float = 0.0


TPU_V5E = ChipSpec("tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                   link_bw=50e9, hbm_bytes=16e9, price_per_hr=1.2)

# Paper Table 1 (used by the scheduler's faithful reproduction)
A100 = ChipSpec("A100", 312e12, 2.0e12, 25e9, 80e9, 1.753)
A6000 = ChipSpec("A6000", 38.7e12, 768e9, 8e9, 48e9, 0.483)
A5000 = ChipSpec("A5000", 27.8e12, 626.8e9, 8e9, 24e9, 0.223)
A40 = ChipSpec("A40", 149.7e12, 696e9, 8e9, 48e9, 0.403)
RTX3090TI = ChipSpec("3090Ti", 40e12, 1008e9, 8e9, 24e9, 0.307)

GPU_SPECS = {c.name: c for c in (A100, A6000, A5000, A40, RTX3090TI)}
