"""Render the roofline table (EXPERIMENTS.md §Roofline) from the per-cell
dry-run JSONs in results/dryrun/."""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

ADVICE = {
    ("memory", "train"): "raise arithmetic intensity: relax the full-recompute "
                         "remat policy (save dot outputs) and fuse optimizer",
    ("memory", "prefill"): "larger attention tiles / fused blocks to cut "
                           "activation traffic",
    ("memory", "decode"): "in-place KV update (fori_loop + donation) instead "
                          "of scan-ys cache rewrite",
    ("compute", "train"): "near-roofline; overlap DP all-reduce with bwd",
    ("compute", "prefill"): "near-roofline; overlap TP collectives",
    ("compute", "decode"): "batch more sequences per step",
    ("collective", "train"): "reduce-scatter+all-gather instead of all-reduce; "
                             "overlap with compute",
    ("collective", "prefill"): "shard sequence instead of heads; compress "
                               "a2a payloads",
    ("collective", "decode"): "replicate small weights; fuse collectives",
}


def load_cells(mesh: str = "single", tag: str = "baseline") -> List[dict]:
    out = []
    for p in sorted(RESULTS.glob(f"{mesh}_*_{tag}.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") == "ok":
            out.append(rec)
    return out


def _kind(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode"}[shape]


def table(mesh: str = "single", tag: str = "baseline") -> str:
    cells = load_cells(mesh, tag)
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | "
           "bottleneck | MODEL_FLOPS | useful | roofline-frac | next lever |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in cells:
        advice = ADVICE.get((r["bottleneck"], _kind(r["shape"])), "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['bottleneck']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} | "
            f"{advice} |")
    return "\n".join(lines)


def pick_hillclimb_cells(mesh: str = "single") -> Dict[str, dict]:
    """The three §Perf cells: worst roofline fraction, most collective-bound,
    most representative of the paper's technique (largest dense-serving
    decode — the phase-splitting target)."""
    cells = load_cells(mesh)
    by = {(c["arch"], c["shape"]): c for c in cells}
    worst = min(cells, key=lambda c: c["roofline_frac"])
    coll = max(cells, key=lambda c: c["collective_s"] / max(c["step_s"], 1e-12))
    paper = by.get(("command-r-35b", "decode_32k")) or worst
    return {"worst_roofline_frac": worst, "most_collective_bound": coll,
            "paper_representative": paper}


def summarize(mesh: str = "single", tag: str = "baseline") -> dict:
    cells = load_cells(mesh, tag)
    if not cells:
        return {}
    return {
        "n": len(cells),
        "bottlenecks": {b: sum(1 for c in cells if c["bottleneck"] == b)
                        for b in ("compute", "memory", "collective")},
        "mean_useful": sum(c["useful_ratio"] for c in cells) / len(cells),
    }


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print(table(mesh))
    print()
    print(summarize(mesh))
    for k, c in pick_hillclimb_cells(mesh).items():
        print(f"{k}: {c['arch']} x {c['shape']} "
              f"(frac={c['roofline_frac']:.3f}, "
              f"coll_share={c['collective_s']/max(c['step_s'],1e-12):.2f})")
