"""Roofline terms from a compiled (AOT) artifact.

``compute/memory`` come from ``compiled.cost_analysis()``; the collective
term is parsed out of the post-SPMD HLO text (``compiled.as_text()``), since
cost_analysis does not attribute communication. Post-partitioning HLO carries
*per-device* shapes, so all three terms are per-device seconds directly.

Wire-byte model per op (ring/bidirectional ICI):
  all-reduce:          2 * size * (n-1)/n
  all-gather:          out_size * (n-1)/n
  reduce-scatter:      in_size  * (n-1)/n
  all-to-all:          size * (n-1)/n
  collective-permute:  size
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

from repro.roofline.hw import ChipSpec, TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|[\w\[\],{}\s]+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(shape_str: str) -> int:
    """Sum byte sizes of every array literal in a (possibly tuple) shape."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    payload_bytes: Dict[str, float] = field(default_factory=dict)
    wire_bytes: float = 0.0

    def total_payload(self) -> float:
        return sum(self.payload_bytes.values())


def parse_collectives(hlo_text: str, default_group: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if "-done" in line.split("=")[1][:60] and f"{op}-done" in line:
            continue  # async pair: count only the -start
        size = _shape_bytes(shape_str)
        gm = _GROUPS_RE.search(line)
        if gm:
            n = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            n = int(gi.group(2)) if gi else default_group
        n = max(n, 2)
        ring = (n - 1) / n
        if op == "all-reduce":
            wire = 2.0 * size * ring
        elif op == "collective-permute":
            wire = float(size)
        else:  # all-gather / reduce-scatter / all-to-all
            wire = size * ring
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.payload_bytes[op] = stats.payload_bytes.get(op, 0.0) + size
        stats.wire_bytes += wire
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float            # per device
    hlo_bytes: float            # per device HBM traffic
    collective_payload: float   # per device payload bytes
    collective_wire: float      # per device wire bytes
    collective_counts: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float          # analytic useful FLOPs (global)
    useful_ratio: float         # model_flops / (hlo_flops * n_chips)
    step_s: float               # max of the three terms
    roofline_frac: float        # compute_s / step_s (how compute-bound)
    per_device_output_bytes: float = 0.0
    memory_estimate_bytes: float = 0.0

    def to_json(self) -> dict:
        return asdict(self)


def analyze(compiled, *, arch: str, shape: str, mesh_desc: str, n_chips: int,
            model_flops: float, chip: ChipSpec = TPU_V5E,
            n_links: int = 3) -> Roofline:
    """Roofline terms via the trip-count-aware HLO walker (hlo_cost).

    XLA's cost_analysis() counts while (scan) bodies once; the walker
    multiplies them by known_trip_count, so flops/bytes/collectives reflect
    the full step. Validated against cost_analysis on unrolled modules.
    """
    from repro.roofline import hlo_cost
    c = hlo_cost.analyze_calibrated(compiled, default_group=n_chips)
    flops, bytes_acc = c.flops, c.bytes
    coll = CollectiveStats(counts=c.coll_counts,
                           payload_bytes={"total": c.coll_payload},
                           wire_bytes=c.wire)
    compute_s = flops / chip.peak_flops
    memory_s = bytes_acc / chip.hbm_bw
    collective_s = coll.wire_bytes / (chip.link_bw * n_links)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    mem_an = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem_an = {"temp": getattr(ma, "temp_size_in_bytes", 0),
                      "arg": getattr(ma, "argument_size_in_bytes", 0),
                      "out": getattr(ma, "output_size_in_bytes", 0)}
    except Exception:
        pass
    total_flops = flops * n_chips
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_desc, n_chips=n_chips,
        hlo_flops=flops, hlo_bytes=bytes_acc,
        collective_payload=coll.total_payload(),
        collective_wire=coll.wire_bytes, collective_counts=coll.counts,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_ratio=(model_flops / total_flops) if total_flops else 0.0,
        step_s=step_s,
        roofline_frac=(compute_s / step_s) if step_s else 0.0,
        memory_estimate_bytes=float(mem_an.get("temp", 0)
                                    + mem_an.get("arg", 0)))


def model_flops_for(cfg, shape) -> float:
    """Analytic 'useful' FLOPs per step (global).

    train:   6 * N_active * tokens      (fwd+bwd)
    prefill: 2 * N_active * tokens  (+ attention-score term)
    decode:  2 * N_active * batch   (+ KV-read dot products)
    """
    n_act = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    d_attn = 0.0
    attn_layers = sum(1 for l in range(cfg.num_layers) if cfg.is_attn_layer(l)
                      and cfg.family != "ssm")
    if shape.kind == "train":
        toks = B * S
        eff_s = min(S, cfg.sliding_window) if cfg.sliding_window else S
        d_attn = 6 * attn_layers * 2 * B * S * eff_s * cfg.q_dim / 2
        return 6.0 * n_act * toks + d_attn
    if shape.kind == "prefill":
        toks = B * S
        eff_s = min(S, cfg.sliding_window) if cfg.sliding_window else S
        d_attn = 2 * attn_layers * 2 * B * S * eff_s * cfg.q_dim / 2
        return 2.0 * n_act * toks + d_attn
    # decode: one token per sequence
    eff_s = min(S, cfg.sliding_window) if cfg.sliding_window else S
    d_attn = 2 * attn_layers * 2 * B * eff_s * cfg.q_dim
    return 2.0 * n_act * B + d_attn
