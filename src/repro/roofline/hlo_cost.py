"""Trip-count-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
under-reports FLOPs/bytes/collectives for scan-based models by the trip
count. This walker parses the post-SPMD HLO, multiplies loop bodies by their
``known_trip_count`` (recorded by XLA in backend_config), and produces
per-device totals:

  flops            — dot ops (2 * prod(out) * prod(contracting))
  bytes            — per top-level op: operand bytes + output bytes
                     (fusion internals excluded — fused intermediates do not
                     touch HBM)
  collective wire  — ring-model wire bytes per collective (see analysis.py)

Validated against cost_analysis() on fully-unrolled modules in
tests/test_roofline.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1,
}

_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "domain",
    "opt-barrier", "optimization-barrier", "rng-get-and-update-state",
}

_COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "all-to-all-start", "reduce-scatter-start",
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# "%name = TYPE opcode(" — TYPE may be a tuple "(f32[..]{1,0}, s32[])" and
# array types carry layout suffixes like {1,0}; non-greedy match up to the
# first " opcode(" token.
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _ARRAY_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _ARRAY_RE.search(shape_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


def _shape_bytes_capped(shape_str: str, cap: int = 2) -> int:
    """Byte size with per-element width capped (TPU-native bf16 operand
    reads: the CPU backend promotes bf16 dot operands to f32, which a TPU
    MXU would consume as bf16 directly)."""
    total = 0
    for dtype, dims in _ARRAY_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * min(_DTYPE_BYTES[dtype], cap)
    return total


_FREE_OPS = {"parameter", "constant", "convert", "bitcast", "copy",
             "reshape", "transpose", "tuple", "get-tuple-element"}


@dataclass
class Op:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    attrs: str
    raw_operands: str = ""

    @property
    def param_index(self) -> int:
        if self.opcode == "parameter":
            try:
                return int(self.raw_operands.strip())
            except ValueError:
                return -1
        return -1


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire: float = 0.0
    coll_payload: float = 0.0
    coll_counts: Dict[str, int] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.wire += o.wire
        self.coll_payload += o.coll_payload
        for k, v in o.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f, self.wire * f,
                    self.coll_payload * f,
                    {k: v * int(f) for k, v in self.coll_counts.items()})


class HloModule:
    def __init__(self, text: str, default_group: int = 1,
                 force_trip_one: bool = False):
        self.computations: Dict[str, List[Op]] = {}
        self.entry: Optional[str] = None
        self.default_group = default_group
        self.force_trip_one = force_trip_one
        self._parse(text)
        self._memo: Dict[Tuple[str, str], Cost] = {}

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            hdr = _COMP_HDR_RE.match(line)
            if hdr and line.endswith("{"):
                cur = hdr.group(2)
                self.computations[cur] = []
                if hdr.group(1):
                    self.entry = cur
                continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            d = _DEF_RE.match(line)
            if not d:
                continue
            name, shape, opcode = d.group(1), d.group(2), d.group(3)
            rest = line[d.end():]
            depth, i = 1, 0
            while i < len(rest) and depth:
                if rest[i] == "(":
                    depth += 1
                elif rest[i] == ")":
                    depth -= 1
                i += 1
            operand_str, attrs = rest[:i - 1], rest[i:]
            operands = _OPERANDS_RE.findall(operand_str)
            self.computations[cur].append(
                Op(name, shape, opcode, operands, attrs, operand_str))

    # -- helpers ------------------------------------------------------------

    def _sym(self, comp: str) -> Dict[str, str]:
        return {op.name: op.shape for op in self.computations.get(comp, [])}

    def _group_size(self, op: Op) -> int:
        m = _GROUPS_RE.search(op.attrs)
        if m:
            return max(2, len(m.group(1).split(",")))
        m = _GROUPS_IOTA_RE.search(op.attrs)
        if m:
            return max(2, int(m.group(2)))
        return max(2, self.default_group)

    def _dot_flops(self, op: Op, sym: Dict[str, str]) -> float:
        out = _shape_dims(op.shape)
        lhs_shape = sym.get(op.operands[0], "") if op.operands else ""
        lhs = _shape_dims(lhs_shape)
        m = _LHS_CONTRACT_RE.search(op.attrs)
        contract = 1
        if m and lhs:
            for idx in m.group(1).split(","):
                if idx:
                    contract *= lhs[int(idx)]
        n_out = 1
        for d in out:
            n_out *= d
        return 2.0 * n_out * contract

    def _collective(self, op: Op, sym: Dict[str, str]) -> Cost:
        base = op.opcode.replace("-start", "")
        out_b = _shape_bytes(op.shape)
        in_b = sum(_shape_bytes(sym.get(o, "")) for o in op.operands)
        n = self._group_size(op)
        ring = (n - 1) / n
        size = max(out_b, in_b)
        if base == "all-reduce":
            wire = 2.0 * size * ring
        elif base == "collective-permute":
            wire = float(out_b)
        elif base == "all-gather":
            wire = out_b * ring
        elif base == "reduce-scatter":
            wire = in_b * ring
        else:  # all-to-all
            wire = size * ring
        return Cost(flops=0.0, bytes=in_b + out_b, wire=wire,
                    coll_payload=size, coll_counts={base: 1})

    def _op_bytes(self, op: Op, sym: Dict[str, str]) -> float:
        """HBM bytes for one op, utilization-aware for slicing ops.

        dynamic-slice / gather read only the addressed region;
        dynamic-update-slice / scatter are in-place (read+write the update
        region only). Without this, scan xs/ys slicing of stacked per-layer
        params/caches would be charged the full stacked buffer per iteration
        (a ~num_layers x inflation).
        """
        out_b = _shape_bytes(op.shape)
        oc = op.opcode
        if oc == "convert":
            return 0.0  # CPU-backend dtype normalization; free on TPU
        if oc in ("dynamic-slice", "gather"):
            return 2.0 * out_b
        if oc == "dynamic-update-slice":
            upd = _shape_bytes(sym.get(op.operands[1], "")) if \
                len(op.operands) > 1 else out_b
            return 2.0 * upd
        if oc == "scatter":
            # aliased in-place update: read+write the update region only
            upd = _shape_bytes(sym.get(op.operands[2], "")) if \
                len(op.operands) > 2 else out_b
            return 2.0 * upd
        if oc == "dot":
            # operand reads at TPU-native width (bf16), f32 accumulate out
            in_b = sum(_shape_bytes_capped(sym.get(o, ""))
                       for o in op.operands)
            return in_b + out_b
        if oc == "fusion":
            m = _CALLS_RE.search(op.attrs)
            if m:
                return self._fusion_bytes(op, sym, m.group(1))
        in_b = sum(_shape_bytes(sym.get(o, "")) for o in op.operands)
        return in_b + out_b

    def _fusion_bytes(self, op: Op, sym: Dict[str, str], comp: str) -> float:
        """Utilization-aware fusion traffic.

        * A fusion operand consumed ONLY through dynamic-slice ops is charged
          the slice sizes, not the full buffer (scan xs of stacked params).
        * A root dynamic-update-slice aliases its target buffer: charge the
          update region, not a full rewrite (scan ys / in-place cache write).
        """
        ops = self.computations.get(comp, [])
        sub_sym = self._sym(comp)
        out_b = _shape_bytes(op.shape)
        # convert-only fusion (dtype-normalization plumbing): free on TPU
        if ops and all(o.opcode in _FREE_OPS for o in ops):
            return 0.0
        root = ops[-1] if ops else None
        # walk through trailing converts/bitcasts to the real root
        seen = {o.name: o for o in ops}
        r = root
        while r is not None and r.opcode in ("convert", "bitcast", "copy") \
                and r.operands and r.operands[0] in seen:
            r = seen[r.operands[0]]
        dus_target = None
        if r is not None and r.opcode in ("dynamic-update-slice", "scatter"):
            # in-place root: charge the update region, alias the target
            upd_idx = 1 if r.opcode == "dynamic-update-slice" else 2
            upd = r.operands[upd_idx] if len(r.operands) > upd_idx else None
            out_b = 2.0 * _shape_bytes_capped(sub_sym.get(upd, "")) \
                if upd else out_b
            # identify which fusion param feeds the target (aliased)
            t = r.operands[0]
            while t in seen and seen[t].opcode in ("convert", "bitcast",
                                                   "copy") and seen[t].operands:
                t = seen[t].operands[0]
            dus_target = t
        params_unordered = [o for o in ops if o.opcode == "parameter"]
        by_idx = {p.param_index: p for p in params_unordered}
        params = [by_idx.get(i) for i in range(len(op.operands))]
        uses: Dict[str, List[str]] = {p.name: [] for p in params_unordered}
        for o2 in ops:
            for src in o2.operands:
                if src in uses:
                    uses[src].append(o2.opcode)
        # bf16-output fusions: f32 intermediates are CPU-backend dtype
        # plumbing; charge sliced reads at native (bf16) width. f32-output
        # fusions (e.g. optimizer-state updates) keep full f32 widths.
        out_native_2b = op.shape.strip().startswith(("bf16", "f16"))
        size_fn = _shape_bytes_capped if out_native_2b else _shape_bytes

        def chase(name):
            """Follow convert/bitcast chains to the op consuming `name`."""
            consumers = []
            for o2 in ops:
                if name in o2.operands:
                    if o2.opcode in ("convert", "bitcast"):
                        consumers.extend(chase(o2.name))
                    else:
                        consumers.append(o2)
            return consumers

        in_b = 0.0
        for i, operand in enumerate(op.operands):
            pname = params[i].name if i < len(params) and params[i] else None
            if pname is not None and pname == dus_target:
                continue  # aliased in-place target
            if pname is not None:
                cons = chase(pname)
                if cons and all(c.opcode == "dynamic-slice" for c in cons):
                    for c in cons:
                        in_b += size_fn(c.shape)
                    continue
            in_b += size_fn(sym.get(operand, ""))
        return in_b + out_b

    def _root_op(self, comp: str) -> Optional[Op]:
        ops = self.computations.get(comp, [])
        return ops[-1] if ops else None

    def _fusion_flops(self, comp: str) -> float:
        """Dot flops inside a fused computation (bytes counted at call site)."""
        total = 0.0
        sym = self._sym(comp)
        for op in self.computations.get(comp, []):
            if op.opcode == "dot":
                total += self._dot_flops(op, sym)
            elif op.opcode == "fusion":
                m = _CALLS_RE.search(op.attrs)
                if m:
                    total += self._fusion_flops(m.group(1))
        return total

    # -- main walk ----------------------------------------------------------

    def comp_cost(self, comp: str) -> Cost:
        if ("cost", comp) in self._memo:
            return self._memo[("cost", comp)]
        total = Cost()
        sym = self._sym(comp)
        for op in self.computations.get(comp, []):
            oc = op.opcode
            if oc in _SKIP_OPS:
                continue
            if oc == "while":
                trip = 1
                m = _TRIP_RE.search(op.attrs)
                if m and not self.force_trip_one:
                    trip = int(m.group(1))
                body = _BODY_RE.search(op.attrs)
                cond = _COND_RE.search(op.attrs)
                if body:
                    total += self.comp_cost(body.group(1)).scaled(trip)
                if cond:
                    total += self.comp_cost(cond.group(1)).scaled(trip)
                continue
            if oc == "conditional":
                m = _BRANCHES_RE.search(op.attrs)
                if m:
                    branches = _OPERANDS_RE.findall(m.group(1))
                    costs = [self.comp_cost(b) for b in branches]
                    if costs:
                        total += max(costs, key=lambda c: c.flops + c.bytes)
                continue
            if oc == "call":
                m = _TO_APPLY_RE.search(op.attrs)
                if m:
                    total += self.comp_cost(m.group(1))
                continue
            if oc in _COLLECTIVE_OPS:
                total += self._collective(op, sym)
                continue
            if oc.endswith("-done") or oc.endswith("-update"):
                continue  # async pair tail: counted at -start
            byt = self._op_bytes(op, sym)
            if oc == "fusion":
                m = _CALLS_RE.search(op.attrs)
                fl = self._fusion_flops(m.group(1)) if m else 0.0
                total += Cost(flops=fl, bytes=byt)
                continue
            if oc == "dot":
                total += Cost(flops=self._dot_flops(op, sym), bytes=byt)
                continue
            # generic op (copy, broadcast, reduce, dynamic-slice, ...)
            total += Cost(bytes=byt)
        self._memo[("cost", comp)] = total
        return total

    def entry_cost(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze_text(text: str, default_group: int = 1) -> Cost:
    return HloModule(text, default_group=default_group).entry_cost()


def analyze_calibrated(compiled, default_group: int = 1) -> Cost:
    """Trip-corrected cost; FLOPs calibrated against XLA's cost_analysis.

    Both XLA's cost_analysis() and our trip1 walk count loop bodies once on
    the identical module, so the ratio (xla / parsed_trip1) isolates any
    dot-counting delta; applying it to the trip-corrected walk yields
    XLA-methodology FLOPs at full trip counts. Bytes are NOT calibrated to
    XLA: the walker deliberately models TPU-native traffic (bf16 dot
    operands, in-place DUS/scatter, free converts), which the CPU-backend
    cost analysis does not.
    """
    text = compiled.as_text()
    full = HloModule(text, default_group=default_group).entry_cost()
    once = HloModule(text, default_group=default_group,
                     force_trip_one=True).entry_cost()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    f_ratio = (xla_flops / once.flops) if once.flops else 1.0
    # clamp: calibration should be a modest correction, never a rescale
    f_ratio = min(max(f_ratio, 0.25), 2.0)
    return Cost(flops=full.flops * f_ratio, bytes=full.bytes,
                wire=full.wire, coll_payload=full.coll_payload,
                coll_counts=full.coll_counts)
