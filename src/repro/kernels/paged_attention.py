"""Paged flash-decode Pallas kernel with fused int4 dequantization.

One query token per sequence attends over a KV cache that lives in
fixed-size PAGES (``page_size`` tokens each) shared by all sequences; each
sequence's pages are named by a per-slot page table. Two residencies:

* **int4** (the interesting one): pages store the SAME group-wise affine
  int4 encoding the prefill->decode wire uses (``kernels/kv_quant.py``),
  so a transferred ``KVWire`` is scattered straight into pages and the
  dequantization happens HERE, inside the attention inner loop — the
  cache is never materialized in 16-bit.
* **bf16**: pages store plain 16-bit values (ablation / fallback).

Page layout (int4), per layer: ``packed (P, page_size*ppr, g//2) u8`` with
``scale``/``zero (P, page_size*ppr, 1) f32``, where ``g`` is the wire's
position-aligned quantization group (g | Hkv*hd) and ``ppr = Hkv*hd // g``
groups per token. Row ``t*ppr + r`` holds token ``t``'s r-th group, so a
page row-range is exactly a token range — the same row order the wire's
flattened ``(L*len*ppr, g)`` quantization produces.

Grid = (B, n_pages_per_seq) with the page axis innermost; the page table
and per-sequence valid lengths arrive as scalar-prefetch operands, so each
step's BlockSpec index map gathers the right page from HBM and fully
masked pages are skipped. Online-softmax state sits in VMEM scratch
(same scheme as ``decode_attention.py``).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import kv_layout

NEG_INF = -1e30


def _dequant_rows(packed, scale, zero):
    """(R, G//2) u8 + (R, 1) scale/zero -> (R, G) f32. Nibble order comes
    from the shared layout contract (``kernels/kv_layout.py``, rule R005):
    element 2j in the low nibble of byte j, 2j+1 high."""
    return kv_layout.interleave_nibbles(packed) * scale + zero


def _accumulate(q, k, v, *, start, kv_len, sm_scale, m_scr, l_scr, acc_scr):
    """One page of online softmax. q (Hkv, gq, hd); k/v (ps, Hkv, hd)."""
    kT = k.transpose(1, 0, 2)                       # (Hkv, ps, hd)
    vT = v.transpose(1, 0, 2)
    s = jax.lax.dot_general(q, kT, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * sm_scale
    pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    s = jnp.where(pos < kv_len, s, NEG_INF)         # (Hkv, gq, ps)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
        p, vT, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new


def _finalize(o_ref, m_scr, l_scr, acc_scr):
    o_ref[0] = (acc_scr[...]
                / jnp.maximum(l_scr[...], 1e-30)[..., None]).astype(
                    o_ref.dtype)


def _kernel_int4(pt_ref, len_ref, q_ref, kp_ref, ks_ref, kz_ref,
                 vp_ref, vs_ref, vz_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 sm_scale, page_size, n_pages, Hkv, hd):
    b = pl.program_id(0)
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[b]
    start = pi * page_size

    @pl.when(start < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        # dequant fused here: rows are token-major, so (ps*ppr, g) -> each
        # token's flattened Hkv*hd slice -> (ps, Hkv, hd)
        k = _dequant_rows(kp_ref[0], ks_ref[0], kz_ref[0]).reshape(
            page_size, Hkv, hd)
        v = _dequant_rows(vp_ref[0], vs_ref[0], vz_ref[0]).reshape(
            page_size, Hkv, hd)
        _accumulate(q, k, v, start=start, kv_len=kv_len, sm_scale=sm_scale,
                    m_scr=m_scr, l_scr=l_scr, acc_scr=acc_scr)

    @pl.when(pi == n_pages - 1)
    def _done():
        _finalize(o_ref, m_scr, l_scr, acc_scr)


def _kernel_bf16(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                 m_scr, l_scr, acc_scr, *, sm_scale, page_size, n_pages):
    b = pl.program_id(0)
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[b]
    start = pi * page_size

    @pl.when(start < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)            # (ps, Hkv, hd)
        v = v_ref[0].astype(jnp.float32)
        _accumulate(q, k, v, start=start, kv_len=kv_len, sm_scale=sm_scale,
                    m_scr=m_scr, l_scr=l_scr, acc_scr=acc_scr)

    @pl.when(pi == n_pages - 1)
    def _done():
        _finalize(o_ref, m_scr, l_scr, acc_scr)


def paged_decode_attention(q, k_pages, v_pages, page_table, kv_len, *,
                           page_size, sm_scale=None, interpret=False):
    """q: (B, Hkv, gq, hd); page_table: (B, W) int32 (entry 0 = the trash
    page, see ``serving/page_pool.py``); kv_len: (B,) valid lengths.

    ``k_pages``/``v_pages`` are either the int4 triple
    ``(packed (P, page_size*ppr, g//2), scale (P, ..., 1), zero)`` or a
    dense ``(P, page_size, Hkv, hd)`` array. Returns (B, Hkv, gq, hd).
    """
    B, Hkv, gq, hd = q.shape
    W = page_table.shape[1]
    sm_scale = sm_scale or 1.0 / math.sqrt(hd)
    quantized = isinstance(k_pages, (tuple, list))

    def page_ix(b, pi, pt, ln):
        # unallocated table entries are 0 (trash page); clamp defensively
        return (jnp.maximum(pt[b, pi], 0), 0, 0)

    q_spec = pl.BlockSpec((1, Hkv, gq, hd), lambda b, pi, *_: (b, 0, 0, 0))
    out_spec = pl.BlockSpec((1, Hkv, gq, hd), lambda b, pi, *_: (b, 0, 0, 0))
    scratch = [pltpu.VMEM((Hkv, gq), jnp.float32),
               pltpu.VMEM((Hkv, gq), jnp.float32),
               pltpu.VMEM((Hkv, gq, hd), jnp.float32)]

    if quantized:
        kp, ks, kz = k_pages
        vp, vs, vz = v_pages
        R, G2 = kp.shape[1], kp.shape[2]
        kernel = functools.partial(_kernel_int4, sm_scale=sm_scale,
                                   page_size=page_size, n_pages=W,
                                   Hkv=Hkv, hd=hd)
        pk_spec = pl.BlockSpec((1, R, G2), page_ix)
        sc_spec = pl.BlockSpec((1, R, 1), page_ix)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, W),
            in_specs=[q_spec, pk_spec, sc_spec, sc_spec,
                      pk_spec, sc_spec, sc_spec],
            out_specs=out_spec,
            scratch_shapes=scratch,
        )
        return pl.pallas_call(
            kernel, grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((B, Hkv, gq, hd), q.dtype),
            interpret=interpret,
        )(page_table.astype(jnp.int32), kv_len.astype(jnp.int32),
          q, kp, ks, kz, vp, vs, vz)

    kernel = functools.partial(_kernel_bf16, sm_scale=sm_scale,
                               page_size=page_size, n_pages=W)
    kd_spec = pl.BlockSpec((1, page_size, Hkv, hd),
                           lambda b, pi, pt, ln: (jnp.maximum(pt[b, pi], 0),
                                                  0, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, W),
        in_specs=[q_spec, kd_spec, kd_spec],
        out_specs=out_spec,
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, gq, hd), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), kv_len.astype(jnp.int32),
      q, k_pages, v_pages)
