"""THE wire/page quantization-layout contract (single source of truth).

Three subsystems must agree bit-for-bit on how KV tensors are laid out in
their int4 form, or zero-copy page insertion silently degrades to a
re-encode (and a drifted nibble order corrupts tokens outright):

* the prefill->decode wire (``serving/kv_transfer.py``),
* the paged decode cache format (``models/paged.py``),
* the pack/unpack kernels (``kernels/kv_quant.py``, ``kernels/ref.py``,
  ``kernels/paged_attention.py``).

Historically each carried its own copy of the group-candidate tuple and
the nibble pack/unpack expressions — exactly the drift the linter's R005
rule (``repro.analysis``) now forbids. Everything layout-bearing lives
HERE and only here:

* :data:`GROUPS` — candidate quantization group widths;
* :func:`pick_group` — the ONE group-selection rule (largest candidate
  dividing the span; groups never straddle token positions when the span
  is ``Hkv * hd``);
* :func:`pack_nibbles` / :func:`unpack_nibbles` — the low-nibble-first
  two-per-byte int4 packing (element ``2j`` in the low nibble of byte
  ``j``, ``2j+1`` in the high nibble).

This module is dependency-light (jnp only) so both ``serving/`` and
``models/`` can import it without cycles, and the helpers are plain jnp
expressions so Pallas kernel bodies can call them while tracing.
"""
from __future__ import annotations

import jax.numpy as jnp

# Candidate quantization group widths (lane dim of the pallas kernel).
# 128-wide groups keep the scale/zero overhead at ~3% even for small
# head_dims; fall back to smaller even groups, then raw (0).
GROUPS = (128, 64, 32, 16, 8, 4, 2)


def pick_group(span: int) -> int:
    """Largest candidate group width dividing ``span`` (0 = none: raw).

    Used by the wire's padded-extract path with ``span = Hkv * hd`` (so
    groups are position-aligned) and by the page format with the same
    span — both MUST go through this function (lint rule R005)."""
    return next((g for g in GROUPS if span % g == 0), 0)


def pack_nibbles(even, odd):
    """Pack two int4 planes into one uint8 plane, LOW NIBBLE FIRST:
    ``even`` lands in bits 0-3, ``odd`` in bits 4-7."""
    return (even | (odd << 4)).astype(jnp.uint8)


def unpack_nibbles(packed):
    """Inverse of :func:`pack_nibbles`: uint8 plane -> (low, high) f32
    planes. Callers interleave with :func:`interleave_nibbles` (or keep
    the planes separate when the layout wants them that way)."""
    lo = (packed & 0xF).astype(jnp.float32)
    hi = (packed >> 4).astype(jnp.float32)
    return lo, hi


def interleave_nibbles(packed):
    """uint8 ``(..., G//2)`` -> f32 ``(..., G)`` restoring the original
    element order (even indices from low nibbles, odd from high)."""
    lo, hi = unpack_nibbles(packed)
    return jnp.stack([lo, hi], axis=-1).reshape(
        packed.shape[:-1] + (packed.shape[-1] * 2,))
