"""Blocked FlashAttention forward for TPU (Pallas), GQA + causal + SWA.

Tiling: grid = (B*Hq, nQ, nK) with the KV axis innermost — TPU grids execute
sequentially, so the (m, l, acc) online-softmax state lives in VMEM scratch
across the nK steps of one (bh, qi) cell. Block shapes are MXU-aligned
(block_q x head_dim and block_k x head_dim tiles; head_dim is the lane dim).

GQA is expressed in the k/v BlockSpec index maps (q head -> kv head =
q_head // group), so no KV replication ever materializes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale, block_q, block_k, n_k, causal, window):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # skip fully-masked blocks (causal: k block entirely above the diagonal;
    # SWA: k block entirely left of the window)
    q_end = q_start + block_q - 1
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_end
    if window:
        run &= (k_start + block_k - 1) > (q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok &= kpos <= qpos
        if window:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, sm_scale=None,
                    block_q=128, block_k=128, interpret=False):
    """q: (BH, Sq, hd); k/v: (BHkv, Sk, hd). Returns (BH, Sq, hd)."""
    BH, Sq, hd = q.shape
    BHkv, Sk, _ = k.shape
    assert BH % BHkv == 0
    group = BH // BHkv
    sm_scale = sm_scale or 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    n_q, n_k = Sq // block_q, Sk // block_k

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, block_q=block_q, block_k=block_k,
        n_k=n_k, causal=causal, window=window)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
