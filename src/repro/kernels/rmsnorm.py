"""Fused RMSNorm Pallas kernel (single pass: square-mean, rsqrt, scale)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)               # (bn, d)
    r = jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    o_ref[...] = (x * r * (1.0 + s_ref[...].astype(jnp.float32))
                  ).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps=1e-6, block_n=256, interpret=False):
    """x: (N, d); scale: (d,)."""
    N, d = x.shape
    block_n = min(block_n, N)
    assert N % block_n == 0, (N, block_n)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(N // block_n,),
        in_specs=[pl.BlockSpec((block_n, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=interpret,
    )(x, scale)
