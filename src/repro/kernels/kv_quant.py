"""int4 group-wise KV-cache quantization kernels (the paper's wire format).

ThunderServe quantizes KV tensors to 4 bits ONLY for the prefill->decode
transfer, then dequantizes on arrival; compute stays 16-bit (paper §4).
These kernels implement the pack/unpack hot loop: per-group (last axis)
min/max affine quantization, two nibbles packed per uint8.

Tiling: rows are blocked (block_n x G tiles in VMEM); G (the quantization
group, e.g. head_dim) sits in the lane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# nibble order is THE layout contract shared with the wire and the paged
# cache — it lives in kv_layout (lint rule R005), and the helpers are
# plain jnp expressions so they trace inside the kernel bodies
from repro.kernels.kv_layout import interleave_nibbles, pack_nibbles


def _quant_kernel(x_ref, q_ref, scale_ref, zero_ref):
    x = x_ref[...].astype(jnp.float32)               # (bn, G)
    mn = x.min(axis=-1, keepdims=True)
    mx = x.max(axis=-1, keepdims=True)
    scale = jnp.maximum(mx - mn, 1e-8) / 15.0
    q = jnp.clip(jnp.round((x - mn) / scale), 0, 15).astype(jnp.uint8)
    bn, G = q.shape
    q2 = q.reshape(bn, G // 2, 2)
    q_ref[...] = pack_nibbles(q2[..., 0], q2[..., 1])
    scale_ref[...] = scale
    zero_ref[...] = mn


def _dequant_kernel(q_ref, scale_ref, zero_ref, x_ref, *, out_dtype):
    q = interleave_nibbles(q_ref[...])               # (bn, Gh*2) f32
    x_ref[...] = (q * scale_ref[...] + zero_ref[...]).astype(out_dtype)


def kv_quant(x, *, block_n=256, interpret=False):
    """x: (N, G) -> (packed (N, G//2) u8, scale (N,1) f32, zero (N,1) f32).

    N need not divide block_n: the grid is ceil-divided and the ragged tail
    block is padded on load / clipped on store by pallas — safe here because
    the min/max reduction is per-row (padding rows never leak into real
    rows). This keeps the batched wire path on one well-tiled launch
    instead of degenerating to tiny blocks for awkward row counts."""
    N, G = x.shape
    assert G % 2 == 0
    block_n = min(block_n, N)
    return pl.pallas_call(
        _quant_kernel,
        grid=(pl.cdiv(N, block_n),),
        in_specs=[pl.BlockSpec((block_n, G), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_n, G // 2), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, G // 2), jnp.uint8),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def kv_dequant(packed, scale, zero, *, out_dtype=jnp.bfloat16, block_n=256,
               interpret=False):
    """Inverse of kv_quant. Returns (N, G) in out_dtype. Ragged N is
    handled the same way as in ``kv_quant`` (per-row kernel)."""
    N, Gh = packed.shape
    block_n = min(block_n, N)
    kernel = functools.partial(_dequant_kernel, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(N, block_n),),
        in_specs=[
            pl.BlockSpec((block_n, Gh), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, Gh * 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Gh * 2), out_dtype),
        interpret=interpret,
    )(packed, scale, zero)
