"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.kv_layout import interleave_nibbles, pack_nibbles


def flash_attention_ref(q, k, v, *, causal=True, window=0, sm_scale=None):
    """q: (BH, Sq, hd); k/v: (BHkv, Sk, hd) with BH = BHkv * group."""
    BH, Sq, hd = q.shape
    BHkv, Sk, _ = k.shape
    g = BH // BHkv
    sm_scale = sm_scale or 1.0 / math.sqrt(hd)
    qh = q.reshape(BHkv, g, Sq, hd).astype(jnp.float32)
    s = jnp.einsum("bgqd,bkd->bgqk", qh, k.astype(jnp.float32)) * sm_scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqk,bkd->bgqd", w, v.astype(jnp.float32))
    return o.reshape(BH, Sq, hd).astype(q.dtype)


def decode_attention_ref(q, k, v, *, kv_len=None, sm_scale=None):
    """q: (B, Hkv, g, hd); k/v: (B, Hkv, S, hd); kv_len: (B,) valid lengths."""
    B, Hkv, g, hd = q.shape
    S = k.shape[2]
    sm_scale = sm_scale or 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhgd,bhkd->bhgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if kv_len is not None:
        mask = jnp.arange(S)[None, None, None, :] < kv_len[:, None, None, None]
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", w, v.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_gather_ref(pages, page_table, *, page_size):
    """Materialize paged KV into a dense (B, W*page_size, Hkv, hd) f32
    tensor by gathering each sequence's pages through its page table.

    ``pages`` is either the int4 triple ``(packed (P, page_size*ppr, g//2),
    scale, zero)`` — dequantized here — or a dense (P, page_size, Hkv, hd)
    array. The oracle for the fused-dequant Pallas kernel."""
    pt = jnp.maximum(page_table, 0)               # 0 = trash page
    if isinstance(pages, (tuple, list)):
        packed, scale, zero = pages
        g = packed.shape[-1] * 2
        x = kv_dequant_ref(packed[pt], scale[pt], zero[pt], dtype=jnp.float32)
        B, W, R = x.shape[:3]
        ps = page_size
        ppr = R // ps
        return x.reshape(B, W * ps, ppr * g)      # (B, S, Hkv*hd) flat
    gathered = pages[pt]                          # (B, W, ps, Hkv, hd)
    B, W, ps, Hkv, hd = gathered.shape
    return gathered.astype(jnp.float32).reshape(B, W * ps, Hkv * hd)


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, kv_len, *,
                               page_size, sm_scale=None):
    """q: (B, Hkv, gq, hd); paged K/V as in ``paged_gather_ref``.

    Gathers + dequantizes to dense, then runs the dense decode oracle."""
    B, Hkv, gq, hd = q.shape
    k = paged_gather_ref(k_pages, page_table, page_size=page_size)
    v = paged_gather_ref(v_pages, page_table, page_size=page_size)
    S = k.shape[1]
    k = k.reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
    return decode_attention_ref(q, k, v, kv_len=kv_len, sm_scale=sm_scale)


def kv_quant_ref(x):
    """Group-wise int4 quantization over the last axis. x: (N, G).

    Returns (packed (N, G//2) uint8, scale (N,1) f32, zero (N,1) f32)."""
    xf = x.astype(jnp.float32)
    mn = xf.min(axis=-1, keepdims=True)
    mx = xf.max(axis=-1, keepdims=True)
    scale = jnp.maximum(mx - mn, 1e-8) / 15.0
    q = jnp.clip(jnp.round((xf - mn) / scale), 0, 15).astype(jnp.uint8)
    packed = pack_nibbles(q[..., 0::2], q[..., 1::2])
    return packed, scale, mn


def kv_dequant_ref(packed, scale, zero, dtype=jnp.bfloat16):
    q = interleave_nibbles(packed)
    return (q * scale + zero).astype(dtype)


def rmsnorm_ref(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
    return (xf * r * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
