"""jit'd public wrappers for the Pallas kernels with backend dispatch.

``backend="auto"`` picks the Pallas TPU kernel on TPU, the interpreted
kernel under tests that request it, and the pure-jnp reference otherwise
(CPU dry-run lowers the jnp path so rooflines reflect XLA:TPU-able HLO, not
an interpreter artifact).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import kv_quant as _kq
from repro.kernels import paged_attention as _pa
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rn


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def _mode(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "ref"
    return backend


@functools.partial(jax.jit, static_argnames=("causal", "window", "backend",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, backend="auto",
                    block_q=128, block_k=128):
    m = _mode(backend)
    if m == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=(m == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend", "block_k"))
def decode_attention(q, k, v, kv_len, *, backend="auto", block_k=256):
    m = _mode(backend)
    if m == "ref":
        return _ref.decode_attention_ref(q, k, v, kv_len=kv_len)
    return _dec.decode_attention(q, k, v, kv_len, block_k=block_k,
                                 interpret=(m == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend", "page_size"))
def paged_decode_attention(q, k_pages, v_pages, page_table, kv_len, *,
                           page_size, backend="auto"):
    """Decode attention over a page-table-indirected KV cache; the int4
    residency dequantizes INSIDE the kernel (never materializing a 16-bit
    cache). ``k_pages``/``v_pages``: (packed, scale, zero) triple or a
    dense (P, page_size, Hkv, hd) array."""
    m = _mode(backend)
    if m == "ref":
        return _ref.paged_decode_attention_ref(q, k_pages, v_pages,
                                               page_table, kv_len,
                                               page_size=page_size)
    return _pa.paged_decode_attention(q, k_pages, v_pages, page_table,
                                      kv_len, page_size=page_size,
                                      interpret=(m == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend", "block_n"))
def kv_quant(x, *, backend="auto", block_n=256):
    m = _mode(backend)
    if m == "ref":
        return _ref.kv_quant_ref(x)
    return _kq.kv_quant(x, block_n=block_n, interpret=(m == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend", "block_n", "out_dtype"))
def kv_dequant(packed, scale, zero, *, out_dtype=jnp.bfloat16, backend="auto",
               block_n=256):
    m = _mode(backend)
    if m == "ref":
        return _ref.kv_dequant_ref(packed, scale, zero, dtype=out_dtype)
    return _kq.kv_dequant(packed, scale, zero, out_dtype=out_dtype,
                          block_n=block_n, interpret=(m == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend", "block_n", "eps"))
def rmsnorm(x, scale, *, eps=1e-6, backend="auto", block_n=256):
    m = _mode(backend)
    if m == "ref":
        return _ref.rmsnorm_ref(x, scale, eps=eps)
    return _rn.rmsnorm(x, scale, eps=eps, block_n=block_n,
                       interpret=(m == "interpret"))
