"""Flash-decode Pallas kernel: one query token per sequence against a long
KV cache, GQA-aware.

The g = Hq/Hkv query heads that share a KV head form the matmul's row block
(g x hd @ hd x block_k), so the MXU tile is dense even at decode. Grid =
(B, Hkv, nK) with the KV axis innermost; online-softmax state in VMEM
scratch. Per-sequence valid lengths arrive as a scalar-prefetch operand so
masked KV blocks are skipped entirely (ragged continuous batching).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, sm_scale, block_k, n_k):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[b]
    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (g, hd)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)        # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_attention(q, k, v, kv_len, *, sm_scale=None, block_k=256,
                     interpret=False):
    """q: (B, Hkv, g, hd); k/v: (B, Hkv, S, hd); kv_len: (B,) int32.

    Returns (B, Hkv, g, hd).
    """
    B, Hkv, g, hd = q.shape
    S = k.shape[2]
    sm_scale = sm_scale or 1.0 / math.sqrt(hd)
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    n_k = S // block_k

    kernel = functools.partial(_decode_kernel, sm_scale=sm_scale,
                               block_k=block_k, n_k=n_k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b, h, ki, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, ki, *_: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, ki, *_: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b, h, ki, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, hd), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q, k, v)
