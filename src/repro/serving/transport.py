"""Pluggable KV transport between prefill and decode replicas.

The gateway never moves a :class:`~repro.serving.kv_transfer.KVWire`
directly: every prefill -> decode handoff goes through a ``Transport``,
which decides what the hop costs and whether the payload leaves the
device. This is the seam where a real network stack (RDMA, gRPC,
DCN collectives) slots in later without touching routing, heartbeats,
or rescheduling logic.

Two in-process realizations ship today:

* :class:`InProcessTransport` — device arrays flow straight through;
  zero delay, no host synchronization (optionally ``materialize=True``
  to force the explicit host hop).
* :class:`SimNetworkTransport` — alpha-beta cost per link
  (``delay = alpha + bytes / bandwidth``) drawn from a
  :class:`~repro.core.cluster.ClusterSpec` bandwidth matrix (or given
  explicitly), with the explicit ``KVWire.materialize()`` host hop a
  real network transfer would pay. Wires become *visible* at the
  receiver only once the wall clock passes the ticket's ``t_ready``,
  so open-loop drivers observe genuinely delayed TTFT.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import (Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple)

from repro.serving.kv_transfer import KVWire, wire_bytes_uncompressed


@dataclass
class TransferTicket:
    """One in-flight prefill->decode KV transfer. ``clock`` is the issuing
    transport's clock (a REFERENCE to ``time.time`` by default, so ticket
    readiness follows a VirtualClock when one is injected — rule R001)."""
    wire: KVWire
    t_ready: float          # clock time the wire is usable downstream
    delay_s: float = 0.0
    nbytes: int = 0
    clock: Callable[[], float] = time.time

    def ready(self, now: Optional[float] = None) -> bool:
        return (now if now is not None else self.clock()) >= self.t_ready


class Transport(Protocol):
    """Narrow seam the gateway uses to ship KV state between replicas.

    ``send`` is called once per wire with the *replica* indices chosen by
    the TSTP routing; it returns a ticket whose ``t_ready`` gates decode
    admission. Implementations decide whether the payload stays a device
    array (in-process) or crosses a host/network boundary.
    """

    def send(self, wire: KVWire, src_replica: int, dst_replica: int,
             *, now: Optional[float] = None) -> TransferTicket:
        ...


class InProcessTransport:
    """Same-process handoff: the decode side consumes device arrays
    directly and the hop is free. ``materialize=True`` forces the single
    explicit device->host sync (models collocated processes that still
    serialize)."""

    def __init__(self, *, materialize: bool = False,
                 clock: Optional[Callable[[], float]] = None):
        self.materialize = materialize
        self.clock = clock if clock is not None else time.time
        self.transfers = 0

    def send(self, wire: KVWire, src_replica: int, dst_replica: int,
             *, now: Optional[float] = None) -> TransferTicket:
        if self.materialize:
            wire.materialize()
        self.transfers += 1
        return TransferTicket(wire, now if now is not None else self.clock(),
                              clock=self.clock)

    def send_decode(self, wire: KVWire, src_dec: int, dst_dec: int,
                    *, now: Optional[float] = None) -> TransferTicket:
        """Decode->decode KV migration hop (preemption drains): free."""
        return self.send(wire, src_dec, dst_dec, now=now)


class SimNetworkTransport:
    """Alpha-beta cost model per (prefill replica, decode replica) link.

    Link parameters come from a :class:`ClusterSpec` plus the device
    groups of each replica (``min_bw_between`` of the two groups — the
    bottleneck link a sliced KV transfer actually crosses), or from
    explicit ``alpha``/``bandwidth`` overrides when no cluster is given.

    ``bytes_scale`` lets a reduced-config engine pay the FULL model's
    wire cost (the same full-model/reduced-compute split the launchers
    use for scheduling); ``count_compressed=False`` charges the
    uncompressed KV size instead of the int4 wire size.
    """

    def __init__(self, cluster=None, *,
                 prefill_devices: Optional[Sequence[Sequence[int]]] = None,
                 decode_devices: Optional[Sequence[Sequence[int]]] = None,
                 alpha: Optional[float] = None,
                 bandwidth: Optional[float] = None,
                 bytes_scale: float = 1.0,
                 count_compressed: bool = True,
                 clock: Optional[Callable[[], float]] = None):
        if cluster is None and bandwidth is None:
            raise ValueError("SimNetworkTransport needs a ClusterSpec or an "
                             "explicit bandwidth")
        self.cluster = cluster
        self.pre_devices = [list(g) for g in (prefill_devices or [])]
        self.dec_devices = [list(g) for g in (decode_devices or [])]
        self.alpha = alpha
        self.bandwidth = bandwidth
        self.bytes_scale = bytes_scale
        self.count_compressed = count_compressed
        self.clock = clock if clock is not None else time.time
        # accounting (benchmarks read these; min_delay_s is the gateway's
        # lower bound for deadline shedding)
        self.transfers = 0
        self.bytes_sent = 0
        self.total_delay_s = 0.0
        self.min_delay_s = 0.0
        self._links: Dict[Tuple[str, int, int], Tuple[float, float]] = {}

    @classmethod
    def from_plan(cls, cluster, plan, **kw) -> "SimNetworkTransport":
        """Wire the link table from a DeploymentPlan's replica->device map."""
        return cls(cluster,
                   prefill_devices=[r.devices for r in plan.prefill_replicas],
                   decode_devices=[r.devices for r in plan.decode_replicas],
                   **kw)

    def rebind_plan(self, plan) -> None:
        """Rebuild the replica->device link table for a new plan epoch:
        after a live re-designation the (prefill i, decode j) indices name
        different device groups, so every cached alpha-beta link is stale.
        Accounting (transfers/bytes/min_delay) is cumulative across epochs
        and intentionally survives."""
        self.pre_devices = [list(r.devices) for r in plan.prefill_replicas]
        self.dec_devices = [list(r.devices) for r in plan.decode_replicas]
        self._links.clear()

    def _link_between(self, key: Tuple[str, int, int],
                      src_group: Optional[Sequence[int]],
                      dst_group: Optional[Sequence[int]]) -> Tuple[float, float]:
        """(alpha_s, bandwidth_Bps) between two device groups, cached."""
        if key in self._links:
            return self._links[key]
        alpha = self.alpha if self.alpha is not None else (
            self.cluster.alpha if self.cluster is not None else 0.0)
        bw = self.bandwidth
        if (bw is None and self.cluster is not None
                and src_group is not None and dst_group is not None):
            bw = self.cluster.min_bw_between(src_group, dst_group)
        if bw is None and self.cluster is not None:
            bw = float(self.cluster.bw[self.cluster.bw > 0].min())
        self._links[key] = (alpha, float(bw))
        return self._links[key]

    def link(self, src_replica: int, dst_replica: int) -> Tuple[float, float]:
        """(alpha_s, bandwidth_Bps) for one prefill->decode link."""
        src = (self.pre_devices[src_replica]
               if src_replica < len(self.pre_devices) else None)
        dst = (self.dec_devices[dst_replica]
               if dst_replica < len(self.dec_devices) else None)
        return self._link_between(("pd", src_replica, dst_replica), src, dst)

    def link_decode(self, src_dec: int, dst_dec: int) -> Tuple[float, float]:
        """(alpha_s, bandwidth_Bps) for a decode->decode migration link
        (used by ``Gateway.handle_preemption`` to drain KV pages off a
        preempted replica)."""
        src = (self.dec_devices[src_dec]
               if src_dec < len(self.dec_devices) else None)
        dst = (self.dec_devices[dst_dec]
               if dst_dec < len(self.dec_devices) else None)
        return self._link_between(("dd", src_dec, dst_dec), src, dst)

    def _ship(self, wire: KVWire, alpha: float, bw: float,
              now: Optional[float]) -> TransferTicket:
        now = now if now is not None else self.clock()
        wire.materialize()          # the explicit host hop of a real network
        nbytes = (wire.nbytes() if self.count_compressed
                  else wire_bytes_uncompressed(wire))
        nbytes = int(nbytes * self.bytes_scale)
        delay = alpha + nbytes / max(bw, 1.0)
        self.transfers += 1
        self.bytes_sent += nbytes
        self.total_delay_s += delay
        self.min_delay_s = (delay if self.transfers == 1
                            else min(self.min_delay_s, delay))
        return TransferTicket(wire, now + delay, delay, nbytes,
                              clock=self.clock)

    def send(self, wire: KVWire, src_replica: int, dst_replica: int,
             *, now: Optional[float] = None) -> TransferTicket:
        alpha, bw = self.link(src_replica, dst_replica)
        return self._ship(wire, alpha, bw, now)

    def send_decode(self, wire: KVWire, src_dec: int, dst_dec: int,
                    *, now: Optional[float] = None) -> TransferTicket:
        """Decode->decode KV migration hop (preemption drains)."""
        alpha, bw = self.link_decode(src_dec, dst_dec)
        return self._ship(wire, alpha, bw, now)

    @property
    def mean_delay_s(self) -> float:
        return self.total_delay_s / max(self.transfers, 1)
