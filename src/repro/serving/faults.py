"""Deterministic fault injection for the serving stack (ROADMAP item 4).

The paper's robustness story (§3.4) is that node failures and spot
preemptions are handled by *lightweight reschedules* on a running
gateway. To prove that in CI we need faults that are (a) injected at
the same seams a real deployment fails at — the transport and the
replica clients — and (b) reproducible: a seeded schedule plus a
virtual clock, so a chaos run is a deterministic test, not a flake.

Pieces:

* :class:`VirtualClock` — injectable ``clock()`` callable (satellite of
  ISSUE 6): tests advance time explicitly instead of sleeping.
* :class:`RetryPolicy` — bounded exponential backoff with jitter for
  transient transport failures.
* :class:`FaultSchedule` — a seeded list of :class:`FaultEvent`
  (CRASH / TRANSIENT / STRAGGLER / PREEMPT) with window queries the
  chaos wrappers consult.
* :class:`ChaosTransport` / :class:`ChaosClient` — thin wrappers over
  the real transport / replica clients that raise or stall according
  to the schedule. Everything else forwards untouched, so the gateway
  code path under test is the production one.
* :class:`ChaosController` — fires control-plane events (crash
  confirmation, preemption notices) from the gateway's pump loop.
* :func:`install_chaos` — one-call wiring of all of the above onto a
  live :class:`~repro.serving.gateway.Gateway`.

This module must not import ``repro.serving.gateway`` (the gateway
imports us — for :class:`RetryPolicy` and the error types).
"""
from __future__ import annotations

import random as _random
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence


# --------------------------------------------------------------------------
# errors


class TransientTransportError(RuntimeError):
    """A KV transfer failed in a retryable way (flaky network window)."""


class ReplicaCrashError(RuntimeError):
    """A replica died mid-call; the request must be recovered elsewhere."""


# --------------------------------------------------------------------------
# virtual clock


class VirtualClock:
    """Deterministic time source. ``clock()`` returns the current virtual
    time; ``advance`` moves it forward. ``sleep`` is an alias for
    ``advance`` so gateway drain loops make progress without wall time."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t

    # gateway._sleep duck-types on this
    def sleep(self, dt: float) -> None:
        self.advance(dt)


# --------------------------------------------------------------------------
# retry policy


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with jitter.

    ``delay_s(attempt)`` for attempt 0..max_retries-1 is
    ``min(base * multiplier**attempt, max_s)`` scaled by a uniform
    jitter in ``[1-jitter, 1+jitter]``. After ``max_retries`` failed
    sends the gateway falls back to requeue-through-prefill.
    """
    max_retries: int = 4
    base_s: float = 0.02
    multiplier: float = 2.0
    jitter: float = 0.5
    max_s: float = 2.0

    def delay_s(self, attempt: int, rng: Optional[_random.Random] = None) -> float:
        d = min(self.base_s * (self.multiplier ** attempt), self.max_s)
        r = rng.random() if rng is not None else _random.random()
        return d * (1.0 - self.jitter + 2.0 * self.jitter * r)


# --------------------------------------------------------------------------
# fault schedule

CRASH = "crash"            # replica dies (raises ReplicaCrashError forever)
TRANSIENT = "transient"    # transport raises TransientTransportError in a window
STRAGGLER = "straggler"    # replica calls stall for slow_s within a window
PREEMPT = "preempt"        # spot preemption notice with a grace window


@dataclass
class FaultEvent:
    """One scheduled fault. ``t`` is seconds after ``FaultSchedule.arm``.

    ``idx = -1`` means "the busiest alive replica of ``phase`` at fire
    time" (resolved by the controller), so a schedule written before the
    trace runs still hits a replica that actually holds work.
    ``require_busy`` defers a CRASH/PREEMPT until the victim has resident
    requests (up to ~2 s past ``t``), making loss scenarios deterministic.
    """
    t: float
    kind: str
    phase: str = "decode"
    idx: int = 0
    duration_s: float = 0.25   # TRANSIENT / STRAGGLER window length
    grace_s: float = 1.0       # PREEMPT grace window
    slow_s: float = 0.05       # STRAGGLER per-call stall
    require_busy: bool = False
    fired: bool = False


class FaultSchedule:
    """A seeded, armed set of fault events.

    Window faults (TRANSIENT, STRAGGLER) are *queried* by the chaos
    wrappers on every call; point faults (CRASH, PREEMPT) are *fired*
    once by the :class:`ChaosController` from the pump loop.
    """

    def __init__(self, events: Sequence[FaultEvent], seed: int = 0):
        self.events: List[FaultEvent] = sorted(events, key=lambda e: e.t)
        self.seed = seed
        self.rng = _random.Random(seed)
        self.t0: Optional[float] = None
        self._crashed: set = set()

    def arm(self, t0: float) -> None:
        """Anchor event times to wall/virtual time ``t0``."""
        self.t0 = float(t0)

    def _rel(self, now: float) -> float:
        return now - (self.t0 if self.t0 is not None else 0.0)

    # --- window queries (called from wrappers) ---

    def transport_faulty(self, now: float) -> bool:
        rel = self._rel(now)
        return any(e.kind == TRANSIENT and e.t <= rel < e.t + e.duration_s
                   for e in self.events)

    def straggle_s(self, phase: str, idx: int, now: float) -> float:
        rel = self._rel(now)
        for e in self.events:
            if (e.kind == STRAGGLER and e.phase == phase
                    and e.idx == idx and e.t <= rel < e.t + e.duration_s):
                return e.slow_s
        return 0.0

    # --- point events (fired by the controller) ---

    def due(self, now: float) -> List[FaultEvent]:
        rel = self._rel(now)
        return [e for e in self.events
                if e.kind in (CRASH, PREEMPT) and not e.fired and e.t <= rel]

    # --- crash registry (keyed by stable ChaosClient ids) ---

    def mark_crashed(self, cid: int) -> None:
        self._crashed.add(cid)

    def is_crashed(self, cid: int) -> bool:
        return cid in self._crashed

    @classmethod
    def random(cls, *, seed: int, horizon_s: float, n_events: int = 3,
               phases: Sequence[str] = ("decode",),
               kinds: Sequence[str] = (CRASH, TRANSIENT, STRAGGLER, PREEMPT),
               n_replicas: int = 2) -> "FaultSchedule":
        """A reproducible random schedule: same seed -> same events."""
        rng = _random.Random(seed)
        ev = []
        for _ in range(n_events):
            ev.append(FaultEvent(
                t=rng.uniform(0.1 * horizon_s, 0.9 * horizon_s),
                kind=rng.choice(list(kinds)),
                phase=rng.choice(list(phases)),
                idx=rng.randrange(n_replicas),
                duration_s=rng.uniform(0.05, 0.3),
                grace_s=rng.uniform(0.3, 1.0),
                slow_s=rng.uniform(0.02, 0.1)))
        return cls(ev, seed=seed)


# --------------------------------------------------------------------------
# chaos wrappers

_next_cid = [0]


def _alloc_cid() -> int:
    _next_cid[0] += 1
    return _next_cid[0]


class ChaosTransport:
    """Wraps a Transport; raises :class:`TransientTransportError` inside
    scheduled transient windows. Everything else (``rebind_plan``,
    accounting attributes, ``send_decode``) forwards to the inner
    transport, so gateway code sees the production interface."""

    def __init__(self, inner, schedule: FaultSchedule,
                 clock: Callable[[], float] = time.time):
        self.__dict__["inner"] = inner
        self.__dict__["schedule"] = schedule
        self.__dict__["clock"] = clock
        self.__dict__["faults_raised"] = 0

    def _gate(self, now: Optional[float]) -> None:
        t = now if now is not None else self.clock()
        if self.schedule.transport_faulty(t):
            self.__dict__["faults_raised"] += 1
            raise TransientTransportError(
                f"injected transport fault at t={t:.3f}")

    def send(self, wire, src_replica, dst_replica, *, now=None):
        self._gate(now)
        return self.inner.send(wire, src_replica, dst_replica, now=now)

    def send_decode(self, wire, src_dec, dst_dec, *, now=None):
        self._gate(now)
        return self.inner.send_decode(wire, src_dec, dst_dec, now=now)

    def __getattr__(self, name):
        return getattr(self.__dict__["inner"], name)

    def __setattr__(self, name, value):
        setattr(self.__dict__["inner"], name, value)


class ChaosClient:
    """Wraps a replica client. Heavy calls (``prefill`` / ``step`` /
    ``admit`` — the one admission entry point, all sources) raise
    :class:`ReplicaCrashError` once this client's stable ``cid`` is
    marked crashed, and stall for scheduled straggler windows.
    Lightweight recovery calls (``resident``, ``release``) keep working
    — post-crash recovery uses gateway-side bookkeeping, not the dead
    engine — and ``n_free`` reports 0 so routing steers away."""

    _HEAVY = ("prefill", "step", "admit")

    def __init__(self, inner, schedule: FaultSchedule, phase: str, idx: int,
                 clock: Callable[[], float] = time.time):
        self.__dict__["inner"] = inner
        self.__dict__["schedule"] = schedule
        self.__dict__["phase0"] = phase
        self.__dict__["idx0"] = idx
        self.__dict__["clock"] = clock
        self.__dict__["cid"] = _alloc_cid()

    @property
    def crashed(self) -> bool:
        return self.schedule.is_crashed(self.cid)

    def _gate(self, name: str):
        if self.crashed:
            raise ReplicaCrashError(
                f"injected crash: {self.phase0}[{self.idx0}] is dead")
        stall = self.schedule.straggle_s(self.phase0, self.idx0, self.clock())
        if stall > 0.0:
            clk = self.__dict__["clock"]
            if hasattr(clk, "advance"):
                clk.advance(stall)
            else:
                time.sleep(stall)

    def prefill(self, *a, **kw):
        self._gate("prefill")
        return self.inner.prefill(*a, **kw)

    def step(self, *a, **kw):
        self._gate("step")
        return self.inner.step(*a, **kw)

    def admit(self, *a, **kw):
        self._gate("admit")
        return self.inner.admit(*a, **kw)

    def n_free(self, *a, **kw):
        if self.crashed:
            return 0
        return self.inner.n_free(*a, **kw)

    def __getattr__(self, name):
        return getattr(self.__dict__["inner"], name)

    def __setattr__(self, name, value):
        setattr(self.__dict__["inner"], name, value)


class ChaosController:
    """Fires point events (CRASH, PREEMPT) against a live gateway.

    Call :meth:`tick` from the pump loop (the gateway does this itself
    once ``install_chaos`` set ``gw.chaos``). Victim resolution happens
    at fire time: ``idx = -1`` picks the busiest alive replica of the
    phase, and ``require_busy`` defers up to ``defer_s`` until the
    victim holds resident work.
    """

    def __init__(self, gw, schedule: FaultSchedule, defer_s: float = 2.0):
        self.gw = gw
        self.schedule = schedule
        self.defer_s = defer_s
        self.fired: List[Dict[str, Any]] = []

    # --- victim resolution ---

    def _handles(self, phase: str):
        return self.gw.pre if phase == "prefill" else self.gw.dec

    def _resident_count(self, h) -> int:
        try:
            return len(h.client.resident())   # decode clients only
        except Exception:
            return 0

    def _resolve(self, ev: FaultEvent) -> Optional[int]:
        handles = self._handles(ev.phase)
        alive = [i for i, h in enumerate(handles) if h.alive]
        if not alive:
            return None
        if ev.idx >= 0:
            return ev.idx if ev.idx in alive else None
        return max(alive, key=lambda i: self._resident_count(handles[i]))

    def tick(self, now: float) -> None:
        for ev in self.schedule.due(now):
            idx = self._resolve(ev)
            if idx is None:
                ev.fired = True      # nothing to hit; consume the event
                continue
            if (ev.require_busy
                    and self._resident_count(self._handles(ev.phase)[idx]) == 0
                    and self.schedule._rel(now) < ev.t + self.defer_s):
                continue             # defer until the victim holds work
            ev.fired = True
            if ev.kind == CRASH:
                self._fire_crash(ev, idx, now)
            elif ev.kind == PREEMPT:
                self._fire_preempt(ev, idx, now)

    def _fire_crash(self, ev: FaultEvent, idx: int, now: float) -> None:
        h = self._handles(ev.phase)[idx]
        client = h.client
        if hasattr(client, "cid"):
            self.schedule.mark_crashed(client.cid)
        else:                         # not chaos-wrapped: hard kill
            self.gw.kill_replica(ev.phase, idx)
        self.fired.append({"kind": CRASH, "phase": ev.phase, "idx": idx,
                           "t": now})

    def _fire_preempt(self, ev: FaultEvent, idx: int, now: float) -> None:
        report = self.gw.handle_preemption(ev.phase, idx,
                                           grace_s=ev.grace_s, now=now)
        self.fired.append({"kind": PREEMPT, "phase": ev.phase, "idx": idx,
                           "t": now, **(report or {})})


def install_chaos(gw, schedule: FaultSchedule,
                  clock: Optional[Callable[[], float]] = None):
    """Wire a fault schedule onto a live gateway: wrap the transport and
    every replica client, arm the schedule at the current clock, and
    attach a :class:`ChaosController` as ``gw.chaos`` (ticked by
    ``Gateway.pump``). Returns the controller."""
    clk = clock if clock is not None else gw.clock
    if gw.transport is not None and not isinstance(gw.transport,
                                                   ChaosTransport):
        gw.transport = ChaosTransport(gw.transport, schedule, clk)
    for phase, handles in (("prefill", gw.pre), ("decode", gw.dec)):
        for i, h in enumerate(handles):
            if not isinstance(h.client, ChaosClient):
                h.client = ChaosClient(h.client, schedule, phase, i, clk)
    schedule.arm(clk())
    ctl = ChaosController(gw, schedule)
    gw.chaos = ctl
    return ctl
