"""Workload profiler (paper Appendix E): sliding-window statistics of
prompt/response lengths and arrival rate, with shift detection that triggers
the scheduler's lightweight rescheduling.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Deque, Optional, Tuple

from repro.core.workload import Workload


@dataclass
class WindowStats:
    n: int
    rate: float
    mean_in: float
    mean_out: float


class WorkloadProfiler:
    def __init__(self, *, window: int = 200, shift_threshold: float = 0.4):
        self.window = window
        self.shift_threshold = shift_threshold
        self._records: Deque[Tuple[float, int, int]] = deque(maxlen=window)
        self._baseline: Optional[WindowStats] = None

    def record(self, n_in: int, n_out: int, t: Optional[float] = None):
        self._records.append((t if t is not None else time.time(),
                              n_in, n_out))

    def stats(self) -> Optional[WindowStats]:
        if len(self._records) < 8:
            return None
        ts = [r[0] for r in self._records]
        dur = max(ts[-1] - ts[0], 1e-9)
        return WindowStats(
            n=len(self._records),
            rate=len(self._records) / dur,
            mean_in=sum(r[1] for r in self._records) / len(self._records),
            mean_out=sum(r[2] for r in self._records) / len(self._records))

    def set_baseline(self):
        self._baseline = self.stats()

    def shift_detected(self) -> bool:
        """Relative change in mean output (or input) length beyond threshold.

        Output length drives the prefill:decode balance (paper §3.4), so it
        is the primary signal."""
        cur = self.stats()
        if cur is None or self._baseline is None:
            return False
        b = self._baseline

        def rel(a, bb):
            return abs(a - bb) / max(abs(bb), 1e-9)

        return (rel(cur.mean_out, b.mean_out) > self.shift_threshold
                or rel(cur.mean_in, b.mean_in) > self.shift_threshold)

    def as_workload(self, name: str = "observed") -> Optional[Workload]:
        s = self.stats()
        if s is None:
            return None
        return Workload(name, mean_in=s.mean_in, mean_out=s.mean_out)
