"""Workload profiler (paper Appendix E): sliding-window statistics of
prompt/response lengths and arrival rate, with shift detection that triggers
the scheduler's lightweight rescheduling.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Optional, Tuple

from repro.core.workload import Workload


@dataclass
class WindowStats:
    n: int
    rate: float
    mean_in: float
    mean_out: float


class WorkloadProfiler:
    """Sliding windows over COMPLETIONS (lengths, via ``record``) and
    ARRIVALS (via ``record_arrival``). The two are kept apart because they
    answer different questions: length statistics exist only once a
    request finishes, but the offered load must be measured at submit time
    — under saturation (exactly when rescheduling matters) the completion
    rate is capped by the stale plan's capacity and badly underestimates
    the arrival rate.

    ``in_scale``/``out_scale`` map observed token counts back to the
    workload the COST MODEL should see — e.g. a reduced-config engine
    serving 1/32-scale prompts sets ``in_scale=32`` so ``as_workload()``
    describes the full-model trace."""

    def __init__(self, *, window: int = 200, shift_threshold: float = 0.4,
                 in_scale: float = 1.0, out_scale: float = 1.0,
                 clock: Optional[Callable[[], float]] = None):
        self.window = window
        self.shift_threshold = shift_threshold
        self.in_scale = in_scale
        self.out_scale = out_scale
        self.clock = clock if clock is not None else time.time
        self._records: Deque[Tuple[float, int, int]] = deque(maxlen=window)
        self._arrivals: Deque[float] = deque(maxlen=window)
        self._prefix: Deque[Tuple[int, int]] = deque(maxlen=window)
        self._baseline: Optional[WindowStats] = None

    def record(self, n_in: int, n_out: int, t: Optional[float] = None):
        self._records.append((t if t is not None else self.clock(),
                              n_in, n_out))

    def record_arrival(self, t: Optional[float] = None):
        self._arrivals.append(t if t is not None else self.clock())

    def record_prefix(self, prompt_len: int, hit_len: int):
        """Record a prefix-cache probe at SUBMIT time: ``hit_len`` of the
        ``prompt_len`` prompt tokens were found in the shared radix cache
        (0 = miss). Probes are recorded at submit rather than completion
        for the same reason arrivals are — under saturation the hit rate
        of what is *offered* is what the next plan must be sized for."""
        self._prefix.append((max(int(prompt_len), 1),
                             max(int(hit_len), 0)))

    def prefix_hit_rate(self) -> float:
        """Token-weighted fraction of prompt tokens served from the
        prefix cache over the window; 0.0 until any probe is recorded."""
        if not self._prefix:
            return 0.0
        tot = sum(p for p, _ in self._prefix)
        hit = sum(min(h, p) for p, h in self._prefix)
        return hit / max(tot, 1)

    def arrival_rate(self) -> Optional[float]:
        """Offered load over the arrival window; None until 8 arrivals."""
        if len(self._arrivals) < 8:
            return None
        dur = max(self._arrivals[-1] - self._arrivals[0], 1e-9)
        return len(self._arrivals) / dur

    def stats(self) -> Optional[WindowStats]:
        if len(self._records) < 8:
            return None
        ts = [r[0] for r in self._records]
        dur = max(ts[-1] - ts[0], 1e-9)
        return WindowStats(
            n=len(self._records),
            rate=len(self._records) / dur,
            mean_in=sum(r[1] for r in self._records) / len(self._records),
            mean_out=sum(r[2] for r in self._records) / len(self._records))

    def set_baseline(self):
        self._baseline = self.stats()

    @property
    def has_baseline(self) -> bool:
        """True once a baseline window is pinned — until then
        ``shift_detected`` can never fire (drivers call ``set_baseline``
        as soon as the first window fills)."""
        return self._baseline is not None

    def shift_detected(self) -> bool:
        """Relative change in mean output (or input) length beyond threshold.

        Output length drives the prefill:decode balance (paper §3.4), so it
        is the primary signal."""
        cur = self.stats()
        if cur is None or self._baseline is None:
            return False
        b = self._baseline

        def rel(a, bb):
            return abs(a - bb) / max(abs(bb), 1e-9)

        return (rel(cur.mean_out, b.mean_out) > self.shift_threshold
                or rel(cur.mean_in, b.mean_in) > self.shift_threshold)

    def as_workload(self, name: str = "observed") -> Optional[Workload]:
        """Observed window as a cost-model workload, with the configured
        engine->full-model scale applied."""
        s = self.stats()
        if s is None:
            return None
        return Workload(name, mean_in=s.mean_in * self.in_scale,
                        mean_out=s.mean_out * self.out_scale,
                        prefix_hit_rate=self.prefix_hit_rate())
