"""Serving engines: a prefill engine and a continuous-batching decode engine
around one model replica each (the in-process realization of the paper's
"model serving group").

The decode engine owns a slotted cache (capacity = max_slots sequences);
requests join/leave slots between steps — classic continuous batching without
page tables (TPU-idiomatic fixed layout + length masks, see DESIGN.md §2).

Perf architecture (DESIGN.md §3): the decode hot loop is DEVICE-RESIDENT —
``step()`` runs a jitted ``lax.scan`` over ``chunk_size`` decode steps and
pays one host synchronization per *chunk* instead of per token. Prefill
compilation is bounded by power-of-two length buckets, so the jit cache
holds at most ``log2(max_seq)`` entries per engine.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import paged as paged_fmt
from repro.models import registry
from repro.serving import kv_transfer, page_pool, protocol
from repro.serving.kv_transfer import KVWire
from repro.serving.page_pool import PagePool, pages_needed
from repro.serving.prefix_cache import PrefixCache, PrefixMatch


@dataclass
class GenRequest:
    """Engine-level unit of work: prompt in, ``out_tokens`` accumulate.

    Engines never stamp the timestamp fields — they belong to the owning
    ``RequestHandle`` (``gateway.py``)."""
    rid: int
    tokens: np.ndarray              # prompt token ids (1D)
    max_new_tokens: int
    extras: Dict[str, np.ndarray] = field(default_factory=dict)
    out_tokens: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = -1.0
    t_done: float = -1.0
    # prefix-cache partial hit: positions [0, start_pos) are already
    # resident on the target decode replica (``prefix_pages``); prefill
    # covers only the suffix, attending over ``prefix_wire``'s KV
    start_pos: int = 0
    prefix_pages: Optional[List[int]] = None
    prefix_wire: Optional[KVWire] = None
    prefix_replica: int = -1


# -- unified admission API ----------------------------------------------------
#
# Every way a request can reach a decode replica is ONE call —
# ``DecodeEngine.admit(AdmissionBatch)`` — with a typed per-item source
# instead of four near-identical entry points (DESIGN.md §5):
#
#   FRESH       one-shot prefill wire; ``token`` is the first output
#   CHUNKED     concatenated chunked-prefill wire; engine-side identical
#               to FRESH (the wire already covers the whole prompt suffix)
#   PREFIX_HIT  full prefix-cache hit; no wire — ``pages`` is the resident
#               chain and ``token`` the known first output
#   MIGRATED    mid-stream snapshot off a preempted replica; ``token`` is
#               the RESUME token (already in ``out_tokens`` at the source,
#               so it is not re-appended)

ADMIT_FRESH = "FRESH"
ADMIT_CHUNKED = "CHUNKED"
ADMIT_PREFIX_HIT = "PREFIX_HIT"
ADMIT_MIGRATED = "MIGRATED"


@dataclass
class AdmissionItem:
    """One request entering continuous batching, tagged with how its KV
    arrives. ``wire`` for FRESH/CHUNKED/MIGRATED; ``pages`` for
    PREFIX_HIT."""
    req: GenRequest
    token: int
    source: str = ADMIT_FRESH
    wire: Optional[KVWire] = None
    pages: Optional[List[int]] = None


@dataclass
class AdmissionBatch:
    """Ordered admission attempt; admission is FIFO and stops at the
    first item the page/slot budget cannot place — ``admit`` returns the
    rejected tail as another ``AdmissionBatch``."""
    items: List[AdmissionItem] = field(default_factory=list)

    def __len__(self) -> int:
        return len(self.items)

    def __iter__(self):
        return iter(self.items)

    def __bool__(self) -> bool:
        return bool(self.items)


@dataclass
class PartialPrefill:
    """Resumable chunked-prefill state (SARATHI-style).

    ``pos`` counts prompt tokens prefilled so far PAST the request's
    ``start_pos`` (prefix-cache-resident tokens are never re-prefilled);
    ``wires`` holds one RAW (uncompressed) suffix wire per completed
    chunk. Chunk N+1 is a suffix prefill attending over the concatenation
    of the request's prefix wire (if any) and the accumulated chunk wires
    — the PR-8 machinery. The chunk wires stay raw so the resumable
    prefix is the EXACT float KV the one-shot prefill would compute
    (int4 round-tripping it perturbs near-tie logits and breaks token
    parity); quantization happens ONCE, over the spliced whole, when the
    job completes — which also makes the ``transport`` wire bit-identical
    to a one-shot extraction. Once ``done``, ``first`` is the argmax at
    the prompt's true last position and :meth:`wire` returns the
    admission wire."""
    req: GenRequest
    pos: int = 0
    wires: List[KVWire] = field(default_factory=list)
    first: int = -1
    done: bool = False
    transport: Optional[KVWire] = None

    @property
    def next_pos(self) -> int:
        return self.req.start_pos + self.pos

    @property
    def remaining(self) -> int:
        return len(self.req.tokens) - self.next_pos

    def wire(self) -> KVWire:
        if self.transport is not None:
            return self.transport
        return kv_transfer.concat_wires(self.wires)


def _next_pow2(n: int) -> int:
    return 1 << max((n - 1).bit_length(), 0)


def _sanitize_enabled() -> bool:
    """``REPRO_SANITIZE=1`` swaps the page pool for the allocation-site-
    tracking variant and audits migrated wires (repro.analysis)."""
    return os.environ.get("REPRO_SANITIZE", "").strip() not in (
        "", "0", "false", "no")


class PrefillEngine:
    """Latency-oriented: processes one prompt batch at a time.

    Prompts are right-padded to power-of-two length buckets (causal
    attention makes the padding invisible to real positions; logits are
    gathered at each prompt's own last token), so the engine compiles at
    most ``log2(max_seq)`` length buckets (times a handful of pow2 batch
    widths <= max_batch) instead of one variant per unique prompt shape. Architectures with recurrent state (SSM / hybrid /
    xLSTM) or sliding-window attention fall back to exact-length grouping:
    their prefill state depends on every processed position, so padding
    would corrupt it.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 512,
                 rt=None, bucket: bool = True, max_batch: int = 4,
                 min_bucket: int = 16):
        self.cfg = cfg
        self.params = params
        self.api = registry.build(cfg, rt=rt)
        self.max_seq = max_seq
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        mixes = ({"attn"} if cfg.family == "audio" else
                 {k.split("+")[0] for k in cfg.layer_kinds()})
        self.bucketed = (bucket and mixes == {"attn"}
                         and not cfg.sliding_window
                         and cfg.family != "vlm")
        self._jits: Dict[Tuple[int, int], Callable] = {}
        # suffix prefill (prefix-cache partial hits): keyed by
        # (batch, suffix_bucket, prefix_bucket) so the jit cache stays
        # log2-bounded in both lengths
        self._suffix_jits: Dict[Tuple[int, int, int], Callable] = {}

    @property
    def supports_suffix(self) -> bool:
        """True when this engine can prefill a suffix against resident
        prefix KV (pure-attention stack, bucketed padding)."""
        return self.bucketed and self.api.prefill_suffix is not None

    def _prefill_fn(self, batch_shape: Tuple[int, int]) -> Callable:
        if batch_shape not in self._jits:
            self._jits[batch_shape] = jax.jit(
                lambda p, b: self.api.prefill(p, b, max_seq=self.max_seq))
        return self._jits[batch_shape]

    def _suffix_fn(self, key: Tuple[int, int, int]) -> Callable:
        if key not in self._suffix_jits:
            self._suffix_jits[key] = jax.jit(
                lambda p, b: self.api.prefill_suffix(
                    p, b, max_seq=self.max_seq))
        return self._suffix_jits[key]

    @property
    def jit_cache_size(self) -> int:
        return len(self._jits) + len(self._suffix_jits)

    def _bucket_of(self, n: int) -> int:
        return min(max(_next_pow2(n), self.min_bucket), self.max_seq)

    def run(self, reqs: List[GenRequest], *, compress: bool = True,
            backend: str = "auto") -> List[Tuple[GenRequest, KVWire, int]]:
        """Prefill a batch; returns per-request (req, wire, first_token).
        Requests carrying a resident prefix (``start_pos``/``prefix_wire``
        from a prefix-cache partial hit) take the suffix path: only
        ``tokens[start_pos:]`` run through the model, attending over the
        dequantized prefix KV; the returned wire covers the suffix."""
        if not reqs:
            return []
        suffix = [r for r in reqs
                  if r.start_pos > 0 and r.prefix_wire is not None]
        sids = {id(r) for r in suffix}
        normal = [r for r in reqs if id(r) not in sids]
        out = []
        if suffix:
            if not self.supports_suffix:
                raise ValueError(
                    "suffix prefill requested but this engine cannot "
                    "slice its state at a position boundary")
            out.extend(self._run_suffix(suffix, compress=compress,
                                        backend=backend))
        if normal:
            if self.bucketed:
                out.extend(self._run_bucketed(normal, compress=compress,
                                              backend=backend))
            else:
                out.extend(self._run_exact(normal, compress=compress,
                                           backend=backend))
        return out

    def prefill_chunk(self, jobs: List[PartialPrefill], budget: int, *,
                      compress: bool = True, backend: str = "auto"
                      ) -> List[PartialPrefill]:
        """Advance chunked prefills by up to ``budget`` prompt tokens
        TOTAL across ``jobs`` (FIFO), one model call per tick.

        Each job's next chunk is a suffix prefill over the concat of its
        accumulated RAW chunk wires (plus any prefix-cache wire) — exact
        float KV, so the suffix attends over the same values a one-shot
        prefill computes in-cache and greedy decoding after chunking
        emits the SAME tokens. The admission wire is quantized once,
        over the spliced whole, when a job completes (``compress=True``),
        which is bit-identical to compressing a one-shot extraction.
        Engines that cannot slice state at a position boundary (recurrent
        state, SWA) run each job to completion in one shot — the budget
        degrades to an admission hint. Mutates and returns ``jobs``."""
        left = max(int(budget), 0)
        work = []                       # (job, clone, take)
        for job in jobs:
            if job.done or job.remaining <= 0 or left <= 0:
                continue
            take = protocol.chunk_take(job.remaining, left,
                                       self.supports_suffix)
            left -= min(take, left)
            req = job.req
            upto = job.next_pos + take
            clone = GenRequest(req.rid, np.asarray(req.tokens)[:upto],
                               req.max_new_tokens, extras=req.extras)
            if job.next_pos > 0:
                parts = ([req.prefix_wire]
                         if req.start_pos > 0 and req.prefix_wire is not None
                         else []) + job.wires
                clone.start_pos = job.next_pos
                clone.prefix_wire = kv_transfer.concat_wires(
                    parts, backend=backend)
            work.append((job, clone, take))
        if not work:
            return jobs
        by_clone = {id(c): (job, take) for job, c, take in work}
        # chunks extract RAW: the resumable prefix must be exact floats
        # (protocol.chunk_extract_compress() is False by contract)
        for clone, wire, first in self.run(
                [c for _, c, _ in work],
                compress=protocol.chunk_extract_compress(),
                backend=backend):
            job, take = by_clone[id(clone)]
            job.wires.append(wire)
            job.pos += take
            if protocol.chunk_complete(job.next_pos, len(job.req.tokens)):
                job.done = True
                job.first = int(first)
                full = kv_transfer.concat_wires(job.wires)
                job.transport = (kv_transfer.compress_wire(
                    full, backend=backend) if compress else full)
        return jobs

    def _run_exact(self, reqs, *, compress, backend):
        """Group by exact prompt length (no padding ever enters attention);
        one jit entry per unique (batch, length) shape."""
        by_len: Dict[int, List[GenRequest]] = {}
        for r in reqs:
            by_len.setdefault(len(r.tokens), []).append(r)
        out = []
        for L, group in by_len.items():
            toks = np.stack([r.tokens for r in group]).astype(np.int32)
            batch = {"tokens": jnp.asarray(toks)}
            for key in group[0].extras:
                batch[key] = jnp.stack(
                    [jnp.asarray(r.extras[key]) for r in group])
            logits, cache = self._prefill_fn(toks.shape)(self.params, batch)
            first = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            wires = kv_transfer.extract_batch(
                cache, [(i, L) for i in range(len(group))],
                compress=compress, backend=backend)
            for i, r in enumerate(group):
                out.append((r, wires[i], int(first[i])))
        return out

    def _run_bucketed(self, reqs, *, compress, backend):
        """Right-pad prompts to power-of-two buckets and a fixed batch
        width; the whole bucket's KV is quantized in one kernel launch."""
        too_long = [r.rid for r in reqs if len(r.tokens) > self.max_seq]
        if too_long:
            # fail loudly like the exact-length path does, instead of
            # silently conditioning on a truncated prompt
            raise ValueError(
                f"prompt(s) exceed max_seq={self.max_seq}: rids {too_long}")
        by_bucket: Dict[int, List[GenRequest]] = {}
        for r in reqs:
            by_bucket.setdefault(self._bucket_of(len(r.tokens)), []).append(r)
        out = []
        for Lb, group in by_bucket.items():
            for lo in range(0, len(group), self.max_batch):
                out.extend(self._run_one_bucket(
                    group[lo:lo + self.max_batch], Lb,
                    compress=compress, backend=backend))
        return out

    def _run_one_bucket(self, group, Lb, *, compress, backend):
        # pow2 batch width: a lone request doesn't pay a full-width
        # forward pass, and the jit cache only gains log2(max_batch)
        # variants per length bucket
        B = min(_next_pow2(len(group)), self.max_batch)
        lens = [min(len(r.tokens), Lb) for r in group]
        toks = np.zeros((B, Lb), np.int32)
        for i, r in enumerate(group):
            toks[i, :lens[i]] = r.tokens[:lens[i]]
        last_pos = np.zeros((B,), np.int32)
        last_pos[:len(group)] = np.asarray(lens) - 1
        true_len = np.ones((B,), np.int32)
        true_len[:len(group)] = lens
        batch = {"tokens": jnp.asarray(toks),
                 "last_pos": jnp.asarray(last_pos),
                 "true_len": jnp.asarray(true_len)}
        for key in (group[0].extras if group else {}):
            ex = [np.asarray(r.extras[key]) for r in group]
            ex += [ex[0]] * (B - len(group))     # dummy rows, discarded
            batch[key] = jnp.asarray(np.stack(ex))
        logits, cache = self._prefill_fn((B, Lb))(self.params, batch)
        first = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        wires = kv_transfer.extract_batch(
            cache, [(i, lens[i]) for i in range(len(group))],
            compress=compress, backend=backend, pad_to=Lb)
        return [(r, wires[i], int(first[i])) for i, r in enumerate(group)]

    def _run_suffix(self, reqs, *, compress, backend):
        """Partial-hit path: group by (suffix bucket, prefix bucket) and
        prefill only the suffix of each prompt against its dequantized
        prefix KV. Prefix lengths are page-aligned by construction."""
        too_long = [r.rid for r in reqs if len(r.tokens) > self.max_seq]
        if too_long:
            raise ValueError(
                f"prompt(s) exceed max_seq={self.max_seq}: rids {too_long}")
        by_key: Dict[Tuple[int, int], List[GenRequest]] = {}
        for r in reqs:
            Lb = self._bucket_of(len(r.tokens) - r.start_pos)
            Pb = self._bucket_of(r.start_pos)
            by_key.setdefault((Lb, Pb), []).append(r)
        out = []
        for (Lb, Pb), group in by_key.items():
            for lo in range(0, len(group), self.max_batch):
                out.extend(self._run_one_suffix_bucket(
                    group[lo:lo + self.max_batch], Lb, Pb,
                    compress=compress, backend=backend))
        return out

    def _run_one_suffix_bucket(self, group, Lb, Pb, *, compress, backend):
        B = min(_next_pow2(len(group)), self.max_batch)
        slens = [min(len(r.tokens) - r.start_pos, Lb) for r in group]
        toks = np.zeros((B, Lb), np.int32)
        for i, r in enumerate(group):
            toks[i, :slens[i]] = np.asarray(r.tokens)[r.start_pos:][:slens[i]]
        last_pos = np.zeros((B,), np.int32)
        last_pos[:len(group)] = np.asarray(slens) - 1
        true_len = np.ones((B,), np.int32)
        true_len[:len(group)] = slens
        prefix_kv, plen = kv_transfer.dequantize_prefix_batch(
            [r.prefix_wire for r in group], Pb, backend=backend)
        if B > len(group):
            # dummy batch rows: zero-length prefix, masked out entirely
            pad = B - len(group)
            prefix_kv = jax.tree.map(
                lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) *
                                  (a.ndim - 2)), prefix_kv)
            plen = jnp.pad(plen, (0, pad))
        batch = {"tokens": jnp.asarray(toks),
                 "last_pos": jnp.asarray(last_pos),
                 "true_len": jnp.asarray(true_len),
                 "prefix_kv": prefix_kv,
                 "prefix_len": plen}
        logits, cache = self._suffix_fn((B, Lb, Pb))(self.params, batch)
        first = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        wires = kv_transfer.extract_batch(
            cache, [(i, slens[i]) for i in range(len(group))],
            compress=compress, backend=backend, pad_to=Lb)
        return [(r, wires[i], int(first[i])) for i, r in enumerate(group)]


class DecodeEngine:
    """Throughput-oriented: continuous batching over a slotted cache.

    ``step()`` advances all slots by up to ``chunk_size`` tokens in ONE
    jitted device loop (current tokens, done-flags, and the emitted chunk
    all live on device; see ``registry.make_decode_chunk``), so the host is
    touched once per chunk. ``step_reference()`` keeps the one-token-per-
    host-round-trip path for A/B benchmarking and equivalence tests.

    With ``paged=True`` (pure-attention archs) the dense ``[max_slots,
    max_seq]`` cache is replaced by a page pool that stays int4-quantized
    at rest (``kv_resident="bf16"`` for ablation): admission reserves
    ``ceil((prompt + max_new) / page_size)`` pages per request instead of
    a worst-case ``max_seq`` column, aligned wires scatter into pages with
    no dequant round-trip, attention dequantizes in-kernel, and capacity
    is governed by the PAGE BUDGET — ``free_slots()`` truncates to what
    the pool can actually admit, which is what the gateway's dispatch and
    shedding read. Unsupported archs fall back to the dense path
    (``paged_fallback`` records why).
    """

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 8,
                 max_seq: int = 512, rt=None, eos_id: int = -1,
                 chunk_size: int = 8, paged: bool = False,
                 page_size: int = paged_fmt.DEFAULT_PAGE_SIZE,
                 num_pages: Optional[int] = None,
                 kv_resident: str = "int4", paged_backend: str = "auto",
                 prefix_sharing: bool = False):
        self.cfg = cfg
        self.params = params
        self.api = registry.build(cfg, rt=rt)
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.chunk_size = chunk_size
        supported = self.api.paged_decode_fns is not None
        self.paged = bool(paged) and supported
        self.paged_fallback = (
            None if (not paged or supported) else
            f"{cfg.name}: arch cannot page its decode cache (recurrent "
            f"state / SWA ring buffer / audio / softcap)")
        if self.paged:
            self.page_size = page_size
            self.table_w = paged_fmt.table_width(max_seq, page_size)
            if num_pages is None:
                # parity with the dense max_slots x max_seq budget (+ the
                # trash page); real deployments size this from HBM instead
                num_pages = max_slots * self.table_w + 1
            self.kv_resident = kv_resident
            if _sanitize_enabled():
                from repro.analysis.sanitizers import make_sanitized_pool
                self.pool = make_sanitized_pool(num_pages, page_size)
            else:
                self.pool = PagePool(num_pages, page_size)
            self.cache = paged_fmt.init_paged_cache(
                cfg, max_slots, max_seq, num_pages, page_size=page_size,
                resident=kv_resident)
            step_fn, chunk_fn = self.api.paged_decode_fns(page_size,
                                                          paged_backend)
            self._decode = jax.jit(step_fn)
            self._chunk = jax.jit(
                chunk_fn, static_argnames=("n_steps", "eos_id", "max_seq"))
            self._slot_pages: Dict[int, List[int]] = {}
            self._need_sum = 0      # pages reserved across admissions
            self._need_n = 0
            self.zero_copy_inserts = 0
            self.reencoded_inserts = 0
            # prefix sharing: finished chains are donated to a radix index
            # and later requests decode straight off the shared pages
            self.prefix_cache = (PrefixCache(page_size) if prefix_sharing
                                 else None)
            self._pins: Dict[object, List[int]] = {}
            self.cow_copies = 0
            self.prefix_admits = 0
        else:
            init_fn = (registry.whisper.init_cache if cfg.family == "audio"
                       else registry.transformer.init_cache)
            self.cache = init_fn(cfg, max_slots, max_seq)
            self._decode = jax.jit(
                lambda p, c, b: self.api.decode(p, c, b))
            self._chunk = jax.jit(
                self.api.decode_chunk,
                static_argnames=("n_steps", "eos_id", "max_seq"))
        self.slots: List[Optional[GenRequest]] = [None] * max_slots
        self.cur_token = np.zeros((max_slots,), np.int32)
        # host-sync accounting (benchmarks read these)
        self.host_syncs = 0
        self.steps_run = 0

    # -- slot management ----------------------------------------------------

    def free_slots(self) -> List[int]:
        """Admissible slot indices. Paged engines truncate the raw free
        list to the PAGE BUDGET: free pages divided by the mean reserved
        pages per admitted request (worst-case table width before any
        observation) — the number the gateway's dispatch/shedding sees."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not self.paged or not free:
            return free
        est = (self._need_sum / self._need_n) if self._need_n \
            else float(self.table_w)
        # pages held ONLY by the prefix index are reclaimable on demand
        # (evicted inside _alloc_pages), so they count as capacity — this
        # is how the hit rate turns into extra concurrent-decode headroom
        reclaimable = 0
        if self.prefix_cache is not None:
            reclaimable = sum(1 for p in self.prefix_cache.page_set()
                              if self.pool.refcount(p) == 1)
        cap = int((self.pool.n_free + reclaimable) / max(est, 1.0))
        return free[:max(cap, 0)]

    def _alloc_pages(self, n: int, owner) -> Optional[List[int]]:
        """Pool alloc with eviction-retry: on failure, evict unshared
        prefix-cache entries (LRU) to cover the shortfall, then retry."""
        if n == 0:
            return []
        pages = self.pool.alloc(n, owner)
        if pages is None and self.prefix_cache is not None:
            self.prefix_cache.evict(self.pool, n - self.pool.n_free)
            pages = self.pool.alloc(n, owner)
        return pages

    def admit(self, batch: AdmissionBatch, *,
              backend: str = "auto") -> AdmissionBatch:
        """Unified admission: one FIFO pass over an :class:`AdmissionBatch`
        whose items carry a typed source (FRESH | CHUNKED | PREFIX_HIT |
        MIGRATED); returns the rejected tail as an ``AdmissionBatch``.

        Admission stops at the first item capacity cannot place. Dense:
        one request per free slot, all wires inserted in ONE batched
        dequant launch. Paged: ALL-OR-NOTHING per request on the page
        budget — ``ceil((prompt + max_new)/page_size)`` pages reserved up
        front so an admitted stream can never die of a mid-decode page
        fault; PREFIX_HIT items share their resident chain (COW-splitting
        the boundary page when the prompt ends mid-page) and wire items
        scatter in one ``insert_wires`` launch."""
        if not isinstance(batch, AdmissionBatch):
            raise TypeError(
                "admit() takes an AdmissionBatch — the positional "
                "admit(req, wire, first_token) shim and the "
                "admit_batch/admit_prefix/admit_migrated variants were "
                "deleted (wrap items in AdmissionItem/AdmissionBatch)")
        items = list(batch.items)
        if _sanitize_enabled() and self.paged:
            # a migrated wire re-encoding (instead of zero-copy page
            # scatter) means extract_slot_wire/insert_wires drifted apart
            from repro.analysis.sanitizers import check_wire_alignment
            for it in items:
                if it.source == ADMIT_MIGRATED:
                    check_wire_alignment(it.wire, self.cfg,
                                         context=f"admit MIGRATED "
                                                 f"rid={it.req.rid}")
        if self.paged:
            n = self._admit_paged(items, backend=backend)
        else:
            n = self._admit_dense(items, backend=backend)
        return AdmissionBatch(items[n:])

    def _admit_dense(self, items: List[AdmissionItem], *, backend) -> int:
        free = self.free_slots()
        placed = []
        for it in items:
            if not free or it.source == ADMIT_PREFIX_HIT:
                break       # page-handle admission needs the paged pool
            placed.append((it, free.pop(0)))
        if placed:
            self.cache = kv_transfer.insert_batch(
                self.cache, [(it.wire, slot) for it, slot in placed],
                backend=backend)
            for it, slot in placed:
                self.slots[slot] = it.req
                self.cur_token[slot] = it.token
                if it.source != ADMIT_MIGRATED:
                    it.req.out_tokens.append(it.token)
        return len(placed)

    def _admit_paged(self, items: List[AdmissionItem], *, backend) -> int:
        n_taken = 0
        placed = []                      # wire-carrying placements
        free = [i for i, s in enumerate(self.slots) if s is None]
        for it in items:
            if it.source == ADMIT_PREFIX_HIT:
                # full hit: chain is resident, prefill was skipped —
                # placed inline (page copies, no wire insert)
                if not self._admit_one_prefix(it.req, it.pages or [],
                                              it.token):
                    break
                free = [i for i, s in enumerate(self.slots) if s is None]
                n_taken += 1
                continue
            if not free:
                break
            req, wire = it.req, it.wire
            migrated = it.source == ADMIT_MIGRATED
            # partial prefix hit: the wire covers only the suffix; the
            # shared prefix chain is already resident — share it under
            # this slot and splice suffix pages after it
            prefix = (list(req.prefix_pages)
                      if (not migrated and req.prefix_pages) else [])
            budget = min(len(req.tokens) + req.max_new_tokens, self.max_seq)
            need = min(pages_needed(budget, self.page_size), self.table_w) \
                - len(prefix)
            need = max(need, pages_needed(wire.request_len, self.page_size))
            if len(prefix) + need > self.table_w:
                break                   # chain cannot fit the table row
            pages = self._alloc_pages(need, free[0])
            if pages is None:           # page budget exhausted: stop (FIFO)
                break
            if prefix:
                try:
                    self.pool.share(prefix, free[0])
                except ValueError:
                    # prefix chain vanished (pin lost); surface as reject
                    self.pool.free(pages, owner=free[0])
                    break
            slot = free.pop(0)
            placed.append((it, slot, pages, prefix))
            n_taken += 1
        if placed:
            self.cache, nz, nr = page_pool.insert_wires(
                self.cache, self.cfg,
                [(it.wire, s, p, pre) for it, s, p, pre in placed],
                backend=backend)
            self.zero_copy_inserts += nz
            self.reencoded_inserts += nr
            for it, slot, pages, prefix in placed:
                self.slots[slot] = it.req
                self._slot_pages[slot] = prefix + pages
                self.cur_token[slot] = it.token
                if it.source != ADMIT_MIGRATED:
                    it.req.out_tokens.append(it.token)
                # only freshly ALLOCATED pages count toward the per-request
                # page-need estimate: shared prefixes cost no free pages,
                # which is exactly the capacity gain free_slots() credits
                self._need_sum += len(pages)
                self._need_n += 1
        return n_taken

    # -- prefix sharing -----------------------------------------------------

    def prefix_match(self, tokens) -> Optional[PrefixMatch]:
        """Probe the radix index with a prompt (gateway dispatch)."""
        if not self.paged or self.prefix_cache is None:
            return None
        return self.prefix_cache.match([int(t) for t in tokens])

    def prefix_pin(self, pages: List[int], tag) -> bool:
        """Take a reference on matched pages under an in-flight tag so
        neither eviction nor a donor release can recycle them between
        match and admission. Idempotence is the caller's problem."""
        try:
            self.pool.share(pages, tag)
        except ValueError:
            return False
        self._pins[tag] = list(pages)
        return True

    def prefix_unpin(self, tag):
        pages = self._pins.pop(tag, None)
        if pages:
            self.pool.unshare(pages, tag)

    def extract_prefix(self, pages: List[int], length: int) -> KVWire:
        """Gather a (pinned) prefix chain into a wire for the suffix
        prefill — a pure page gather, no dequantization here."""
        return page_pool.extract_slot_wire(self.cache, self.cfg, length,
                                           pages)

    def _admit_one_prefix(self, req: GenRequest, pages: List[int],
                          next_token: int) -> bool:
        """Admit a FULL prefix hit: every prompt token's KV is already
        resident in ``pages`` and ``next_token`` is the known first
        output, so prefill is skipped entirely — zero transfer, zero
        dequant. The slot shares the chain; if decode's next append lands
        in a shared page (prompt ends mid-page), that single page is
        copy-on-write duplicated first."""
        if not self.paged:
            return False
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return False
        slot = free[0]
        ln = len(req.tokens)
        budget = min(ln + req.max_new_tokens, self.max_seq)
        need_total = min(pages_needed(budget, self.page_size), self.table_w)
        n_extra = max(need_total - len(pages), 0)
        # next append hits a shared page -> that page must be COW-split
        # (boundary arithmetic is the protocol contract the model checker
        # explores; see serving/protocol.py)
        cow_at = protocol.cow_boundary(ln, self.page_size, self.table_w)
        cow = protocol.cow_needed(ln, self.page_size, self.table_w,
                                  len(pages))
        alloced = self._alloc_pages(n_extra + int(cow), slot)
        if alloced is None:
            return False
        try:
            self.pool.share(pages, slot)
        except ValueError:
            if alloced:
                self.pool.free(alloced, owner=slot)
            return False
        chain = list(pages) + (alloced[:n_extra] if cow else alloced)
        if cow:
            repl = alloced[-1]
            self.cache = page_pool.copy_page(self.cache, chain[cow_at],
                                             repl)
            self.pool.unshare([chain[cow_at]], slot)
            chain[cow_at] = repl
            self.cow_copies += 1
        self.cache = page_pool.set_page_chain(self.cache, slot, chain, ln)
        self.slots[slot] = req
        self._slot_pages[slot] = chain
        self.cur_token[slot] = next_token
        req.out_tokens.append(next_token)
        self._need_sum += len(alloced)
        self._need_n += 1
        self.prefix_admits += 1
        return True

    def _retire_slot(self, slot: int, req: GenRequest, kv_len: int):
        """Release a finished slot's pages, first donating the chain to
        the prefix index — donated pages live on under the index's owner
        tag; the rest return to the free list. The donate-BEFORE-free
        ordering comes from ``protocol.retire_steps`` (the model checker
        explores the same sequence; reversing it shares pages that are
        already on the free list)."""
        donate = (self.prefix_cache is not None and req is not None
                  and kv_len > 0)
        chain = self._slot_pages.get(slot, []) if donate else []
        n_used = pages_needed(kv_len, self.page_size) if donate else 0
        donate = bool(donate and chain and n_used <= len(chain))
        for op in protocol.retire_steps(donate):
            if op == "donate":
                toks = [int(t) for t in req.tokens] + \
                    [int(t) for t in req.out_tokens]
                self.prefix_cache.insert(toks, kv_len, chain[:n_used],
                                         len(req.tokens), self.pool)
            elif op == "free":
                self._free_pages_of(slot)

    def clear_prefix(self) -> int:
        """Drop the radix index AND any in-flight pins (drain / phase
        flip): releases every cache-held page reference so a drained pool
        really is all-free. Gateway-side pin records become stale no-ops."""
        if not self.paged:
            return 0
        for tag in list(self._pins):
            self.prefix_unpin(tag)
        if self.prefix_cache is None:
            return 0
        return self.prefix_cache.clear(self.pool)

    # -- live migration (preemption drains) ---------------------------------

    def extract_resident(self, *, compress: bool = True,
                         backend: str = "auto"
                         ) -> List[Tuple[int, GenRequest, KVWire, int]]:
        """Snapshot every resident request as (slot, req, wire, cur_token)
        for migration to another decode replica.

        The wire covers exactly the tokens whose K/V is in the cache
        (prompt + generated-so-far); ``cur_token`` — the last emitted
        token, whose K/V is appended by the NEXT step — rides alongside so
        the destination resumes mid-stream without regenerating anything.
        Paged engines gather pages zero-dequant
        (:func:`~repro.serving.page_pool.extract_slot_wire`); dense ones
        go through the standard ``kv_transfer.extract`` path. Slots are
        NOT released — the caller frees them once the handoff commits."""
        out = []
        if self.active == 0:
            return out
        lengths = np.asarray(self.cache["lengths"])
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            ln = int(lengths[slot])
            if self.paged:
                wire = page_pool.extract_slot_wire(
                    self.cache, self.cfg, ln, self._slot_pages[slot])
            else:
                wire = kv_transfer.extract(self.cache, slot, ln,
                                           compress=compress,
                                           backend=backend)
            out.append((slot, req, wire, int(self.cur_token[slot])))
        return out

    def _free_pages_of(self, slot: int):
        pages = self._slot_pages.pop(slot, [])
        if pages:
            self.pool.free(pages, owner=slot)

    def release(self, slot: int) -> Optional[GenRequest]:
        """Free one slot (cancellation / failure recovery): clears the
        request, returns every page to the pool (paged), and zeroes the
        slot's cache length so a later admit starts from a clean masked
        extent."""
        req = self.slots[slot]
        self.slots[slot] = None
        if self.paged:
            self._free_pages_of(slot)
            self.cache = page_pool.release_slot(self.cache, slot)
        else:
            self.cache["lengths"] = self.cache["lengths"].at[slot].set(0)
        return req

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def jit_cache_size(self) -> int:
        """Total compiled variants across the decode jits (the sanitizer's
        retrace monitor flags growth after warmup)."""
        n = 0
        for fn in (self._decode, self._chunk):
            sz = getattr(fn, "_cache_size", None)
            if callable(sz):
                n += sz()
        return n

    # -- stepping -----------------------------------------------------------

    def _host_state(self):
        active = np.array([s is not None for s in self.slots], bool)
        n_out = np.array([len(s.out_tokens) if s else 0 for s in self.slots],
                         np.int32)
        max_new = np.array([s.max_new_tokens if s else 0 for s in self.slots],
                           np.int32)
        return {"cur": jnp.asarray(self.cur_token),
                "active": jnp.asarray(active),
                "n_out": jnp.asarray(n_out),
                "max_new": jnp.asarray(max_new)}

    def step(self, n_steps: Optional[int] = None) -> List[GenRequest]:
        """Advance all active slots by up to ``chunk_size`` tokens; returns
        finished requests. ONE host synchronization for the whole chunk."""
        if self.active == 0:
            return []
        n = n_steps or self.chunk_size
        toks_d, valid_d, self.cache, st = self._chunk(
            self.params, self.cache, self._host_state(),
            n_steps=n, eos_id=self.eos_id, max_seq=self.max_seq)
        # the single device->host hop for this chunk
        toks, valid, cur, still_active, lengths = jax.device_get(
            (toks_d, valid_d, st["cur"], st["active"],
             self.cache["lengths"]))
        self.host_syncs += 1
        self.steps_run += n
        self.cur_token = np.array(cur)   # writable copy (admit mutates it)
        finished = []
        freed = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.out_tokens.extend(int(t) for t in toks[valid[:, i], i])
            if not still_active[i]:
                finished.append(req)
                self.slots[i] = None
                freed.append(i)
        if freed:
            # release the slots' cache lengths like step_reference does —
            # the scan freezes lengths for inactive slots, so without this
            # a finished slot would keep its old extent until re-admission
            self.cache["lengths"] = \
                self.cache["lengths"].at[jnp.asarray(freed)].set(0)
            if self.paged:
                # finished chains are donated to the prefix index before
                # the slot's references go back to the pool; the table
                # row points back at the trash page
                for req, i in zip(finished, freed):
                    self._retire_slot(i, req, int(lengths[i]))
                self.cache["page_table"] = \
                    self.cache["page_table"].at[jnp.asarray(freed)].set(0)
        return finished

    def step_reference(self) -> List[GenRequest]:
        """SEED PATH (kept for A/B benchmarks + equivalence tests): one
        decode step per call, one host sync + Python slot loop per token."""
        if self.active == 0:
            return []
        batch = {"tokens": jnp.asarray(self.cur_token[:, None])}
        logits, self.cache = self._decode(self.params, self.cache, batch)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        self.host_syncs += 1
        self.steps_run += 1
        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            self.cur_token[i] = tok
            done = (len(req.out_tokens) >= req.max_new_tokens
                    or tok == self.eos_id
                    or int(self.cache["lengths"][i]) >= self.max_seq - 1)
            if done:
                finished.append(req)
                self.slots[i] = None
                kv_len = int(self.cache["lengths"][i])
                self.cache["lengths"] = \
                    self.cache["lengths"].at[i].set(0)
                if self.paged:
                    self._retire_slot(i, req, kv_len)
                    self.cache["page_table"] = \
                        self.cache["page_table"].at[i].set(0)
        return finished

    # -- paged accounting ---------------------------------------------------

    def page_stats(self) -> Optional[Dict[str, float]]:
        """Pool occupancy + internal fragmentation (reserved-but-unused
        token fraction; generated counts are host-visible, so mid-chunk
        tokens are slightly understated). None for dense engines."""
        if not self.paged:
            return None
        st = self.pool.stats()
        reserved = sum(len(p) for p in self._slot_pages.values()) \
            * self.page_size
        used = sum(len(r.tokens) + len(r.out_tokens)
                   for r in self.slots if r is not None)
        st["resident_tokens"] = used
        st["reserved_tokens"] = reserved
        st["internal_frag"] = (1.0 - used / reserved) if reserved else 0.0
        st["zero_copy_inserts"] = self.zero_copy_inserts
        st["reencoded_inserts"] = self.reencoded_inserts
        # pages the pool holds that nothing references — should be 0
        # always; a release path that skipped pool.free shows up here
        # (and trips the REPRO_SANITIZE drain audit). The prefix index
        # and in-flight pins are legitimate holders.
        referenced = {p for ps in self._slot_pages.values() for p in ps}
        if self.prefix_cache is not None:
            referenced |= self.prefix_cache.page_set()
        for pinned in self._pins.values():
            referenced.update(pinned)
        st["leaked_pages"] = sum(1 for p in self.pool.pages_in_use()
                                 if p not in referenced)
        st["cow_copies"] = self.cow_copies
        st["prefix_admits"] = self.prefix_admits
        if self.prefix_cache is not None:
            for k, v in self.prefix_cache.stats().items():
                st[f"prefix_{k}"] = v
        return st


# -- phase-switchable replica -------------------------------------------------


class Replica:
    """One model replica that owns its parameters ONCE and can host either
    serving role (paper §3.4's core trick: a phase flip re-uses the
    resident, already-sharded weights — no reload, no restart).

    ``switch_phase()`` constructs (or re-activates) the engine for the
    other phase around the SAME parameter buffers. Engines are cached per
    phase, so a replica that flips back re-enters a warm jit cache. A
    decode replica must be drained (or its requests requeued by the
    gateway) before flipping — the slotted KV cache does not survive a
    role change. The page pool is likewise DECODE-phase-owned: it lives on
    the cached decode engine, so a drained flip leaves an all-free pool
    behind and a warm re-flip re-enters it without reallocation.
    """

    def __init__(self, cfg: ModelConfig, params, *,
                 phase: str = "prefill", max_seq: int = 512, rt=None,
                 prefill_kw: Optional[Dict] = None,
                 decode_kw: Optional[Dict] = None):
        if phase not in ("prefill", "decode"):
            raise ValueError(f"unknown phase {phase!r}")
        self.cfg = cfg
        self.params = params
        self._prefill_kw = {"max_seq": max_seq, "rt": rt,
                            **(prefill_kw or {})}
        self._decode_kw = {"max_seq": max_seq, "rt": rt,
                           **(decode_kw or {})}
        self._engines: Dict[str, object] = {}
        self.phase = ""
        self.switches = 0
        self.engine = None
        self._activate(phase)

    def _activate(self, phase: str):
        if phase not in self._engines:
            if phase == "prefill":
                self._engines[phase] = PrefillEngine(self.cfg, self.params,
                                                     **self._prefill_kw)
            else:
                self._engines[phase] = DecodeEngine(self.cfg, self.params,
                                                    **self._decode_kw)
        self.engine = self._engines[phase]
        self.phase = phase

    @property
    def drained(self) -> bool:
        """True when no request state would be lost by a phase flip."""
        return self.phase != "decode" or self.engine.active == 0

    def switch_phase(self, phase: Optional[str] = None):
        """Flip to ``phase`` (default: the other one) around the resident
        parameter buffers. Raises if an undrained decode engine would lose
        in-flight requests — the caller (gateway) drains or requeues them
        first. Returns the now-active engine."""
        target = phase or ("decode" if self.phase == "prefill"
                           else "prefill")
        if target not in ("prefill", "decode"):
            raise ValueError(f"unknown phase {target!r}")
        if target == self.phase:
            return self.engine
        if not self.drained:
            raise RuntimeError(
                f"cannot flip an undrained {self.phase} replica "
                f"({self.engine.active} request(s) resident): drain or "
                f"requeue them first")
        if self.phase == "decode" and hasattr(self.engine, "clear_prefix"):
            # shared prefixes don't survive a role change: release the
            # index's page references so the pool is left all-free
            self.engine.clear_prefix()
        self._activate(target)
        self.switches += 1
        return self.engine
