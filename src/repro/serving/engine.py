"""Serving engines: a prefill engine and a continuous-batching decode engine
around one model replica each (the in-process realization of the paper's
"model serving group").

The decode engine owns a slotted cache (capacity = max_slots sequences);
requests join/leave slots between steps — classic continuous batching without
page tables (TPU-idiomatic fixed layout + length masks, see DESIGN.md §2).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serving import kv_transfer
from repro.serving.kv_transfer import KVWire


@dataclass
class GenRequest:
    rid: int
    tokens: np.ndarray              # prompt token ids (1D)
    max_new_tokens: int
    extras: Dict[str, np.ndarray] = field(default_factory=dict)
    out_tokens: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = -1.0
    t_done: float = -1.0
    wire: Optional[KVWire] = None


class PrefillEngine:
    """Latency-oriented: processes one prompt batch at a time."""

    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 512,
                 rt=None):
        self.cfg = cfg
        self.params = params
        self.api = registry.build(cfg, rt=rt)
        self.max_seq = max_seq
        self._jits: Dict[Tuple[int, int], Callable] = {}

    def _prefill_fn(self, batch_shape: Tuple[int, int]) -> Callable:
        if batch_shape not in self._jits:
            self._jits[batch_shape] = jax.jit(
                lambda p, b: self.api.prefill(p, b, max_seq=self.max_seq))
        return self._jits[batch_shape]

    def run(self, reqs: List[GenRequest], *, compress: bool = True,
            backend: str = "auto") -> List[Tuple[GenRequest, KVWire, int]]:
        """Prefill a batch; returns per-request (req, wire, first_token).

        Requests are internally grouped by prompt length so no padding
        tokens ever enter attention (exact-length batching)."""
        if not reqs:
            return []
        by_len: Dict[int, List[GenRequest]] = {}
        for r in reqs:
            by_len.setdefault(len(r.tokens), []).append(r)
        out = []
        for L, group in by_len.items():
            toks = np.stack([r.tokens for r in group]).astype(np.int32)
            batch = {"tokens": jnp.asarray(toks)}
            for key in group[0].extras:
                batch[key] = jnp.stack(
                    [jnp.asarray(r.extras[key]) for r in group])
            logits, cache = self._prefill_fn(toks.shape)(self.params, batch)
            first = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for i, r in enumerate(group):
                wire = kv_transfer.extract(cache, i, L, compress=compress,
                                           backend=backend)
                out.append((r, wire, int(first[i])))
        return out


class DecodeEngine:
    """Throughput-oriented: continuous batching over a slotted cache."""

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 8,
                 max_seq: int = 512, rt=None, eos_id: int = -1):
        self.cfg = cfg
        self.params = params
        self.api = registry.build(cfg, rt=rt)
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.cache = self.api.cache_specs  # placeholder; real init below
        init_fn = (registry.whisper.init_cache if cfg.family == "audio"
                   else registry.transformer.init_cache)
        self.cache = init_fn(cfg, max_slots, max_seq)
        self.slots: List[Optional[GenRequest]] = [None] * max_slots
        self.cur_token = np.zeros((max_slots,), np.int32)
        self._decode = jax.jit(
            lambda p, c, b: self.api.decode(p, c, b))

    # -- slot management ----------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def admit(self, req: GenRequest, wire: KVWire, first_token: int,
              *, backend: str = "auto") -> bool:
        free = self.free_slots()
        if not free:
            return False
        i = free[0]
        self.cache = kv_transfer.insert(self.cache, wire, i, backend=backend)
        self.slots[i] = req
        self.cur_token[i] = first_token
        req.out_tokens.append(first_token)
        if req.t_first < 0:
            req.t_first = time.time()
        return True

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    # -- stepping -----------------------------------------------------------

    def step(self) -> List[GenRequest]:
        """One decode step for all active slots; returns finished requests."""
        if self.active == 0:
            return []
        batch = {"tokens": jnp.asarray(self.cur_token[:, None])}
        logits, self.cache = self._decode(self.params, self.cache, batch)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            self.cur_token[i] = tok
            done = (len(req.out_tokens) >= req.max_new_tokens
                    or tok == self.eos_id
                    or int(self.cache["lengths"][i]) >= self.max_seq - 1)
            if done:
                req.t_done = time.time()
                finished.append(req)
                self.slots[i] = None
                self.cache["lengths"] = \
                    self.cache["lengths"].at[i].set(0)
        return finished
