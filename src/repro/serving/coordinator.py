"""DEPRECATED batch-shaped coordinator — a thin shim over
:class:`repro.serving.gateway.Gateway`.

The serving stack's public API is now the request-lifecycle gateway
(``gateway.py``: ``submit(ServeRequest) -> RequestHandle`` with streaming,
deadlines, cancellation, and a pluggable ``Transport``). This module keeps
the original ``Coordinator.submit(GenRequest)`` / ``run_until_drained()``
entry points working for existing tests and callers:

* ``submit`` accepts mutable :class:`GenRequest` objects and tracks them,
* ``run_until_drained`` returns the finished ``GenRequest`` list with
  ``t_submit``/``t_first``/``t_done`` copied back from the handles (the
  engines themselves no longer stamp timestamps),
* ``materialize_wires`` maps onto the transport layer
  (:class:`~repro.serving.transport.InProcessTransport` with
  ``materialize=True``) instead of being a coordinator flag.

Routing, heartbeats, failure recovery, straggler mitigation, and
workload-shift rescheduling all live in the gateway now; this class adds
no behavior of its own.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.orchestrator import Orchestration
from repro.serving.engine import DecodeEngine, GenRequest, PrefillEngine
from repro.serving.gateway import Gateway, ReplicaHandle  # noqa: F401
from repro.serving.transport import InProcessTransport


class Coordinator(Gateway):
    def __init__(self, prefills: List[PrefillEngine],
                 decodes: List[DecodeEngine], *,
                 orchestration: Optional[Orchestration] = None,
                 compress: bool = True, backend: str = "auto",
                 heartbeat_timeout: float = 10.0, seed: int = 0):
        super().__init__(prefills, decodes, orchestration=orchestration,
                         compress=compress, backend=backend,
                         heartbeat_timeout=heartbeat_timeout, seed=seed)

    # -- deprecated wire-materialization flag --------------------------------

    @property
    def materialize_wires(self) -> bool:
        return bool(getattr(self.transport, "materialize", False))

    @materialize_wires.setter
    def materialize_wires(self, value: bool):
        self.transport = InProcessTransport(materialize=bool(value))

    # -- legacy batch API ----------------------------------------------------

    def submit(self, req: GenRequest):                # type: ignore[override]
        """Legacy entry point: enqueue a GenRequest (no deadlines/priority);
        the handle is created internally."""
        super().submit(req)

    def run_until_drained(self, *, max_iters: int = 10000
                          ) -> List[GenRequest]:      # type: ignore[override]
        handles = super().run_until_drained(max_iters=max_iters)
        out = []
        for h in handles:
            h.req.t_submit = h.t_submit
            h.req.t_first = h.t_first
            h.req.t_done = h.t_done
            out.append(h.req)
        return out
