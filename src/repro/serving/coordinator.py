"""Task coordinator (paper Appendix E): request dispatch across prefill and
decode replicas per the TSTP routing, heartbeat-based failure detection,
workload-shift-triggered lightweight rescheduling, and straggler mitigation
via periodic routing refresh from measured latencies.

In-process realization: replicas are engine objects; the event loop advances
prefill queues and decode steps. The same control flow maps onto a
multi-host deployment (each replica = a pod slice driven over RPC) — the
coordinator only exchanges requests, KVWire blobs, and heartbeats.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import scheduler as sched
from repro.core.orchestrator import Orchestration, SloSpec
from repro.serving.engine import DecodeEngine, GenRequest, PrefillEngine
from repro.serving.profiler import WorkloadProfiler


@dataclass
class ReplicaHandle:
    idx: int
    phase: str
    engine: object
    alive: bool = True
    last_heartbeat: float = field(default_factory=time.time)
    # straggler tracking
    ema_latency: float = 0.0

    def beat(self):
        self.last_heartbeat = time.time()


class Coordinator:
    def __init__(self, prefills: List[PrefillEngine],
                 decodes: List[DecodeEngine], *,
                 orchestration: Optional[Orchestration] = None,
                 compress: bool = True, backend: str = "auto",
                 heartbeat_timeout: float = 10.0, seed: int = 0):
        self.pre = [ReplicaHandle(i, "prefill", e)
                    for i, e in enumerate(prefills)]
        self.dec = [ReplicaHandle(j, "decode", e)
                    for j, e in enumerate(decodes)]
        self.o = orchestration
        self.compress = compress
        self.backend = backend
        self.heartbeat_timeout = heartbeat_timeout
        self.rng = np.random.default_rng(seed)
        self.profiler = WorkloadProfiler()
        self.queue: List[GenRequest] = []
        self.transfer_queue: List[Tuple[GenRequest, object, int, int]] = []
        self.done: List[GenRequest] = []
        self.events: List[str] = []
        # when True, wires are synced to host before the decode handoff
        # (models a real network hop; in-process the device arrays flow
        # straight through — see KVWire.materialize)
        self.materialize_wires = False
        self._decode_outage_reported = False

    # -- routing ------------------------------------------------------------

    def _X(self) -> np.ndarray:
        alive = np.array([r.alive for r in self.pre], float)
        if self.o is not None and self.o.X.shape[0] == len(self.pre):
            x = self.o.X * alive
        else:
            x = alive
        s = x.sum()
        return x / s if s > 0 else alive / max(alive.sum(), 1)

    def _Y(self, i: int) -> np.ndarray:
        alive = np.array([r.alive for r in self.dec], float)
        if self.o is not None and self.o.Y.shape == (len(self.pre),
                                                     len(self.dec)):
            y = self.o.Y[i] * alive
        else:
            y = alive
        s = y.sum()
        return y / s if s > 0 else alive / max(alive.sum(), 1)

    # -- request lifecycle ----------------------------------------------------

    def submit(self, req: GenRequest):
        req.t_submit = time.time()
        self.queue.append(req)

    def pump(self, *, max_prefill_batch: int = 4) -> int:
        """One coordinator iteration; returns #finished this round."""
        self._check_heartbeats()
        # 1. dispatch queued prompts: drain EVERY alive prefill replica this
        #    round (the TSTP masses only order who gets fed first), instead
        #    of feeding one randomly sampled replica and idling the rest
        if self.queue:
            X = self._X()
            cand = [i for i in range(len(self.pre))
                    if self.pre[i].alive and X[i] > 0]
            if len(cand) > 1:
                p = X[cand] / X[cand].sum()
                cand = [int(i) for i in self.rng.choice(
                    cand, size=len(cand), replace=False, p=p)]
            for i in cand:
                if not self.queue:
                    break
                batch = self.queue[:max_prefill_batch]
                self.queue = self.queue[max_prefill_batch:]
                t0 = time.time()
                results = self.pre[i].engine.run(
                    batch, compress=self.compress, backend=self.backend)
                self._track(self.pre[i], time.time() - t0)
                Y = self._Y(i)
                routable = Y.sum() > 0
                for req, wire, first in results:
                    if self.materialize_wires:
                        wire.materialize()   # the explicit host wire hop
                    # with no alive decode replica the target is a
                    # placeholder; _drain_transfers holds the wire + events
                    j = (int(self.rng.choice(len(self.dec), p=Y))
                         if routable else 0)
                    self.transfer_queue.append((req, wire, first, j))
        # 2. drain KV transfers into decode slots (prefill-side queueing:
        #    wires wait here if the target has no free slot, cf. Appendix E)
        self._drain_transfers()
        # 3. advance every decode replica one chunk of steps
        n_done = 0
        for handle in self.dec:
            if not handle.alive:
                continue
            t0 = time.time()
            finished = handle.engine.step()
            if handle.engine.active or finished:
                self._track(handle, time.time() - t0)
            for req in finished:
                self.profiler.record(len(req.tokens), len(req.out_tokens))
                self.done.append(req)
                n_done += 1
        return n_done

    def _drain_transfers(self):
        if not self.transfer_queue:
            return
        alive = [j for j, d in enumerate(self.dec) if d.alive]
        if not alive:
            # do NOT silently reroute to replica 0 (it is dead too) — keep
            # the wires queued and surface the outage once
            if not self._decode_outage_reported:
                self.events.append(
                    "all decode replicas dead; KV transfers stalled")
                self._decode_outage_reported = True
            return
        self._decode_outage_reported = False
        by_target: Dict[int, List[Tuple[GenRequest, object, int]]] = {}
        for req, wire, first, j in self.transfer_queue:
            if not self.dec[j].alive:
                # reroute to the alive replica with the most free slots
                j = max(alive,
                        key=lambda jj: len(self.dec[jj].engine.free_slots()))
            by_target.setdefault(j, []).append((req, wire, first))
        still = []
        for j, items in by_target.items():
            rejected = self.dec[j].engine.admit_batch(
                items, backend=self.backend)
            still.extend((req, wire, first, j)
                         for req, wire, first in rejected)
        self.transfer_queue = still

    def run_until_drained(self, *, max_iters: int = 10000) -> List[GenRequest]:
        it = 0
        while (self.queue or self.transfer_queue
               or any(d.engine.active for d in self.dec)) \
                and it < max_iters:
            self.pump()
            it += 1
        return self.done

    # -- fault tolerance ------------------------------------------------------

    def _check_heartbeats(self):
        now = time.time()
        for h in self.pre + self.dec:
            if h.alive and now - h.last_heartbeat > self.heartbeat_timeout:
                h.alive = False
                self.events.append(f"replica {h.phase}:{h.idx} timed out")
                self._recover_from(h)

    def kill_replica(self, phase: str, idx: int):
        """Failure injection (tests/benchmarks)."""
        group = self.pre if phase == "prefill" else self.dec
        group[idx].alive = False
        self.events.append(f"replica {phase}:{idx} killed")
        self._recover_from(group[idx])

    def _recover_from(self, h: ReplicaHandle):
        """Requests in a dead decode replica lose their KV — re-queue them
        for a fresh prefill on a surviving replica."""
        if h.phase != "decode":
            return
        eng = h.engine
        for i, req in enumerate(eng.slots):
            if req is None:
                continue
            req.out_tokens = []
            req.t_first = -1.0
            self.queue.append(req)
            eng.slots[i] = None
            self.events.append(f"request {req.rid} re-queued after "
                               f"decode:{h.idx} failure")

    def heartbeat_all(self):
        for h in self.pre + self.dec:
            if h.alive:
                h.beat()

    def _track(self, h: ReplicaHandle, dt: float):
        h.beat()
        h.ema_latency = 0.8 * h.ema_latency + 0.2 * dt if h.ema_latency \
            else dt

    # -- straggler mitigation ---------------------------------------------------

    def refresh_routing_from_latency(self):
        """Bleed traffic away from slow replicas: reweight X/Y by inverse
        measured latency (keeps the TSTP structure, scales the masses)."""
        if self.o is None:
            return
        lat_p = np.array([max(h.ema_latency, 1e-6) for h in self.pre])
        w = (1.0 / lat_p)
        w /= w.sum()
        X = self.o.X * w
        if X.sum() > 0:
            self.o.X = X / X.sum()
        lat_d = np.array([max(h.ema_latency, 1e-6) for h in self.dec])
        wd = (1.0 / lat_d)
        wd /= wd.sum()
        Y = self.o.Y * wd[None, :]
        s = Y.sum(axis=1, keepdims=True)
        self.o.Y = np.where(s > 0, Y / np.maximum(s, 1e-12), self.o.Y)

    # -- workload shift -> lightweight rescheduling ------------------------------

    def maybe_reschedule(self, cluster, cfg: ModelConfig, plan, rate: float,
                         slo: SloSpec):
        if not self.profiler.shift_detected():
            return None
        wl = self.profiler.as_workload()
        new_plan = sched.reschedule_lightweight(cluster, cfg, plan, wl, rate,
                                                slo)
        self.o = new_plan.orchestration
        self.profiler.set_baseline()
        self.events.append(
            f"lightweight rescheduling: {new_plan.search_seconds:.2f}s, "
            f"P:{len(new_plan.prefill_replicas)} "
            f"D:{len(new_plan.decode_replicas)}")
        return new_plan
