"""Radix index over resident KV pages: prefix sharing for the paged pool.

Serving traffic is dominated by shared prefixes (system prompts, few-shot
preambles, chat history), and the paged int4 cache was laid out so reuse
is pure HOST bookkeeping: pages are read-only to the decode kernel except
each slot's tail append, so a prefix that is already resident can back any
number of concurrent slots. This module is the index that finds it.

Structure: a radix tree at PAGE granularity. Each edge/node covers one
page worth of token ids (``page_size`` tokens; the last node of a donated
chain may be partial) and names the resident page that holds those
tokens' KV. Walking the tree with a prompt yields the longest resident
prefix; the caller shares the returned pages (``PagePool.share``) under
its own owner tag before using them — the tree itself holds ONE reference
per page under :data:`PREFIX_OWNER`, taken at donation time.

Hit classes:

* **full** — every prompt token's KV is resident AND the token following
  the prompt is known from a donor sequence where that token was
  GENERATED (greedy decode is deterministic, so a donor's generated token
  at position ``L`` is exactly what the model would emit after the same
  ``L``-token prefix). Prefill is skipped entirely: the request decodes
  straight off the shared chain, with copy-on-write if its tail page is
  shared. Tokens that were part of a donor's *prompt* are arbitrary user
  text and never satisfy this — full hits require the ``gen`` flag.
* **partial** — the match is truncated DOWN to a page boundary and the
  suffix is prefilled against the dequantized prefix KV
  (``transformer.prefill_suffix``), so shared pages are never written
  mid-page by the insert path.

Eviction: LRU over leaves whose page has no owner besides the tree
(refcount-0 from the outside). Interior nodes become evictable once their
children go. Refcount mutation happens only through the pool API (R006).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.page_pool import PagePool, pages_needed

# the tree's own reference on every adopted page rides under this tag
PREFIX_OWNER = "prefix-cache"


@dataclass
class PrefixMatch:
    """Result of matching a prompt against the index.

    ``length`` counts resident KV tokens covered (== prompt length for a
    full hit, a multiple of ``page_size`` otherwise); ``pages`` is the
    chain backing positions ``0..length-1``; ``next_token`` is the known
    continuation for a full hit (the token the skipped prefill would have
    produced)."""
    length: int
    pages: List[int]
    full: bool
    next_token: Optional[int] = None


class _Node:
    __slots__ = ("tokens", "gen", "page", "children", "parent", "key",
                 "stamp", "tail_token", "tail_gen")

    def __init__(self, tokens: Tuple[int, ...], gen: Tuple[bool, ...],
                 page: int, parent: "_Node", key: Tuple[int, ...]):
        self.tokens = tokens          # this block's token ids (n_valid of them)
        self.gen = gen                # True where the token was model-generated
        self.page = page
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.key = key
        self.stamp = 0
        # donor's first token BEYOND this node's KV (chain ended here)
        self.tail_token: Optional[int] = None
        self.tail_gen = False

    @property
    def n_valid(self) -> int:
        return len(self.tokens)


class PrefixCache:
    """Radix index of resident page chains for ONE decode engine's pool
    (pages are pool-local ids; every decode replica indexes its own)."""

    def __init__(self, page_size: int, owner=PREFIX_OWNER):
        self.page_size = page_size
        self.owner = owner
        self._root = _Node((), (), -1, None, ())
        self._clock = 0
        # counters (hit/partial/miss are tallied where routing happens —
        # the gateway — since one prompt may probe several replicas)
        self.n_entries = 0
        self.donations = 0
        self.adopted_pages = 0
        self.upgrades = 0
        self.evictions = 0

    # ------------------------------------------------------------------
    # lookup
    # ------------------------------------------------------------------

    def _touch(self, node: _Node):
        self._clock += 1
        while node is not None and node is not self._root:
            node.stamp = self._clock
            node = node.parent

    def match(self, tokens: Sequence[int]) -> Optional[PrefixMatch]:
        """Longest resident prefix of ``tokens``; None on a miss."""
        toks = list(tokens)
        n = len(toks)
        ps = self.page_size
        node, pages, k = self._root, [], 0
        while True:
            rem = n - k * ps
            if rem == 0:
                nt = self._continuation(node)
                if nt is not None:
                    self._touch(node)
                    return PrefixMatch(n, pages, True, nt)
                break
            if rem >= ps:
                blk = tuple(toks[k * ps:(k + 1) * ps])
                child = node.children.get(blk)
                if child is not None:
                    node = child
                    pages.append(child.page)
                    k += 1
                    continue
            if 0 < rem < ps:
                part = tuple(toks[k * ps:])
                hit = self._tail_hit(node, part)
                if hit is not None:
                    page, nt, leaf = hit
                    self._touch(leaf)
                    return PrefixMatch(n, pages + [page], True, nt)
            break
        # partial: page-aligned, and leave >= 1 token for the suffix prefill
        if k * ps >= n:
            k -= 1
            pages.pop()
        if k == 0:
            return None
        self._touch(node)
        return PrefixMatch(k * ps, pages, False, None)

    def _continuation(self, node: _Node) -> Optional[int]:
        """Trusted next token after a prompt ending at ``node``'s
        boundary: a generated-flagged first token of any child edge, or
        the donor's trailing emitted token."""
        if node is self._root:
            return None
        for child in node.children.values():
            if child.gen and child.gen[0]:
                return child.tokens[0]
        if node.tail_token is not None and node.tail_gen:
            return node.tail_token
        return None

    def _tail_hit(self, node: _Node, part: Tuple[int, ...]):
        """Full-hit probe for a prompt ending ``len(part)`` tokens into a
        child edge. Returns (page, next_token, node) or None."""
        r = len(part)
        for child in node.children.values():
            if child.n_valid >= r and child.tokens[:r] == part:
                if child.n_valid > r:
                    if child.gen[r]:
                        return child.page, child.tokens[r], child
                elif child.tail_token is not None and child.tail_gen:
                    return child.page, child.tail_token, child
        return None

    # ------------------------------------------------------------------
    # donation
    # ------------------------------------------------------------------

    def insert(self, tokens: Sequence[int], kv_len: int,
               pages: Sequence[int], gen_from: int, pool: PagePool) -> int:
        """Donate a finished request's chain: ``tokens`` is the full
        sequence (prompt + emitted tokens, possibly one token past the
        resident KV), ``pages`` the chain covering ``kv_len`` resident
        positions, ``gen_from`` the index of the first generated token.
        Blocks already in the tree keep their existing pages (the donor's
        duplicates are simply not adopted; the caller frees its refs as
        usual); new blocks are adopted via ``pool.share`` under the
        tree's owner tag. Returns the number of pages adopted."""
        ps = self.page_size
        kv_len = min(kv_len, len(tokens))
        n_blocks = pages_needed(kv_len, ps) if kv_len > 0 else 0
        node, adopted = self._root, 0
        for j in range(n_blocks):
            lo, hi = j * ps, min((j + 1) * ps, kv_len)
            blk = tuple(tokens[lo:hi])
            gen = tuple(i >= gen_from for i in range(lo, hi))
            page = pages[j]
            if node is not self._root and node.n_valid < ps:
                break  # a partial node can't have children; chain ends
            child = node.children.get(blk) if len(blk) == ps else None
            if child is None:
                child = self._find_covering(node, blk)
            if child is not None:
                # token-identical overlap: OR-merge generated flags
                child.gen = tuple(
                    a or b for a, b in zip(child.gen, gen)
                ) + child.gen[len(gen):]
                node = child
                continue
            ext = self._find_extensible(node, blk)
            if ext is not None:
                # donor's block extends an existing partial leaf: swap to
                # the donor's longer page (old page released by the tree;
                # in-flight sharers keep their own refs on it)
                pool.share([page], self.owner)
                pool.unshare([ext.page], self.owner)
                del node.children[ext.key]
                merged = tuple(a or b for a, b in zip(ext.gen, gen))
                ext.tokens, ext.page = blk, page
                ext.gen = merged + gen[len(merged):]
                ext.key = blk
                ext.tail_token, ext.tail_gen = None, False
                node.children[blk] = ext
                self.upgrades += 1
                adopted += 1
                node = ext
                continue
            pool.share([page], self.owner)
            fresh = _Node(blk, gen, page, node, blk)
            node.children[blk] = fresh
            self.n_entries += 1
            adopted += 1
            node = fresh
        # trailing emitted token past the resident KV: continuation hint
        if node is not self._root and kv_len < len(tokens):
            end = (n_blocks - 1) * ps + node.n_valid
            if end == kv_len:
                t = int(tokens[kv_len])
                if node.tail_token is None or (not node.tail_gen
                                               and kv_len >= gen_from):
                    node.tail_token = t
                    node.tail_gen = kv_len >= gen_from
        self._touch(node)
        self.donations += 1
        self.adopted_pages += adopted
        return adopted

    def _find_covering(self, node: _Node, blk: Tuple[int, ...]):
        """An existing child whose tokens start with ``blk`` (the donor
        adds nothing new for this block)."""
        for child in node.children.values():
            if child.n_valid >= len(blk) and child.tokens[:len(blk)] == blk:
                return child
        return None

    def _find_extensible(self, node: _Node, blk: Tuple[int, ...]):
        """An existing partial leaf that ``blk`` strictly extends."""
        for child in node.children.values():
            if 0 < child.n_valid < len(blk) \
                    and blk[:child.n_valid] == child.tokens:
                return child
        return None

    # ------------------------------------------------------------------
    # eviction / teardown
    # ------------------------------------------------------------------

    def _evictable_leaves(self, pool: PagePool) -> List[_Node]:
        out: List[_Node] = []

        def walk(node: _Node):
            for child in node.children.values():
                if child.children:
                    walk(child)
                elif pool.owners_of(child.page) == {self.owner}:
                    out.append(child)

        walk(self._root)
        return out

    def evict(self, pool: PagePool, n_pages: int) -> int:
        """Free up to ``n_pages`` pages, LRU-first, dropping only leaves
        whose page nobody shares (refcount 0 from outside the tree).
        Returns the number of pages actually freed."""
        freed = 0
        while freed < n_pages:
            leaves = self._evictable_leaves(pool)
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: nd.stamp)
            del victim.parent.children[victim.key]
            pool.unshare([victim.page], self.owner)
            self.n_entries -= 1
            self.evictions += 1
            freed += 1
        return freed

    def clear(self, pool: PagePool) -> int:
        """Drop every entry (replica drain / phase flip): release the
        tree's reference on all pages; externally shared pages survive
        under their other owners."""
        pages = self.page_set()
        if pages:
            pool.unshare(sorted(pages), self.owner)
        n = self.n_entries
        self._root = _Node((), (), -1, None, ())
        self.n_entries = 0
        return n

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    def page_set(self) -> set:
        out = set()

        def walk(node: _Node):
            for child in node.children.values():
                out.add(child.page)
                walk(child)

        walk(self._root)
        return out

    def stats(self) -> Dict[str, int]:
        return {"entries": self.n_entries, "pages": len(self.page_set()),
                "donations": self.donations,
                "adopted_pages": self.adopted_pages,
                "upgrades": self.upgrades, "evictions": self.evictions}
