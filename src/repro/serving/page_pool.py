"""Host-side page allocator for the paged decode cache + KV-wire insertion.

The device-side page format lives in ``models/paged.py``; this module owns
the HOST bookkeeping: which pages are free, which slot owns which pages,
occupancy/fragmentation stats, and the scatter of an arriving ``KVWire``
into a slot's freshly allocated pages.

Key property (the reason the wire and the pages share one quantization
layout): a wire produced by the bucketed-prefill fast path
(``kv_transfer.extract_batch(pad_to=...)``) carries POSITION-ALIGNED int4
groups — row ``t*ppr + r`` is token ``t``'s r-th group, exactly a page's
row order — so insertion is a pure uint8/f32 scatter: **no dequantization,
no requantization, no 16-bit materialization**. Wires with position-
spanning groups (exact-length extracts pick the group from the flattened
size) or raw payloads are re-encoded into the page layout on arrival (one
dequant+quant, still never touching the dense cache layout).

Page 0 is reserved as the TRASH page (see ``models/paged.py``); the
allocator never hands it out.

The :class:`PagePool` allocator itself is PURE PYTHON — the jax/numpy
imports the insertion/extraction functions need are deferred into those
functions, so the model checker (``repro.analysis.modelcheck``, tier-1
CI) can drive the REAL refcount protocol in an image with no accelerator
stack installed.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, Hashable, List, Optional, \
    Sequence, Set, Tuple

if TYPE_CHECKING:       # heavy imports stay lazy (see module docstring)
    from repro.serving.kv_transfer import KVWire, WireTensor


class PagePool:
    """Fixed-size-page allocator over ``num_pages`` pages (page 0 is the
    trash page and is never allocated). LIFO free list: a released
    request's pages are the next handed out, which keeps the hot page set
    small.

    Pages are REFCOUNTED: ``alloc`` grants the first reference,
    ``share`` adds one for a new owner (prefix-cache sharing), and
    ``free``/``unshare`` drop one owner's reference — a page returns to
    the free list only when its last owner releases it. Owner tags are
    arbitrary hashables (slot indices, the prefix cache's tag, in-flight
    pin tags); an owner holds at most one reference per page. All
    refcount/free-list mutation lives HERE (lint rule R006) — everything
    else goes through this API.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._owners: Dict[int, Set[Hashable]] = {}   # page -> owner tags
        self._by_owner: Dict[Hashable, Set[int]] = {}  # owner -> its pages
        # stats
        self.allocs = 0
        self.frees = 0
        self.shares = 0
        self.unshares = 0
        self.alloc_failures = 0
        self.peak_in_use = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_in_use(self) -> int:
        return len(self._owners)

    @property
    def n_shared(self) -> int:
        """Pages currently held by more than one owner."""
        return sum(1 for s in self._owners.values() if len(s) > 1)

    @property
    def capacity(self) -> int:
        """Allocatable pages (total minus the trash page)."""
        return self.num_pages - 1

    def owned_by(self, owner: Hashable) -> List[int]:
        """Pages this owner holds a reference on — O(pages held), via the
        per-owner index (not a scan of the whole pool)."""
        return sorted(self._by_owner.get(owner, ()))

    def owners_of(self, page: int) -> frozenset:
        return frozenset(self._owners.get(page, frozenset()))

    def refcount(self, page: int) -> int:
        return len(self._owners.get(page, ()))

    def pages_in_use(self) -> List[int]:
        return sorted(self._owners)

    def _grant(self, page: int, owner: Hashable):
        self._owners.setdefault(page, set()).add(owner)
        self._by_owner.setdefault(owner, set()).add(page)

    def _revoke(self, page: int, owner: Hashable) -> bool:
        """Drop one owner's reference; True when the page became free."""
        owners = self._owners[page]
        owners.discard(owner)
        held = self._by_owner.get(owner)
        if held is not None:
            held.discard(page)
            if not held:
                del self._by_owner[owner]
        if owners:
            return False
        del self._owners[page]
        self._free.append(page)
        return True

    def alloc(self, n: int, owner: Hashable) -> Optional[List[int]]:
        """Take ``n`` pages for ``owner`` (a slot index); None if the pool
        cannot satisfy the request — all-or-nothing, no partial grants."""
        if n <= 0:
            return []
        if n > len(self._free):
            self.alloc_failures += 1
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._grant(p, owner)
        self.allocs += n
        self.peak_in_use = max(self.peak_in_use, self.n_in_use)
        return pages

    def share(self, pages: Sequence[int], owner: Hashable):
        """Add ``owner``'s reference to already-allocated pages (prefix
        sharing). Batch-validated before any mutation: every page must be
        in use and not already held by this owner."""
        seen: Set[int] = set()
        for p in pages:
            if p in seen:
                raise ValueError(f"page {p} listed twice in one share()")
            seen.add(p)
            owners = self._owners.get(p)
            if owners is None:
                raise ValueError(f"share of free/foreign page {p}")
            if owner in owners:
                raise ValueError(f"owner {owner!r} already holds page {p}")
        for p in pages:
            self._grant(p, owner)
        self.shares += len(seen)

    def free(self, pages: Sequence[int], owner: Optional[Hashable] = None):
        """Release ``owner``'s reference on each page; a page returns to
        the free list only when its refcount hits 0. With ``owner=None``
        (legacy single-owner call) each page must have exactly one owner.
        Validates the WHOLE batch before mutating anything, so a bad call
        (double free, page listed twice, page not held by ``owner``)
        raises without corrupting the free list with a partial free."""
        seen: Set[int] = set()
        for p in pages:
            if p in seen:
                raise ValueError(f"page {p} listed twice in one free()")
            seen.add(p)
            owners = self._owners.get(p)
            if owners is None:
                raise ValueError(f"double free / foreign page {p}")
            if owner is None:
                if len(owners) != 1:
                    raise ValueError(
                        f"page {p} is shared by {sorted(map(repr, owners))}; "
                        f"free() needs an explicit owner")
            elif owner not in owners:
                if len(owners) == 1:
                    raise ValueError(
                        f"page {p} is owned by slot {next(iter(owners))}, "
                        f"not {owner!r}")
                raise ValueError(
                    f"page {p} is shared by {sorted(map(repr, owners))}; "
                    f"{owner!r} holds no reference")
        for p in pages:
            o = owner if owner is not None else next(iter(self._owners[p]))
            if self._revoke(p, o):
                self.frees += 1
            else:
                self.unshares += 1

    def unshare(self, pages: Sequence[int], owner: Hashable):
        """Drop ``owner``'s reference on shared pages — same release path
        as :meth:`free` (a page whose last reference drops goes back to
        the free list), with the owner always explicit."""
        self.free(pages, owner=owner)

    def snapshot(self):
        """Opaque deep copy of the allocator state (free list, refcount
        maps, counters) — with :meth:`restore`, the ONE sanctioned way to
        branch/rewind pool state from outside this module (rule R006:
        the model checker forks states without touching internals)."""
        return (list(self._free),
                {p: set(o) for p, o in self._owners.items()},
                {o: set(p) for o, p in self._by_owner.items()},
                (self.allocs, self.frees, self.shares, self.unshares,
                 self.alloc_failures, self.peak_in_use))

    def restore(self, snap):
        """Rewind to a :meth:`snapshot` (same pool geometry assumed)."""
        free, owners, by_owner, counters = snap
        self._free = list(free)
        self._owners = {p: set(o) for p, o in owners.items()}
        self._by_owner = {o: set(p) for o, p in by_owner.items()}
        (self.allocs, self.frees, self.shares, self.unshares,
         self.alloc_failures, self.peak_in_use) = counters

    def canonicalize(self):
        """Sort the free list into a fixed order. Free-list order is
        semantically irrelevant (any free page serves any alloc) but LIFO
        recycling makes :meth:`state_key` distinguish states that differ
        only by allocation history — the model checker calls this after
        every event as a symmetry reduction, collapsing those
        permutations so its search closes."""
        self._free.sort()

    def state_key(self) -> Tuple:
        """Hashable identity of the allocator state (owner tags by repr)
        — the model checker's visited-state key."""
        return (tuple(self._free),
                tuple(sorted((p, tuple(sorted(repr(o) for o in owners)))
                             for p, owners in self._owners.items())))

    def occupancy(self) -> float:
        return self.n_in_use / max(self.capacity, 1)

    def stats(self) -> Dict[str, float]:
        return {"pages": self.capacity, "page_size": self.page_size,
                "in_use": self.n_in_use, "free": self.n_free,
                "occupancy": self.occupancy(),
                "peak_in_use": self.peak_in_use, "allocs": self.allocs,
                "frees": self.frees, "shares": self.shares,
                "unshares": self.unshares, "shared_pages": self.n_shared,
                "alloc_failures": self.alloc_failures}


def pages_needed(n_tokens: int, page_size: int) -> int:
    return max(1, -(-n_tokens // page_size))


def _wire_rows_aligned(wt: WireTensor, g: int, ppr: int) -> bool:
    """True when an int4 wire tensor's quantization rows map 1:1 onto page
    rows: same group width, groups never straddling token positions."""
    if wt.kind != "int4":
        return False
    L, ln, Hkv, hd = wt.orig_shape
    packed = wt.payload["packed"]
    return (packed.shape[1] == g // 2
            and packed.shape[0] == L * ln * ppr)


def _wire_to_rows(wt: WireTensor, cfg, backend: str):
    """Return (packed, scale, zero) rows in page row-order for one wire
    tensor — zero-copy when the wire layout already matches, otherwise
    re-encoded via one dequant+quant (device ops, no host sync)."""
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.models import paged
    from repro.serving.kv_transfer import _dequantize
    g = paged.page_group(cfg)
    ppr = paged.groups_per_token(cfg)
    if _wire_rows_aligned(wt, g, ppr):
        return (jnp.asarray(wt.payload["packed"]),
                jnp.asarray(wt.payload["scale"]),
                jnp.asarray(wt.payload["zero"]), True)
    dense = _dequantize(wt, backend)               # (L, ln, Hkv, hd)
    L, ln = dense.shape[:2]
    rows = dense.reshape(L * ln * ppr, g)
    packed, scale, zero = ops.kv_quant(rows, backend=backend)
    return packed, scale, zero, False


def insert_wires(cache, cfg, items: Sequence[Tuple], *,
                 backend: str = "auto"):
    """Scatter transferred requests into their allocated pages.

    ``items`` = (wire, slot_index, pages) with ``pages`` already allocated
    by the :class:`PagePool` (``len(pages) >= ceil(len/page_size)``; the
    tail of the last page absorbs decode appends). A 4th element
    ``prefix_pages`` SPLICES the wire onto a shared, page-aligned prefix
    chain: the wire carries only the suffix (token 0 of the wire is
    absolute position ``len(prefix_pages) * page_size``), the page-table
    row becomes ``prefix_pages + pages``, and lengths cover the whole
    chain. Prefix pages are never written — only the suffix scatter and
    decode's tail appends touch pages here. Updates page-table rows and
    lengths. Returns (cache, n_zero_copy, n_reencoded) — the counters
    feed the bench's zero-dequant claim."""
    import jax.numpy as jnp
    import numpy as np

    from repro.models import paged
    from repro.serving.kv_transfer import _dequantize
    int4 = "kp" in cache["slot0"]
    ps = cache_page_size(cache, cfg)
    ppr = paged.groups_per_token(cfg)
    g = paged.page_group(cfg)
    W = cache["page_table"].shape[1]
    n_zero, n_reenc = 0, 0
    for item in items:
        wire, slot, pages = item[0], item[1], item[2]
        prefix = list(item[3]) if len(item) > 3 and item[3] else []
        prefix_len = len(prefix) * ps
        ln = wire.request_len
        need = pages_needed(ln, ps)
        if len(pages) < need or len(prefix) + need > W:
            raise ValueError(
                f"slot {slot}: {len(pages)} page(s) for a {ln}-token wire "
                f"(needs {need}, prefix {len(prefix)}, table width {W})")
        tpos = np.arange(ln)
        dst_page = np.asarray(pages, np.int32)[tpos // ps]          # (ln,)
        for name, slot_wire in wire.slots.items():
            buf = cache[name]
            for key, base in (("k", "k"), ("v", "v")):
                wt = slot_wire.get(key)
                if wt is None:
                    continue
                if int4:
                    packed, scale, zero, aligned = _wire_to_rows(
                        wt, cfg, backend)
                    n_zero += int(aligned)
                    n_reenc += int(not aligned)
                    L = wt.orig_shape[0]
                    rows = ((tpos % ps)[:, None] * ppr
                            + np.arange(ppr)[None])                 # (ln,ppr)
                    pg = dst_page[:, None]
                    for suffix, val, width in (
                            ("p", packed, g // 2), ("s", scale, 1),
                            ("z", zero, 1)):
                        dst = buf[base + suffix]
                        cache[name][base + suffix] = dst.at[:, pg, rows].set(
                            val.reshape(L, ln, ppr, width).astype(dst.dtype))
                else:
                    dense = _dequantize(wt, backend)     # (L, ln, Hkv, hd)
                    dst = buf[base]
                    cache[name][base] = dst.at[
                        :, dst_page, tpos % ps].set(dense.astype(dst.dtype))
        row = np.zeros((W,), np.int32)                   # rest -> trash
        chain = prefix + list(pages)
        row[:len(chain)] = chain
        cache["page_table"] = cache["page_table"].at[slot].set(
            jnp.asarray(row))
        cache["lengths"] = cache["lengths"].at[slot].set(prefix_len + ln)
    return cache, n_zero, n_reenc


def set_page_chain(cache, slot: int, pages: Sequence[int], length: int):
    """Point a slot's page-table row at an existing page chain (the full
    prefix-hit admission: every token is already resident, nothing is
    scattered). Row tail stays at the trash page."""
    import jax.numpy as jnp
    import numpy as np
    W = cache["page_table"].shape[1]
    if len(pages) > W:
        raise ValueError(f"chain of {len(pages)} pages exceeds table "
                         f"width {W}")
    row = np.zeros((W,), np.int32)
    row[:len(pages)] = pages
    cache["page_table"] = cache["page_table"].at[slot].set(jnp.asarray(row))
    cache["lengths"] = cache["lengths"].at[slot].set(length)
    return cache


def copy_page(cache, src_page: int, dst_page: int):
    """Device-copy one page's contents across every layer tensor — the
    copy-on-write step before a slot appends to a page it shares. Pure
    data movement; refcount bookkeeping stays in :class:`PagePool`."""
    for name, buf in cache.items():
        if name in ("page_table", "lengths"):
            continue
        for key, a in buf.items():
            cache[name][key] = a.at[:, dst_page].set(a[:, src_page])
    return cache


def extract_slot_wire(cache, cfg, ln: int, pages: Sequence[int],
                      ) -> KVWire:
    """Gather one resident slot's pages back into a :class:`KVWire`
    (the decode->decode migration path of ``Gateway.handle_preemption``).

    For the int4 residency this is a pure page gather — the pages already
    hold the wire's position-aligned group encoding, so the produced
    tensors pass ``_wire_rows_aligned`` and scatter zero-copy into the
    destination pool (no dequant/requant round-trip in either direction).
    The bf16 residency ships raw tokens. Tokens appended by the in-loop
    decode quantizer extract bit-identically to wire-inserted ones
    (``models/paged.py`` keeps the two paths on the same kernel math).
    """
    import numpy as np

    from repro.models import paged
    from repro.serving.kv_transfer import KVWire, WireTensor
    int4 = "kp" in cache["slot0"]
    ps = cache_page_size(cache, cfg)
    ppr = paged.groups_per_token(cfg)
    g = paged.page_group(cfg)
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    if pages_needed(ln, ps) > len(pages):
        raise ValueError(f"{ln}-token slot spans more than {len(pages)} "
                         f"page(s)")
    pg = np.asarray(pages, np.int32)
    slots: Dict[str, Dict[str, WireTensor]] = {}
    for name, buf in cache.items():
        if name in ("page_table", "lengths"):
            continue
        out: Dict[str, WireTensor] = {}
        for key, base in (("k", "k"), ("v", "v")):
            if int4:
                payload = {}
                for suffix, wkey, width in (("p", "packed", g // 2),
                                            ("s", "scale", 1),
                                            ("z", "zero", 1)):
                    a = buf[base + suffix][:, pg]        # (L, n_pg, R, w)
                    L = a.shape[0]
                    payload[wkey] = a.reshape(
                        L, len(pages) * ps, ppr, width)[:, :ln].reshape(
                        -1, width)
                out[key] = WireTensor("int4", payload, (L, ln, Hkv, hd))
            else:
                a = buf[base][:, pg]                 # (L, n_pg, ps, Hkv, hd)
                L = a.shape[0]
                t = a.reshape(L, len(pages) * ps, Hkv, hd)[:, :ln]
                out[key] = WireTensor("raw", {"x": t}, tuple(t.shape),
                                      str(t.dtype))
        slots[name] = out
    return KVWire(request_len=ln, slots=slots)


def release_slot(cache, slot: int):
    """Point a released slot's table row back at the trash page and zero
    its length (the pages themselves go back through ``PagePool.free``)."""
    cache["page_table"] = cache["page_table"].at[slot].set(0)
    cache["lengths"] = cache["lengths"].at[slot].set(0)
    return cache


def cache_page_size(cache, cfg) -> int:
    """Recover page_size from the cache shapes (token rows per page)."""
    from repro.models import paged
    slot = cache["slot0"]
    if "kp" in slot:
        return slot["kp"].shape[2] // paged.groups_per_token(cfg)
    return slot["k"].shape[2]
