"""The serving stack's protocol contracts, as executable data.

This module is the SINGLE SOURCE OF TRUTH for three protocols the rest of
the stack implements and the model checker (``repro.analysis.modelcheck``)
exhaustively explores:

* the request lifecycle transition table (:data:`TRANSITIONS`) — the
  gateway's ``RequestHandle._transition`` validates against exactly this
  object (``gateway._TRANSITIONS is protocol.TRANSITIONS``), so the
  checker and the running code cannot drift: an edge removed here breaks
  both the same way, and the checker's counterexample names the event
  that needed it;
* the retire ordering (:func:`retire_steps`) — a finished slot's chain is
  DONATED to the prefix index before its references go back to the pool
  (free-before-donate hands the index pages that are already on the free
  list: silent KV corruption the first time they are re-allocated);
* the chunked-prefill advance rules (:func:`chunk_take`,
  :func:`chunk_complete`, :func:`chunk_extract_compress`) and the
  copy-on-write boundary (:func:`cow_boundary`, :func:`cow_needed`) —
  the arithmetic ``prefill_chunk`` / ``_admit_one_prefix`` execute, bound
  here so the checker's abstract models run the SAME decision code as the
  engines.

Stdlib only, imports nothing: the tier-1 ``modelcheck --quick`` CI step
runs it in an image where jax is not even installed. Keep it that way.

Note the runtime sanitizer (``repro.analysis.sanitizers``) keeps its OWN
independent copy of the lifecycle table on purpose — a drive-by edit here
trips the audit there; ``modelcheck`` cross-checks the two for drift.
"""
from __future__ import annotations

from typing import Dict, Tuple

# -- request lifecycle --------------------------------------------------------

QUEUED = "QUEUED"
PREFILLING = "PREFILLING"
TRANSFERRING = "TRANSFERRING"
DECODING = "DECODING"
DONE = "DONE"
CANCELLED = "CANCELLED"
REJECTED = "REJECTED"
FAILED = "FAILED"

TERMINAL_STATES = frozenset({DONE, CANCELLED, REJECTED, FAILED})

TRANSITIONS: Dict[str, frozenset] = {
    # QUEUED -> TRANSFERRING: full prefix-cache hit — every prompt
    # token's KV is already resident on a decode replica, so prefill is
    # skipped and the "transfer" is a page handle (DESIGN.md §10)
    QUEUED: frozenset({PREFILLING, TRANSFERRING, CANCELLED, REJECTED,
                       FAILED}),
    # PREFILLING -> QUEUED: the prefill replica crashed mid-batch
    PREFILLING: frozenset({TRANSFERRING, QUEUED, CANCELLED, FAILED}),
    TRANSFERRING: frozenset({DECODING, QUEUED, CANCELLED, FAILED}),
    # DECODING -> TRANSFERRING: mid-stream KV migration off a preempted
    # decode replica (handle_preemption)
    DECODING: frozenset({DONE, QUEUED, TRANSFERRING, CANCELLED, FAILED}),
    DONE: frozenset(), CANCELLED: frozenset(),
    REJECTED: frozenset(), FAILED: frozenset(),
}


def legal(src: str, dst: str) -> bool:
    """True when ``src -> dst`` is an edge of the lifecycle machine."""
    return dst in TRANSITIONS.get(src, frozenset())


# -- retire ordering (page donation vs. free) ---------------------------------


def retire_steps(donate: bool) -> Tuple[str, ...]:
    """Ordered operations for retiring a finished slot's page chain.

    ``("donate", "free")``: the prefix index takes its references on the
    chain (``PrefixCache.insert`` -> ``pool.share``) while the slot still
    holds its own, THEN the slot's references are released. Reversing the
    order frees the chain first, and the donation shares pages that are
    already back on the free list — ``PagePool.share`` raises "share of
    free/foreign page", which is exactly the counterexample the checker's
    pool model produces for the mutated ordering."""
    return ("donate", "free") if donate else ("free",)


# -- copy-on-write boundary (prefix-hit admission) ----------------------------


def cow_boundary(prompt_len: int, page_size: int, table_w: int) -> int:
    """Chain index of the page the NEXT decode append writes into, for a
    full prefix hit of ``prompt_len`` tokens (clamped to the table)."""
    return min(prompt_len // page_size, table_w - 1)


def cow_needed(prompt_len: int, page_size: int, table_w: int,
               chain_len: int) -> bool:
    """True when the append page is part of the SHARED chain and must be
    copy-on-write duplicated before this slot may write to it. Skipping
    the duplication (e.g. an off-by-one that exempts the tail page) lets
    the new stream append into KV that other readers still decode from."""
    return cow_boundary(prompt_len, page_size, table_w) < chain_len


# -- chunked-prefill advance --------------------------------------------------


def chunk_take(remaining: int, budget_left: int,
               supports_suffix: bool) -> int:
    """Prompt tokens the next chunk covers. Engines that cannot slice
    state at a position boundary (recurrent state, SWA) must run the
    whole remainder in one shot — the budget degrades to an admission
    hint."""
    return min(remaining, budget_left) if supports_suffix else remaining


def chunk_complete(next_pos: int, prompt_len: int) -> bool:
    """True when a chunked-prefill job has covered the whole prompt and
    may be admitted. Admitting earlier ships a wire that is missing the
    tail of the prompt's KV."""
    return next_pos >= prompt_len


def chunk_extract_compress() -> bool:
    """Whether per-chunk extraction quantizes (it must NOT): chunk wires
    stay RAW so the resumable prefix is the exact float KV a one-shot
    prefill computes; quantization happens ONCE over the spliced whole at
    completion (lint rule R007 enforces the same invariant statically)."""
    return False
