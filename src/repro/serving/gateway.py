"""Serving gateway (paper Appendix E as a *service* API).

The public surface of the serving stack: ``Gateway.submit(ServeRequest)``
returns a :class:`RequestHandle` carrying an explicit request lifecycle

    QUEUED -> PREFILLING -> TRANSFERRING -> DECODING -> DONE
       |          |              |            |    ^
       |          |              |            |    | (KV page migration,
       |          |              |            v    |  preemption drain)
       |          +--------------+---> QUEUED + TRANSFERRING
       |               (replica failure / retry exhaustion)
       +---> TRANSFERRING   (full prefix-cache hit: prefill skipped, the
                             "wire" is a page handle on the target replica)
    any non-terminal -> CANCELLED / REJECTED / FAILED

with streaming token delivery (callback and iterator), ``cancel()``,
per-request deadline/priority, and admission control that sheds requests
whose TTFT deadline is provably missed while still queued.

Fault tolerance (DESIGN.md §8): an injectable ``clock`` makes every
timestamp deterministic under test; transient transport errors retry
with bounded exponential backoff + jitter before falling back to
requeue-through-prefill; the failure detector distinguishes
suspected-slow (kept out of routing, still stepped) from confirmed-dead
(recovered + epoch reschedule via ``set_failover``); and
``handle_preemption`` drains a spot-preempted decode replica by
migrating its resident KV page-granular over the transport to surviving
replicas within the grace window.

Replicas are reached only through the narrow :class:`PrefillClient` /
:class:`DecodeClient` interfaces and KV state moves only through a
:class:`~repro.serving.transport.Transport`, so a multi-host RPC
realization (each replica = a pod slice driven over the wire) slots in
without touching routing, heartbeats, or rescheduling logic
(DESIGN.md §5).

Scheduling (DESIGN.md §11): each ``pump()`` is one token-budget tick —
with ``SchedulerConfig.prefill_chunk_tokens > 0`` pending prefill runs
as fixed-token CHUNKS (SARATHI-style continuous batching: a long prompt
no longer head-of-line-blocks TTFT for everyone behind it) interleaved
with one decode chunk per replica, and admissions/evictions happen at
the chunk boundary in between.
"""
from __future__ import annotations

import math
import os
import random as _random
import time
from dataclasses import dataclass, field
from typing import (Callable, Dict, Iterator, List, Optional, Protocol,
                    Sequence, Tuple)

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import scheduler as sched
from repro.core.orchestrator import Orchestration, SloSpec
from repro.serving.engine import (ADMIT_CHUNKED, ADMIT_FRESH,
                                  ADMIT_MIGRATED, ADMIT_PREFIX_HIT,
                                  AdmissionBatch, AdmissionItem,
                                  DecodeEngine, GenRequest, PartialPrefill,
                                  PrefillEngine, Replica)
from repro.serving.faults import (ReplicaCrashError, RetryPolicy,
                                  TransientTransportError)
from repro.serving.kv_transfer import KVWire
from repro.serving.profiler import WorkloadProfiler
from repro.serving.transport import (InProcessTransport, TransferTicket,
                                     Transport)

# -- request lifecycle --------------------------------------------------------
#
# The states and the transition table live in ``serving/protocol.py`` (the
# stdlib-only contract module the model checker binds to); this module
# re-exports them under their historical names. ``_TRANSITIONS`` is the
# SAME object the checker explores — no hand-copied table, so the two
# cannot drift (repro.analysis.modelcheck, DESIGN.md §12).

from repro.serving.protocol import (CANCELLED, DECODING, DONE, FAILED,
                                    PREFILLING, QUEUED, REJECTED,
                                    TERMINAL_STATES, TRANSFERRING)
from repro.serving.protocol import TRANSITIONS as _TRANSITIONS


@dataclass
class ServeRequest:
    """What a client submits: prompt + generation budget + SLO terms."""
    rid: int
    tokens: np.ndarray                   # prompt token ids (1D)
    max_new_tokens: int
    extras: Dict[str, np.ndarray] = field(default_factory=dict)
    priority: int = 0                    # higher dispatches first
    ttft_deadline_s: float = math.inf    # relative to submit time
    e2e_deadline_s: float = math.inf

    @classmethod
    def from_gen(cls, req: GenRequest, **kw) -> "ServeRequest":
        return cls(req.rid, req.tokens, req.max_new_tokens,
                   extras=req.extras, **kw)


class RequestHandle:
    """Live view of one request's journey through the gateway.

    Tokens stream into :attr:`tokens` as decode chunks complete (first
    tokens are observable long before ``run_until_drained`` returns);
    ``on_token`` fires per delivered token. Terminal state, a
    human-readable :attr:`reason` for REJECTED/FAILED, and per-request
    TTFT/TPOT/E2E metrics live here — engines never mutate timestamps.
    """

    def __init__(self, request: ServeRequest, gen: GenRequest,
                 gateway: "Gateway",
                 on_token: Optional[Callable[["RequestHandle", int], None]]
                 = None):
        self.request = request
        self.req = gen                   # engine-level unit (owns out_tokens)
        self.tokens: List[int] = []
        self.on_token = on_token
        self.state = QUEUED
        self.reason: Optional[str] = None
        self.restarts = 0
        self.t_submit = gateway.clock()
        self.t_first = -1.0
        self.t_done = -1.0
        self.history: List[Tuple[float, str]] = [(self.t_submit, QUEUED)]
        self._gateway = gateway
        self._engine_seen = 0     # out_tokens consumed from current attempt

    # -- state machine ------------------------------------------------------

    @property
    def is_terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    def _transition(self, state: str, now: Optional[float] = None,
                    reason: Optional[str] = None):
        if state == self.state:
            return
        if state not in _TRANSITIONS[self.state]:
            raise RuntimeError(f"illegal transition {self.state} -> {state} "
                               f"for request {self.request.rid}")
        if state in (FAILED, REJECTED) and reason is None:
            raise ValueError(f"{state} requires a reason "
                             f"(request {self.request.rid})")
        now = now if now is not None else self._gateway.clock()
        self.state = state
        self.history.append((now, state))
        if reason is not None:
            self.reason = reason
        if state == DONE:
            self.t_done = now

    def _deliver(self, toks: Sequence[int], now: float):
        for t in toks:
            if self.t_first < 0:
                self.t_first = now
            self.tokens.append(int(t))
            if self.on_token is not None:
                self.on_token(self, int(t))

    def _requeue(self, now: float):
        """Decode-replica failure: KV is gone, go back through prefill.
        The DECODING -> QUEUED edge stays visible in :attr:`history`.
        Already-delivered tokens are KEPT — the restarted attempt's
        regenerated prefix is suppressed in ``_sync_tokens`` so streaming
        consumers never see a duplicate (greedy decoding regenerates the
        identical prefix)."""
        self._transition(QUEUED, now)
        self.restarts += 1
        self.req.out_tokens = []
        self._engine_seen = 0

    # -- client API ---------------------------------------------------------

    def cancel(self) -> bool:
        """Abort the request; frees its decode slot if mid-decode."""
        return self._gateway.cancel(self)

    def stream(self, *, max_iters: int = 100000) -> Iterator[int]:
        """Yield tokens as they are produced, pumping the gateway while
        waiting. Returns when the request reaches a terminal state."""
        sent = 0
        it = 0
        while True:
            while sent < len(self.tokens):
                yield self.tokens[sent]
                sent += 1
            if self.is_terminal:
                return
            if it >= max_iters:
                raise RuntimeError(f"request {self.request.rid} stalled in "
                                   f"{self.state}")
            self._gateway.pump()
            it += 1

    def result(self, *, max_iters: int = 100000) -> List[int]:
        """Block (pumping the gateway) until terminal; returns the tokens."""
        for _ in self.stream(max_iters=max_iters):
            pass
        return list(self.tokens)

    # -- metrics ------------------------------------------------------------

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_submit if self.t_first > 0 else math.nan

    @property
    def e2e(self) -> float:
        return self.t_done - self.t_submit if self.t_done > 0 else math.nan

    @property
    def tpot(self) -> float:
        if self.t_done < 0 or self.t_first < 0 or len(self.tokens) <= 1:
            return math.nan
        return (self.t_done - self.t_first) / (len(self.tokens) - 1)

    def metrics(self) -> Dict[str, object]:
        r = self.request
        return {"rid": r.rid, "state": self.state, "reason": self.reason,
                "n_tokens": len(self.tokens), "restarts": self.restarts,
                "ttft_s": self.ttft, "tpot_s": self.tpot, "e2e_s": self.e2e,
                "ttft_met": (self.state == DONE
                             and self.ttft <= r.ttft_deadline_s),
                "e2e_met": (self.state == DONE
                            and self.e2e <= r.e2e_deadline_s)}


# -- scheduler configuration --------------------------------------------------


@dataclass(frozen=True)
class SchedulerConfig:
    """Token-budget scheduler knobs in ONE place (replaces the scattered
    ``pump(max_prefill_batch=...)`` kwarg and per-call admission tuning).

    ``prefill_chunk_tokens = 0`` keeps the legacy one-shot prefill; any
    positive budget turns each pump tick into a SARATHI-style schedule:
    up to that many prompt tokens of pending prefill run per prefill
    replica per tick (long prompts split across ticks, short prompts slip
    in between their chunks), then every decode replica advances one
    chunk. The dispatch order is ``priority_weight * priority +
    age_weight * seconds_waited`` (descending; FIFO tiebreak), so the
    defaults reproduce the old ``(-priority, t_submit)`` sort. Within a
    chunk tick the SAME key orders the in-flight job set, with
    shortest-remaining-prefill breaking ties — a freshly injected short
    prompt finishes inside its first tick instead of queueing its whole
    budget share behind a long prompt's leftover chunks (raise
    ``age_weight`` if long prompts must not yield under sustained
    short-prompt pressure)."""
    prefill_chunk_tokens: int = 0    # 0 = one-shot prefill (legacy)
    max_prefill_batch: int = 4       # concurrent prompts per prefill call
    decode_chunk_steps: int = 0      # 0 = the engine's own chunk_size
    priority_weight: float = 1.0
    age_weight: float = 0.0


# -- replica clients ----------------------------------------------------------


class PrefillClient(Protocol):
    """Everything the gateway needs from a prefill replica. A multi-host
    deployment implements this over RPC; the prompt goes out, wires and
    first tokens come back."""

    def prefill(self, reqs: List[GenRequest], *, compress: bool,
                backend: str) -> List[Tuple[GenRequest, KVWire, int]]:
        ...

    def prefill_chunk(self, jobs: List[PartialPrefill], budget: int, *,
                      compress: bool, backend: str) -> List[PartialPrefill]:
        """Advance chunked prefills by up to ``budget`` prompt tokens
        total; completed jobs come back ``done`` with their first token
        (RPC realization: jobs are sticky to this replica — the
        accumulated chunk wires live here)."""
        ...


class DecodeClient(Protocol):
    """Everything the gateway needs from a decode replica."""

    def admit(self, batch: AdmissionBatch, *,
              backend: str) -> AdmissionBatch:
        """THE admission call: one FIFO pass over typed items (FRESH |
        CHUNKED | PREFIX_HIT | MIGRATED — see ``engine.AdmissionItem``);
        returns the rejected tail. The RPC mapping is one request
        carrying per-item sources (DESIGN.md §5). The legacy
        ``admit_batch``/``admit_prefix``/``admit_migrated`` variants are
        DELETED — lint rule R003 bans reintroducing them."""
        ...

    def step(self, n_steps: Optional[int] = None) -> List[GenRequest]:
        ...

    def n_free(self) -> int:
        """Admissible request count. This is CAPACITY, not slot count:
        paged engines (DESIGN.md §7) report their page-budget headroom
        here, so dispatch and deadline shedding follow real KV memory
        instead of a worst-case ``max_slots x max_seq`` slab."""
        ...

    @property
    def active(self) -> int:
        ...

    def resident(self) -> List[GenRequest]:
        ...

    def release(self, req: GenRequest) -> bool:
        ...

    def extract_resident(self, *, compress: bool, backend: str):
        """(slot, req, wire, cur_token) snapshot of every resident request
        — the migration source side of a preemption drain (re-admitted on
        the target as MIGRATED items through :meth:`admit`)."""
        ...

    def page_stats(self) -> Optional[Dict[str, float]]:
        """Page-pool counters (incl. ``leaked_pages``) for paged engines;
        None for dense ones. The observability seam ``Gateway.stats()``
        aggregates — NOT an engine attribute reach-through (rule R003)."""
        ...

    # Prefix-cache seam (optional — the gateway getattr-guards every
    # call, so clients without sharing simply never match):
    #   prefix_match(tokens) -> Optional[PrefixMatch]
    #   prefix_pin(pages, tag) -> bool / prefix_unpin(tag)
    #   extract_prefix(pages, length) -> KVWire


class LocalPrefillClient:
    """In-process realization around a :class:`PrefillEngine`."""

    synchronous = True      # a blocking call that returns proves liveness

    def __init__(self, engine: PrefillEngine):
        self.engine = engine

    def prefill(self, reqs, *, compress, backend):
        return self.engine.run(reqs, compress=compress, backend=backend)

    def prefill_chunk(self, jobs, budget, *, compress, backend):
        return self.engine.prefill_chunk(jobs, budget, compress=compress,
                                         backend=backend)

    def supports_suffix(self) -> bool:
        return self.engine.supports_suffix

    def jit_cache_size(self) -> int:
        return self.engine.jit_cache_size


class LocalDecodeClient:
    """In-process realization around a :class:`DecodeEngine`."""

    synchronous = True      # a blocking call that returns proves liveness

    def __init__(self, engine: DecodeEngine):
        self.engine = engine

    def admit(self, batch, *, backend):
        return self.engine.admit(batch, backend=backend)

    def step(self, n_steps=None):
        return self.engine.step(n_steps)

    def n_free(self) -> int:
        return len(self.engine.free_slots())

    @property
    def active(self) -> int:
        return self.engine.active

    def resident(self):
        return [r for r in self.engine.slots if r is not None]

    def release(self, req) -> bool:
        for i, r in enumerate(self.engine.slots):
            if r is req:
                self.engine.release(i)
                return True
        return False

    def extract_resident(self, *, compress, backend):
        return self.engine.extract_resident(compress=compress,
                                            backend=backend)

    def page_stats(self):
        ps = getattr(self.engine, "page_stats", None)
        return ps() if callable(ps) else None

    def prefix_match(self, tokens):
        return self.engine.prefix_match(tokens)

    def prefix_pin(self, pages, tag) -> bool:
        return self.engine.prefix_pin(pages, tag)

    def prefix_unpin(self, tag):
        self.engine.prefix_unpin(tag)

    def extract_prefix(self, pages, length):
        return self.engine.extract_prefix(pages, length)

    def jit_cache_size(self) -> int:
        return self.engine.jit_cache_size


class LocalReplicaClient:
    """In-process client around a phase-switchable :class:`Replica`.

    Implements BOTH :class:`PrefillClient` and :class:`DecodeClient`,
    delegating to whichever engine the replica currently hosts, plus the
    ``switch_phase`` seam an epoch transition needs. A remote realization
    would implement the same surface over RPC (the flip is an in-place
    role change on the remote pod — the paper's no-reload trick — not a
    redeploy)."""

    synchronous = True      # a blocking call that returns proves liveness

    def __init__(self, replica: Replica):
        self.replica = replica

    @property
    def engine(self):
        return self.replica.engine

    @property
    def phase(self) -> str:
        return self.replica.phase

    def switch_phase(self, phase: Optional[str] = None):
        return self.replica.switch_phase(phase)

    def _require(self, phase: str):
        if self.replica.phase != phase:
            raise RuntimeError(
                f"replica is designated {self.replica.phase!r}, "
                f"cannot serve a {phase!r} call (stale routing after an "
                f"epoch transition?)")
        return self.replica.engine

    # -- PrefillClient -------------------------------------------------------

    def prefill(self, reqs, *, compress, backend):
        return self._require("prefill").run(reqs, compress=compress,
                                            backend=backend)

    def prefill_chunk(self, jobs, budget, *, compress, backend):
        return self._require("prefill").prefill_chunk(
            jobs, budget, compress=compress, backend=backend)

    def supports_suffix(self) -> bool:
        return (self.replica.phase == "prefill"
                and self.replica.engine.supports_suffix)

    # -- DecodeClient --------------------------------------------------------

    def admit(self, batch, *, backend):
        return self._require("decode").admit(batch, backend=backend)

    def step(self, n_steps=None):
        return self._require("decode").step(n_steps)

    def n_free(self) -> int:
        return len(self._require("decode").free_slots())

    @property
    def active(self) -> int:
        return self._require("decode").active

    def resident(self):
        return [r for r in self._require("decode").slots if r is not None]

    def release(self, req) -> bool:
        eng = self._require("decode")
        for i, r in enumerate(eng.slots):
            if r is req:
                eng.release(i)
                return True
        return False

    def extract_resident(self, *, compress, backend):
        return self._require("decode").extract_resident(compress=compress,
                                                        backend=backend)

    def page_stats(self):
        ps = getattr(self._require("decode"), "page_stats", None)
        return ps() if callable(ps) else None

    def prefix_match(self, tokens):
        return self._require("decode").prefix_match(tokens)

    def prefix_pin(self, pages, tag) -> bool:
        return self._require("decode").prefix_pin(pages, tag)

    def prefix_unpin(self, tag):
        self._require("decode").prefix_unpin(tag)

    def extract_prefix(self, pages, length):
        return self._require("decode").extract_prefix(pages, length)

    def jit_cache_size(self) -> int:
        return self.replica.engine.jit_cache_size


def _as_prefill_client(obj) -> PrefillClient:
    if isinstance(obj, Replica):
        return LocalReplicaClient(obj)
    return LocalPrefillClient(obj) if isinstance(obj, PrefillEngine) else obj


def _as_decode_client(obj) -> DecodeClient:
    if isinstance(obj, Replica):
        return LocalReplicaClient(obj)
    return LocalDecodeClient(obj) if isinstance(obj, DecodeEngine) else obj


@dataclass
class ReplicaHandle:
    """Gateway-side view of one replica: liveness + latency tracking.

    The failure detector is status-based (DESIGN.md §8):

    * ``alive`` — in the routing tables, taking new work;
    * ``suspected`` — kept OUT of routing (missed heartbeats or a
      latency outlier) but still stepped: resident requests finish, and
      recovery (a fresh beat / a healthy latency sample / a probe) flips
      it back to alive. Suspicion is cheap to be wrong about;
    * ``draining`` — spot-preemption notice received, admissions
      stopped, resident KV migrating out (transient, within
      ``handle_preemption``);
    * ``dead`` — confirmed: requests recovered, failover reschedule
      queued. Death is terminal for the handle.

    ``group`` is the replica's device-group identity from the deployment
    plan (the stable key across plan epochs); it is None for plan-less
    gateways built from bare engine lists, which then cannot take live
    epoch transitions."""
    idx: int
    phase: str
    client: object
    status: str = "alive"
    suspect_why: Optional[str] = None   # "heartbeat" | "latency"
    group: Optional[Tuple[int, ...]] = None
    last_heartbeat: float = 0.0   # gateway stamps clock() at construction
    last_track: float = 0.0             # last latency observation
    ema_latency: float = 0.0            # straggler tracking
    min_latency: float = math.inf       # lower bound for deadline shedding

    @property
    def alive(self) -> bool:
        """Not confirmed-dead (suspected/draining replicas still count —
        they hold live state). Pre-dating the status model; new code
        should test ``status`` directly."""
        return self.status != "dead"

    @alive.setter
    def alive(self, v: bool):
        self.status = "alive" if v else "dead"

    @property
    def dispatchable(self) -> bool:
        """May take NEW work (suspected replicas are excluded until they
        recover; draining/dead never come back)."""
        return self.status == "alive"

    def beat(self, now: float):
        """Record a liveness signal at gateway-clock time ``now`` (always
        caller-supplied — the handle has no clock of its own, rule R001)."""
        self.last_heartbeat = now
        # a beat refutes heartbeat-sourced suspicion only: latency
        # suspicion clears on a healthy sample or a probe, not on beats
        # (sync clients beat every pump)
        if self.status == "suspected" and self.suspect_why == "heartbeat":
            self.status = "alive"
            self.suspect_why = None

    @property
    def engine(self):
        """Underlying in-process engine, when there is one (local clients
        only — an RPC client has no engine attribute)."""
        return getattr(self.client, "engine", None)

    @property
    def switchable(self) -> bool:
        return hasattr(self.client, "switch_phase")


@dataclass
class _Transfer:
    handle: RequestHandle
    ticket: Optional[TransferTicket]   # None: nothing moves (full prefix hit)
    first: int               # first token (normal) / resume token (migrated)
    target: int
    migrated: bool = False   # mid-stream KV migration, not a fresh prefill
    chunked: bool = False    # wire spliced from prefill chunks
    # full prefix-cache hit: the "wire" is a handle onto pages already
    # resident on ``target`` — admission shares the chain, zero transfer
    prefix_full: bool = False
    prefix_pages: Optional[List[int]] = None

    @property
    def replica_bound(self) -> bool:
        """True when this transfer only makes sense on its target replica
        (page handles / suffix wires splicing onto pinned pages) — it is
        never rerouted, only requeued through prefill."""
        return self.prefix_full or self.handle.req.start_pos > 0

    def admission_item(self) -> AdmissionItem:
        """The typed item this transfer becomes at the decode boundary."""
        if self.prefix_full:
            return AdmissionItem(self.handle.req, int(self.first),
                                 ADMIT_PREFIX_HIT,
                                 pages=list(self.prefix_pages or []))
        src = (ADMIT_MIGRATED if self.migrated
               else ADMIT_CHUNKED if self.chunked else ADMIT_FRESH)
        return AdmissionItem(self.handle.req, int(self.first), src,
                             wire=self.ticket.wire)


@dataclass
class _ChunkJob:
    """An in-flight chunked prefill, sticky to the prefill replica whose
    jit caches (and accumulated chunk wires) it lives on."""
    handle: RequestHandle
    partial: PartialPrefill
    pre: ReplicaHandle


@dataclass
class _RetrySend:
    """A KV send that hit a transient transport fault, waiting out its
    backoff before the next attempt."""
    handle: RequestHandle
    wire: KVWire
    first: int
    src: int                 # prefill replica that produced the wire
    attempt: int             # next attempt number (1-based)
    not_before: float


# -- the gateway --------------------------------------------------------------


class Gateway:
    """Request-lifecycle facade over phase-split serving replicas.

    Owns TSTP routing (X/Y masses from the orchestration), heartbeat
    failure detection, straggler-aware routing refresh, workload-shift
    rescheduling, deadline admission control, and the prefill ->
    transport -> decode pipeline. One ``pump()`` is one event-loop
    iteration; ``run_until_drained()`` drives until every submitted
    request reaches a terminal state.
    """

    def __init__(self, prefills: Sequence, decodes: Sequence, *,
                 transport: Optional[Transport] = None,
                 orchestration: Optional[Orchestration] = None,
                 plan=None, compress: bool = True, backend: str = "auto",
                 heartbeat_timeout: float = 10.0, seed: int = 0,
                 profiler: Optional[WorkloadProfiler] = None,
                 clock: Callable[[], float] = time.time,
                 retry: Optional[RetryPolicy] = None,
                 max_restarts: int = 5,
                 suspect_timeout: Optional[float] = None,
                 suspect_latency_factor: float = 4.0,
                 suspect_probe_s: float = 1.0,
                 scheduler: Optional[SchedulerConfig] = None):
        self.clock = clock               # injectable time source (faults.py)
        self.scheduler = scheduler or SchedulerConfig()
        self.pre = [ReplicaHandle(i, "prefill", _as_prefill_client(e),
                                  last_heartbeat=clock())
                    for i, e in enumerate(prefills)]
        self.dec = [ReplicaHandle(j, "decode", _as_decode_client(e),
                                  last_heartbeat=clock())
                    for j, e in enumerate(decodes)]
        self.transport: Transport = transport or InProcessTransport(
            clock=clock)
        self.plan = plan                 # current DeploymentPlan, if bound
        self.epoch = 0                   # bumped by every apply_plan
        if plan is not None:
            self._bind_plan_groups(plan)
        self.o = orchestration if orchestration is not None else (
            plan.orchestration if plan is not None else None)
        self.compress = compress
        self.backend = backend
        self.heartbeat_timeout = heartbeat_timeout
        # suspected-slow threshold: missing beats for half the death
        # timeout parks a replica out of routing before it is declared dead
        self.suspect_timeout = (suspect_timeout if suspect_timeout is not None
                                else heartbeat_timeout / 2.0)
        self.suspect_latency_factor = suspect_latency_factor
        self.suspect_probe_s = suspect_probe_s
        self.rng = np.random.default_rng(seed)
        self.profiler = profiler or WorkloadProfiler(clock=clock)
        self.queue: List[RequestHandle] = []
        self.transfer_queue: List[_Transfer] = []
        self._chunks: List[_ChunkJob] = []   # in-flight chunked prefills
        self.n_chunk_ticks = 0               # prefill_chunk calls issued
        self.n_chunked_prefills = 0          # prompts completed chunked
        self.done: List[RequestHandle] = []
        self.events: List[str] = []
        self._by_req: Dict[int, RequestHandle] = {}   # id(GenRequest) -> h
        self._decode_outage_reported = False
        # fault tolerance (DESIGN.md §8)
        self.retry = retry or RetryPolicy()
        self.retry_queue: List[_RetrySend] = []
        self._retry_rng = _random.Random(seed)
        self.max_restarts = max_restarts
        self.chaos = None                # set by faults.install_chaos
        self._failover = None            # set by set_failover
        self._pending_failover = False
        # counters surfaced by stats()
        self.n_retries = 0
        self.n_requeues = 0
        self.n_migrations = 0
        self.n_migrated_tokens = 0
        self.n_failed = 0
        self.n_preemptions = 0
        # prefix cache (DESIGN.md §10): submit-time radix matches
        self.n_prefix_hits = 0          # full: prefill skipped entirely
        self.n_prefix_partial = 0       # suffix-only prefill
        self.n_prefix_miss = 0
        self.n_prefix_tokens_hit = 0    # prompt tokens whose KV was reused
        self._pins: Dict[int, Tuple[ReplicaHandle, object]] = {}  # rid ->
        # runtime sanitizers (REPRO_SANITIZE=1): lazy import keeps the
        # analysis package out of the hot path when disabled
        self.sanitizer = None
        if os.environ.get("REPRO_SANITIZE", "").strip() not in (
                "", "0", "false", "no"):
            from repro.analysis.sanitizers import GatewaySanitizer
            self.sanitizer = GatewaySanitizer()

    def _bind_plan_groups(self, plan):
        """Tag live replica handles with their plan device groups (matched
        positionally: i-th prefill handle <-> i-th prefill replica plan)."""
        if (len(self.pre) != len(plan.prefill_replicas)
                or len(self.dec) != len(plan.decode_replicas)):
            raise ValueError(
                f"plan/replica mismatch: plan has "
                f"{len(plan.prefill_replicas)}P/{len(plan.decode_replicas)}D,"
                f" gateway has {len(self.pre)}P/{len(self.dec)}D")
        for h, r in zip(self.pre, plan.prefill_replicas):
            h.group = sched._group_key(r.devices)
        for h, r in zip(self.dec, plan.decode_replicas):
            h.group = sched._group_key(r.devices)

    # -- routing ------------------------------------------------------------

    @staticmethod
    def _routable(handles: Sequence[ReplicaHandle]) -> np.ndarray:
        """Routing mask: healthy replicas first; if suspicion emptied the
        fleet, fall back to the suspected ones (slow beats unserved) —
        draining/dead replicas never take new work."""
        m = np.array([h.status == "alive" for h in handles], float)
        if m.sum() == 0:
            m = np.array([h.status == "suspected" for h in handles], float)
        return m

    def _X(self) -> np.ndarray:
        alive = self._routable(self.pre)
        if self.o is not None and self.o.X.shape[0] == len(self.pre):
            x = self.o.X * alive
        else:
            x = alive
        s = x.sum()
        return x / s if s > 0 else alive / max(alive.sum(), 1)

    def _Y(self, i: int) -> np.ndarray:
        alive = self._routable(self.dec)
        if self.o is not None and self.o.Y.shape == (len(self.pre),
                                                     len(self.dec)):
            y = self.o.Y[i] * alive
        else:
            y = alive
        s = y.sum()
        return y / s if s > 0 else alive / max(alive.sum(), 1)

    # -- request lifecycle --------------------------------------------------

    def submit(self, request, *,
               on_token: Optional[Callable[[RequestHandle, int], None]]
               = None) -> RequestHandle:
        """Admit a request into the gateway; returns its live handle.

        Accepts a :class:`ServeRequest` (the v2 API) or a bare
        :class:`GenRequest` (deprecated shim path: no deadlines, default
        priority)."""
        if isinstance(request, GenRequest):
            gen = request
            request = ServeRequest.from_gen(gen)
        else:
            gen = GenRequest(request.rid,
                             np.asarray(request.tokens, np.int32),
                             request.max_new_tokens,
                             extras=dict(request.extras))
        h = RequestHandle(request, gen, self, on_token=on_token)
        gen.t_submit = h.t_submit
        self.profiler.record_arrival(h.t_submit)
        self._by_req[id(gen)] = h
        # radix-match the prompt against resident prefixes BEFORE
        # dispatch: a full hit skips prefill entirely (QUEUED ->
        # TRANSFERRING with a page handle); a partial hit annotates the
        # request so prefill covers only the suffix
        if self._try_prefix(h):
            return h
        self.queue.append(h)
        return h

    # -- prefix cache (DESIGN.md §10) ----------------------------------------

    def _try_prefix(self, h: RequestHandle) -> bool:
        """Match ``h``'s prompt against every dispatchable decode
        replica's radix index. Returns True when the request was placed
        on the transfer queue as a FULL hit (caller must not queue it);
        a partial hit pins the prefix chain and annotates the request,
        which still goes through (suffix) prefill."""
        gen = h.req
        if len(gen.tokens) == 0:
            return False
        best: Optional[Tuple[object, int]] = None
        for j, d in enumerate(self.dec):
            if not d.dispatchable:
                continue
            pm = getattr(d.client, "prefix_match", None)
            if not callable(pm):
                continue
            m = pm(gen.tokens)
            if m is None:
                continue
            if best is None or (m.full, m.length) > (best[0].full,
                                                     best[0].length):
                best = (m, j)
        plen = len(gen.tokens)
        if best is None:
            self.n_prefix_miss += 1
            self.profiler.record_prefix(plen, 0)
            return False
        m, j = best
        d = self.dec[j]
        tag = ("prefix-pin", gen.rid)
        if not m.full:
            # partial hits need a prefill replica that can run a suffix
            if not any(p.dispatchable and self._suffix_ok(p)
                       for p in self.pre):
                self.n_prefix_miss += 1
                self.profiler.record_prefix(plen, 0)
                return False
        if not d.client.prefix_pin(m.pages, tag):
            self.n_prefix_miss += 1
            self.profiler.record_prefix(plen, 0)
            return False
        self._pins[gen.rid] = (d, tag)
        self.n_prefix_tokens_hit += m.length
        self.profiler.record_prefix(plen, m.length)
        if m.full:
            self.n_prefix_hits += 1
            h._transition(TRANSFERRING, self.clock())
            self.transfer_queue.append(_Transfer(
                h, None, int(m.next_token), j, prefix_full=True,
                prefix_pages=list(m.pages)))
            self.events.append(
                f"request {gen.rid}: full prefix hit ({m.length} tokens "
                f"resident on decode:{j}); prefill skipped")
            return True
        self.n_prefix_partial += 1
        gen.start_pos = m.length
        gen.prefix_pages = list(m.pages)
        gen.prefix_replica = j
        gen.prefix_wire = d.client.extract_prefix(m.pages, m.length)
        self.events.append(
            f"request {gen.rid}: partial prefix hit ({m.length}/{plen} "
            f"tokens resident on decode:{j}); suffix prefill only")
        return False

    @staticmethod
    def _suffix_ok(p: ReplicaHandle) -> bool:
        sup = getattr(p.client, "supports_suffix", None)
        return bool(sup()) if callable(sup) else False

    def _release_prefix(self, h: RequestHandle):
        """Drop ``h``'s pin (if any) and clear its prefix annotations so
        a requeued attempt goes through a normal full prefill."""
        rec = self._pins.pop(h.request.rid, None)
        if rec is not None:
            d, tag = rec
            if d.status != "dead":
                try:
                    d.client.prefix_unpin(tag)
                except Exception:
                    pass    # replica flipped/died mid-release: engine-side
                            # clear_prefix dropped the pin already
        h.req.start_pos = 0
        h.req.prefix_pages = None
        h.req.prefix_wire = None
        h.req.prefix_replica = -1

    def cancel(self, h: RequestHandle) -> bool:
        """Abort a request in any non-terminal state; a mid-decode cancel
        releases the slot (and its cache length) immediately."""
        if h.is_terminal:
            return False
        now = self.clock()
        if h in self.queue:
            self.queue.remove(h)
        self.transfer_queue = [t for t in self.transfer_queue
                               if t.handle is not h]
        self.retry_queue = [r for r in self.retry_queue
                            if r.handle is not h]
        self._chunks = [c for c in self._chunks if c.handle is not h]
        if h.state == DECODING:
            for d in self.dec:
                if d.client.release(h.req):
                    break
        self._release_prefix(h)
        h._transition(CANCELLED, now, reason="cancelled by client")
        self._finish(h)
        self.events.append(f"request {h.request.rid} cancelled "
                           f"({h.history[-2][1].lower()})")
        return True

    # -- admission control --------------------------------------------------

    def _min_prefill_estimate(self) -> float:
        """Best-case seconds of work between *now* and the first token for
        a request still in the queue: the fastest latency EVER observed on
        an alive prefill replica plus the smallest transport hop ever
        paid. Minima — not EMAs, which one jit-compile spike would inflate
        — and unmeasured components count as zero, so shedding stays
        conservative. Caveat: the minima are conditioned on the batch /
        bucket / wire shapes actually served so far; a request much
        smaller than everything previously observed could in principle
        beat them (the estimate is the tightest bound available without a
        per-shape cost model)."""
        mins = [r.min_latency for r in self.pre
                if r.alive and math.isfinite(r.min_latency)]
        est = min(mins) if mins else 0.0
        est += getattr(self.transport, "min_delay_s", 0.0) or 0.0
        return est

    def _shed_expired(self, now: float):
        if not self.queue:
            return
        est = self._min_prefill_estimate()
        keep = []
        for h in self.queue:
            dl = h.request.ttft_deadline_s
            waited = now - h.t_submit
            if math.isfinite(dl) and waited + est > dl:
                h._transition(
                    REJECTED, now,
                    reason=(f"TTFT deadline provably missed while queued: "
                            f"waited {waited:.3f}s + best-case "
                            f"{est:.3f}s > deadline {dl:.3f}s"))
                self._finish(h)
                self.events.append(
                    f"request {h.request.rid} rejected: ttft deadline")
            else:
                keep.append(h)
        self.queue = keep

    # -- event loop ---------------------------------------------------------

    def pump(self, *, max_prefill_batch: Optional[int] = None) -> int:
        """One token-budget scheduler tick; returns #finished this round.

        With ``scheduler.prefill_chunk_tokens > 0`` each tick packs up to
        that many prompt tokens of pending prefill per prefill replica
        (chunked — a long prompt spreads over many ticks while short
        prompts behind it complete and reach decode in between) plus one
        decode chunk per decode replica, with transfers admitted and
        finished slots evicted at the chunk boundary in between. With a
        zero budget, prefill is one-shot per prompt (legacy behavior).

        ``max_prefill_batch`` is a DEPRECATED one-PR override of
        ``scheduler.max_prefill_batch``."""
        if self.chaos is not None:
            self.chaos.tick(self.clock())
        if self._pending_failover:
            self._run_failover()
        now = self.clock()
        self._check_heartbeats()
        self._update_suspects(now)
        self._shed_expired(now)
        self._flush_retries(now)
        # 1. dispatch queued prompts: drain EVERY routable prefill replica
        #    this round (the TSTP masses only order who gets fed first)
        batch_cap = (max_prefill_batch if max_prefill_batch is not None
                     else self.scheduler.max_prefill_batch)
        if self.queue or self._chunks:
            self._dispatch_round(batch_cap, now)
        # 2. drain KV transfers whose wires have arrived into decode slots
        #    (prefill-side queueing: wires wait here if the target has no
        #    free slot, cf. Appendix E)
        self._drain_transfers()
        # 3. advance every decode replica one chunk of steps; stream every
        #    newly emitted token to its handle
        return self._step_decodes()

    def _dispatch_round(self, batch_cap: int, now: float):
        if self.queue:
            w_p = self.scheduler.priority_weight
            w_a = self.scheduler.age_weight
            self.queue.sort(key=lambda h: (
                -(w_p * h.request.priority + w_a * (now - h.t_submit)),
                h.t_submit))
        X = self._X()
        cand = [i for i in range(len(self.pre))
                if self.pre[i].alive and X[i] > 0]
        if len(cand) > 1:
            p = X[cand] / X[cand].sum()
            cand = [int(i) for i in self.rng.choice(
                cand, size=len(cand), replace=False, p=p)]
        budget = self.scheduler.prefill_chunk_tokens
        for i in cand:
            if not self.queue and not self._chunks:
                break
            if budget > 0 and self._suffix_ok(self.pre[i]):
                self._pump_chunks(self.pre[i], batch_cap, budget)
            elif self.queue:
                batch = self.queue[:batch_cap]
                self.queue = self.queue[batch_cap:]
                self._dispatch_prefill(i, batch)

    def _pump_chunks(self, pre: ReplicaHandle, batch_cap: int,
                     budget: int):
        """One chunked-prefill tick on replica ``pre``: top up its active
        job set from the queue (injection happens HERE, at a chunk
        boundary — no prompt waits for another prompt to finish), run one
        ``prefill_chunk`` call of up to ``budget`` tokens, and ship a
        spliced wire for every prompt that completed."""
        mine = [c for c in self._chunks if c.pre is pre]
        while self.queue and len(mine) < batch_cap:
            h = self.queue.pop(0)
            if h.req.start_pos > 0:
                # stale partial-hit annotation: only honored while the
                # pinned decode replica still takes work
                j = h.req.prefix_replica
                if not (0 <= j < len(self.dec)) \
                        or not self.dec[j].dispatchable:
                    self._release_prefix(h)
            h._transition(PREFILLING, self.clock())
            c = _ChunkJob(h, PartialPrefill(h.req), pre)
            self._chunks.append(c)
            mine.append(c)
        if not mine:
            return
        t0 = self.clock()
        # budget flows in dispatch-priority order, shortest remaining
        # prefill first among ties: a short prompt injected this tick
        # completes NOW, the long prompt soaks up the leftover budget
        mine.sort(key=lambda c: (
            -(self.scheduler.priority_weight * c.handle.request.priority
              + self.scheduler.age_weight * (t0 - c.handle.t_submit)),
            c.partial.remaining, c.handle.t_submit))
        try:
            pre.client.prefill_chunk([c.partial for c in mine], budget,
                                     compress=self.compress,
                                     backend=self.backend)
        except ReplicaCrashError as e:
            self._confirm_dead(pre, str(e))
            now = self.clock()
            for c in mine:
                if c in self._chunks:
                    self._chunks.remove(c)
                self._requeue_handle(c.handle, now,
                                     f"prefill:{pre.idx} crashed mid-chunk")
            return
        t1 = self.clock()
        self.n_chunk_ticks += 1
        self._track(pre, t1 - t0, t1)
        for c in mine:
            if not c.partial.done:
                continue
            self._chunks.remove(c)
            self.n_chunked_prefills += 1
            c.handle._transition(TRANSFERRING, t1)
            self._send_wire(c.handle, c.partial.wire(), c.partial.first,
                            pre.idx, t1, chunked=True)

    def _dispatch_prefill(self, i: int, batch: List[RequestHandle]):
        t0 = self.clock()
        suffix_ok = self._suffix_ok(self.pre[i])
        for h in batch:
            if h.req.start_pos > 0:
                # partial-hit annotation is only honored when this prefill
                # replica can run a suffix AND the pinned decode replica
                # is still taking work — otherwise fall back to a full
                # prefill before any suffix wire exists
                j = h.req.prefix_replica
                if (not suffix_ok or not (0 <= j < len(self.dec))
                        or not self.dec[j].dispatchable):
                    self._release_prefix(h)
            h._transition(PREFILLING, t0)
        try:
            results = self.pre[i].client.prefill(
                [h.req for h in batch], compress=self.compress,
                backend=self.backend)
        except ReplicaCrashError as e:
            now = self.clock()
            self._confirm_dead(self.pre[i], str(e))
            for h in batch:
                self._requeue_handle(h, now, f"prefill:{i} crashed")
            return
        t1 = self.clock()
        self._track(self.pre[i], t1 - t0, t1)
        for req, wire, first in results:
            h = self._by_req[id(req)]
            h._transition(TRANSFERRING, t1)
            self._send_wire(h, wire, first, i, t1)

    # -- transient-fault retry (bounded backoff + jitter) --------------------

    def _send_wire(self, h: RequestHandle, wire: KVWire, first: int,
                   src: int, now: float, attempt: int = 0,
                   chunked: bool = False):
        """Ship one wire toward a routable decode replica. A transient
        transport fault schedules a retry instead of losing the request;
        with no alive decode replica the target is a placeholder and
        ``_drain_transfers`` holds the wire + events."""
        if h.req.start_pos > 0:
            # suffix wire: only the replica holding the pinned prefix
            # chain can splice it — no TSTP routing choice to make
            j = h.req.prefix_replica
            if not (0 <= j < len(self.dec)) \
                    or not self.dec[j].dispatchable:
                self._requeue_handle(h, now, "(pinned prefix replica "
                                             "lost mid-transfer)")
                return
        else:
            Y = self._Y(src)
            j = (int(self.rng.choice(len(self.dec), p=Y))
                 if Y.sum() > 0 else 0)
        try:
            ticket = self.transport.send(wire, src, j, now=now)
        except TransientTransportError as e:
            self._schedule_retry(h, wire, first, src, attempt, now, str(e))
            return
        self.transfer_queue.append(
            _Transfer(h, ticket, first, j, chunked=chunked))

    def _schedule_retry(self, h: RequestHandle, wire: KVWire, first: int,
                        src: int, attempt: int, now: float, why: str):
        if attempt >= self.retry.max_retries:
            self.events.append(f"request {h.request.rid}: transfer retries "
                               f"exhausted after {attempt} attempt(s)")
            self._requeue_handle(h, now, "transfer retries exhausted")
            return
        delay = self.retry.delay_s(attempt, self._retry_rng)
        self.retry_queue.append(
            _RetrySend(h, wire, first, src, attempt + 1, now + delay))
        self.n_retries += 1
        self.events.append(f"request {h.request.rid}: transfer retry "
                           f"{attempt + 1} in {delay * 1e3:.0f}ms ({why})")

    def _flush_retries(self, now: float):
        if not self.retry_queue:
            return
        due, later = [], []
        for r in self.retry_queue:
            if r.handle.is_terminal:
                continue             # cancelled while backing off
            (due if r.not_before <= now else later).append(r)
        self.retry_queue = later
        for r in due:
            self._send_wire(r.handle, r.wire, r.first, r.src, now,
                            attempt=r.attempt)

    def _drain_transfers(self):
        if not self.transfer_queue:
            return
        now = self.clock()
        arrived = [t for t in self.transfer_queue
                   if t.ticket is None or t.ticket.ready(now)]
        in_flight = [t for t in self.transfer_queue
                     if not (t.ticket is None or t.ticket.ready(now))]
        if not arrived:
            return
        usable = [j for j, d in enumerate(self.dec) if d.dispatchable]
        if not usable:
            # do NOT silently reroute to replica 0 (it is dead too) — keep
            # the wires queued and surface the outage once
            if not self._decode_outage_reported:
                self.events.append(
                    "all decode replicas dead; KV transfers stalled")
                self._decode_outage_reported = True
            self.transfer_queue = in_flight + arrived
            return
        self._decode_outage_reported = False
        by_target: Dict[int, List[_Transfer]] = {}
        for t in arrived:
            j = t.target
            if not self.dec[j].dispatchable:
                if t.replica_bound:
                    # the pinned pages died with the replica: page handles
                    # and suffix wires cannot reroute — back through a
                    # full prefill (pin dropped, annotations cleared)
                    self._requeue_handle(
                        t.handle, now,
                        f"(prefix replica decode:{j} lost)")
                    continue
                # reroute to the healthy replica with the most free slots
                j = max(usable, key=lambda jj: self.dec[jj].client.n_free())
            by_target.setdefault(j, []).append(t)
        still = in_flight
        for j, items in by_target.items():
            # one admission RPC per target, source-typed per item — the
            # engine places what fits FIFO and hands back the rejected
            # tail, which stays queued until capacity frees up (full
            # prefix hits need no wire: the chain is shared into a fresh
            # slot, COW if the prompt ends mid-page, so TTFT is pure
            # queueing)
            pfx = [t for t in items if t.prefix_full]
            mig = [t for t in items if t.migrated]
            norm = [t for t in items
                    if not t.migrated and not t.prefix_full]
            ordered = pfx + norm + mig
            try:
                rejected = self.dec[j].client.admit(
                    AdmissionBatch([t.admission_item() for t in ordered]),
                    backend=self.backend)
            except ReplicaCrashError as e:
                self._confirm_dead(self.dec[j], str(e))
                still.extend(ordered)        # retry next pump
                continue
            rej = {id(it.req) for it in rejected.items}
            t_adm = self.clock()
            for t in ordered:
                if id(t.handle.req) in rej:
                    still.append(t)
                    continue
                t.handle._transition(DECODING, t_adm)
                if not t.migrated:
                    # migrated wires resume mid-stream: the resume token
                    # is never re-appended, so there is nothing to sync
                    self._sync_tokens(t.handle, t_adm)
                if t.prefix_full or t.handle.req.start_pos > 0:
                    # prefix chain shared/spliced: the slot now holds its
                    # own references — drop the pin
                    self._release_prefix(t.handle)
        self.transfer_queue = still

    def _step_decodes(self) -> int:
        n_done = 0
        for handle in self.dec:
            if not handle.alive:
                continue
            t0 = self.clock()
            ns = self.scheduler.decode_chunk_steps
            try:
                finished = (handle.client.step(n_steps=ns) if ns
                            else handle.client.step())
            except ReplicaCrashError as e:
                self._confirm_dead(handle, str(e))
                continue
            t1 = self.clock()
            if handle.client.active or finished:
                self._track(handle, t1 - t0, t1)
            for req in handle.client.resident():
                self._sync_tokens(self._by_req[id(req)], t1)
            for req in finished:
                h = self._by_req[id(req)]
                self._sync_tokens(h, t1)
                h._transition(DONE, t1)
                self.profiler.record(len(req.tokens), len(req.out_tokens),
                                     t1)
                self._finish(h)
                n_done += 1
        return n_done

    def _finish(self, h: RequestHandle):
        """Terminal bookkeeping: the GenRequest leaves the routing tables
        so a long-running service doesn't grow without bound (the handle
        itself stays in ``done`` until ``clear_finished``)."""
        self._release_prefix(h)        # no-op unless a pin is still held
        self._by_req.pop(id(h.req), None)
        self.done.append(h)
        if self.sanitizer is not None:
            self.sanitizer.on_finish(h)

    def clear_finished(self) -> List[RequestHandle]:
        """Hand over (and forget) terminal handles + events — call
        periodically from a long-running service loop to bound memory."""
        out, self.done = self.done, []
        self.events.clear()
        return out

    def _sync_tokens(self, h: RequestHandle, now: float):
        """Stream tokens the engines appended to ``req.out_tokens`` since
        the last pump into the handle (timestamps live on the handle, not
        the GenRequest). After a failure requeue the restarted attempt
        regenerates the already-delivered prefix (greedy decode is
        deterministic): those positions are swallowed instead of re-firing
        ``on_token`` or growing ``tokens`` twice."""
        out = h.req.out_tokens
        pos = h._engine_seen
        new = out[pos:]
        if not new:
            return
        h._engine_seen = len(out)
        replay = len(h.tokens) - pos        # delivered positions to skip
        if replay > 0:
            new = new[replay:]
        if new:
            h._deliver(new, now)

    def _sleep(self, dt: float):
        """Wait helper that understands virtual clocks: a VirtualClock
        advances (so simulated wires land and backoffs expire without wall
        time); a wall clock really sleeps."""
        adv = getattr(self.clock, "advance", None)
        if adv is not None:
            adv(dt)
        else:
            time.sleep(dt)

    def run_until_drained(self, *, max_iters: int = 10000,
                          poll_s: float = 2e-4) -> List[RequestHandle]:
        """Drive until every submitted request is terminal (or decode is
        wedged); returns terminal handles in completion order."""
        it = 0
        while (self.queue or self._chunks or self.transfer_queue
               or self.retry_queue
               or any(d.alive and d.client.active for d in self.dec)) \
                and it < max_iters:
            n = self.pump()
            it += 1
            if n == 0 and not self.queue and not self._chunks \
                    and (self.transfer_queue or self.retry_queue) \
                    and not any(d.alive and d.client.active
                                for d in self.dec):
                # nothing computable until a simulated wire lands / a
                # backoff expires (or a dead fleet recovers): don't burn
                # max_iters busy-spinning
                self._sleep(poll_s)
        self.sanitize_check("run_until_drained")
        return self.done

    def sanitize_check(self, context: str = "drain"):
        """Run the ``REPRO_SANITIZE=1`` audits now: page leaks /
        ownership drift on live decode replicas, state-machine violations
        on finished requests, steady-state jit retraces. No-op when
        sanitizers are disabled."""
        if self.sanitizer is not None:
            self.sanitizer.check(self, context=context)

    # -- fault tolerance ----------------------------------------------------

    def _check_heartbeats(self):
        now = self.clock()
        for h in self.pre + self.dec:
            if h.status in ("dead", "draining"):
                continue
            if getattr(h.client, "synchronous", False):
                # an in-process client cannot miss a heartbeat: its calls
                # block, so wall time spent elsewhere (traffic gaps, jit
                # compilation) is not evidence of replica death — only
                # kill_replica takes a local replica down. Timeout-based
                # death is for asynchronous/remote clients.
                h.beat(now)
                continue
            silent = now - h.last_heartbeat
            if silent > self.heartbeat_timeout:
                self.events.append(f"replica {h.phase}:{h.idx} timed out")
                self._confirm_dead(
                    h, f"heartbeat timed out ({silent:.1f}s silent)")
            elif silent > self.suspect_timeout and h.status == "alive":
                h.status = "suspected"
                h.suspect_why = "heartbeat"
                self.events.append(f"replica {h.phase}:{h.idx} suspected "
                                   f"({silent:.1f}s without a heartbeat)")

    def _update_suspects(self, now: float):
        """Latency-based suspicion: a replica whose EMA latency exceeds
        ``suspect_latency_factor`` x the fleet median leaves the routing
        tables (it still steps its resident work). Recovery is either a
        healthy sample or — for a suspected replica starved of traffic,
        whose EMA can never refresh — a probe re-admission after
        ``suspect_probe_s`` without an observation."""
        for handles in (self.pre, self.dec):
            obs = [h.ema_latency for h in handles
                   if h.status in ("alive", "suspected")
                   and h.ema_latency > 0]
            if len(obs) >= 2:
                bar = self.suspect_latency_factor * float(np.median(obs))
                for h in handles:
                    if h.ema_latency <= 0 or bar <= 0:
                        continue
                    if h.status == "alive" and h.ema_latency > bar:
                        h.status = "suspected"
                        h.suspect_why = "latency"
                        self.events.append(
                            f"replica {h.phase}:{h.idx} suspected (latency "
                            f"{h.ema_latency * 1e3:.1f}ms > "
                            f"{bar * 1e3:.1f}ms)")
                    elif (h.status == "suspected"
                          and h.suspect_why == "latency"
                          and h.ema_latency <= bar):
                        h.status = "alive"
                        h.suspect_why = None
                        self.events.append(
                            f"replica {h.phase}:{h.idx} recovered "
                            f"(latency back under the bar)")
            for h in handles:
                if (h.status == "suspected" and h.suspect_why == "latency"
                        and now - h.last_track > self.suspect_probe_s):
                    h.status = "alive"
                    h.suspect_why = None
                    self.events.append(
                        f"replica {h.phase}:{h.idx} probe: re-admitted "
                        f"to routing for a fresh measurement")

    def kill_replica(self, phase: str, idx: int, *, recover: bool = True):
        """Failure injection (tests/benchmarks). ``recover=False`` models
        the NO-HANDLING baseline: the replica dies and its resident
        requests are silently stranded (they never reach a terminal
        state) — the contrast the fault-tolerance bench measures."""
        group = self.pre if phase == "prefill" else self.dec
        h = group[idx]
        h.status = "dead"
        self.events.append(f"replica {phase}:{idx} killed")
        if recover:
            self._recover_from(h)
            self._pending_failover = True

    def _confirm_dead(self, h: ReplicaHandle, why: str):
        """suspected/alive -> dead: recover resident requests and queue a
        failover reschedule (picked up at the top of the next pump — never
        mid-iteration, so dispatch loops don't see half-rebuilt replica
        lists)."""
        if h.status == "dead":
            return
        h.status = "dead"
        h.suspect_why = None
        self.events.append(f"replica {h.phase}:{h.idx} confirmed dead "
                           f"({why})")
        self._recover_from(h)
        self._pending_failover = True

    def _recover_from(self, h: ReplicaHandle):
        """Requests in a dead decode replica lose their KV — their handles
        transition DECODING -> QUEUED (visible in ``history``, counted in
        ``restarts``) and they re-enter the queue for a fresh prefill on a
        surviving replica. A request past ``max_restarts`` FAILs instead
        of looping forever."""
        now = self.clock()
        if h.phase == "prefill":
            # partially-prefilled chunk jobs lose their accumulated wires
            # with the replica — back through the queue for a fresh start
            lost = [c for c in self._chunks if c.pre is h]
            self._chunks = [c for c in self._chunks if c.pre is not h]
            for c in lost:
                self._requeue_handle(c.handle, now,
                                     f"after prefill:{h.idx} failure")
            return
        for req in h.client.resident():
            h.client.release(req)
            hd = self._by_req.get(id(req))
            if hd is not None:
                self._requeue_handle(hd, now,
                                     f"after decode:{h.idx} failure")

    def _requeue_handle(self, hd: RequestHandle, now: float, why: str):
        """The ONE requeue-through-prefill path (decode death, preemption
        overflow, retry exhaustion, prefill crash): KV is gone, delivered
        tokens are kept, the regenerated prefix is suppressed. Gives up
        with FAILED once ``max_restarts`` attempts are burned."""
        if hd.is_terminal:
            return
        # a restarted attempt conditions on nothing: pins released, prefix
        # annotations cleared (it may re-match at the fresh prefill's cost)
        self._release_prefix(hd)
        if hd.restarts >= self.max_restarts:
            hd._transition(FAILED, now,
                           reason=f"gave up after {hd.restarts} restart(s): "
                                  f"{why}")
            self._finish(hd)
            self.n_failed += 1
            self.events.append(f"request {hd.request.rid} failed: {why}")
            return
        hd._requeue(now)
        self.queue.append(hd)
        self.n_requeues += 1
        self.events.append(f"request {hd.request.rid} re-queued {why}")

    # -- spot preemption: page-granular KV drain -----------------------------

    def handle_preemption(self, phase: str, idx: int,
                          grace_s: float = 1.0, *,
                          now: Optional[float] = None) -> Dict[str, int]:
        """Spot-preemption notice for replica ``phase:idx`` with a grace
        window of ``grace_s`` seconds (ROADMAP item 4: treat the notice as
        a planned drain).

        Admissions stop immediately (status ``draining``). For a decode
        replica, every resident request's KV is extracted PAGE-GRANULAR
        (zero-dequant for the int4 residency — the pages already hold the
        wire encoding) and shipped over the transport's decode->decode
        links to the surviving replica with the most capacity. Transfers
        whose cumulative delay would outlive the grace window — and
        everything when no survivor exists — fall back to
        requeue-through-prefill, so no accepted request is ever lost. The
        replica is then confirmed dead, which queues the failover
        reschedule. Returns ``{"migrated", "requeued", "tokens_migrated"}``.
        """
        now = now if now is not None else self.clock()
        handles = self.pre if phase == "prefill" else self.dec
        h = handles[idx]
        if h.status == "dead":
            return {"migrated": 0, "requeued": 0, "tokens_migrated": 0}
        self.n_preemptions += 1
        h.status = "draining"
        self.events.append(f"replica {phase}:{idx} preemption notice "
                           f"(grace {grace_s:.2f}s)")
        migrated = requeued = tokens_moved = 0
        if phase == "decode":
            try:
                items = h.client.extract_resident(compress=self.compress,
                                                  backend=self.backend)
            except Exception as e:     # died before the drain: requeue-all
                self.events.append(f"decode:{idx} drain extract failed "
                                   f"({e}); requeueing residents")
                items = []
            survivors = [j for j, d in enumerate(self.dec)
                         if j != idx and d.status == "alive"]
            send = getattr(self.transport, "send_decode",
                           self.transport.send)
            budget = grace_s
            for _slot, req, wire, cur in items:
                hd = self._by_req.get(id(req))
                if hd is None:
                    h.client.release(req)
                    continue
                ticket = None
                if survivors and budget > 0:
                    target = max(survivors,
                                 key=lambda j: self.dec[j].client.n_free())
                    ticket = send(wire, idx, target, now=now)
                    budget -= ticket.delay_s
                    if budget < 0:
                        # this transfer would outlive the node: abandon it
                        ticket = None
                if ticket is not None:
                    hd._transition(TRANSFERRING, now)
                    self.transfer_queue.append(
                        _Transfer(hd, ticket, cur, target, migrated=True))
                    migrated += 1
                    tokens_moved += wire.request_len
                    self.events.append(
                        f"request {req.rid} migrating decode:{idx} -> "
                        f"decode:{target} ({wire.request_len} tokens)")
                else:
                    self._requeue_handle(
                        hd, now, f"(preempted decode:{idx}, "
                                 f"no migration target/grace)")
                    requeued += 1
                h.client.release(req)
            # anything extract_resident missed goes the requeue path
            self._recover_from(h)
        self.n_migrations += migrated
        self.n_migrated_tokens += tokens_moved
        self._confirm_dead(h, f"spot preemption (grace {grace_s:.2f}s "
                              f"elapsed)")
        self.events.append(f"preemption drain {phase}:{idx}: {migrated} "
                           f"migrated, {requeued} requeued")
        return {"migrated": migrated, "requeued": requeued,
                "tokens_migrated": tokens_moved}

    # -- failover: epoch reschedule excluding dead nodes ---------------------

    def set_failover(self, cluster, cfg: ModelConfig, slo: SloSpec, *,
                     workload=None, rate: Optional[float] = None,
                     search_fn=None):
        """Arm automatic failover rescheduling: when a replica is
        confirmed dead, the next pump re-plans on the surviving device
        groups (``drop_nodes`` -> flip-only search seeded with the
        survivors -> ``plan_diff`` -> ``apply_plan``). ``search_fn`` must
        accept ``reschedule_lightweight``'s signature (incl.
        ``init_solution``)."""
        self._failover = {"cluster": cluster, "cfg": cfg, "slo": slo,
                          "workload": workload, "rate": rate,
                          "search_fn": search_fn}

    def _run_failover(self):
        self._pending_failover = False
        ctx = self._failover
        if ctx is None or not self._is_plan_bound():
            return None
        plan_groups = {sched._group_key(r.devices) for r in
                       (list(self.plan.prefill_replicas)
                        + list(self.plan.decode_replicas))}
        dead_groups = [h.group for h in self.pre + self.dec
                       if h.status == "dead" and h.group in plan_groups]
        if not dead_groups:
            return None
        dead_devices = sorted({d for g in dead_groups for d in g})
        wl = ctx["workload"] or self.profiler.as_workload()
        if wl is None:
            self.events.append("failover reschedule skipped: no workload "
                               "observation yet (pass workload= to "
                               "set_failover)")
            return None
        rate = self.profiler.arrival_rate() or ctx["rate"] or 1.0
        init = sched.drop_nodes(ctx["cluster"], self.plan, dead_devices)
        if not init.groups:
            self.events.append("failover reschedule skipped: no surviving "
                               "groups")
            return None
        search = ctx["search_fn"] or sched.reschedule_lightweight
        try:
            new_plan = search(ctx["cluster"], ctx["cfg"], self.plan, wl,
                              rate, ctx["slo"], init_solution=init)
            delta = sched.plan_diff(self.plan, new_plan)
            n = 0 if delta.is_noop else self.apply_plan(delta)
        except Exception as e:
            self.events.append(f"failover reschedule failed: {e}")
            return None
        self.events.append(f"failover reschedule: dropped devices "
                           f"{dead_devices}, {n} request(s) requeued")
        return new_plan

    def heartbeat_all(self):
        now = self.clock()
        for h in self.pre + self.dec:
            if h.alive:
                h.beat(now)

    def _track(self, h: ReplicaHandle, dt: float,
               now: Optional[float] = None):
        now = now if now is not None else self.clock()
        h.beat(now)
        h.last_track = now
        h.ema_latency = 0.8 * h.ema_latency + 0.2 * dt if h.ema_latency \
            else dt
        h.min_latency = min(h.min_latency, dt)

    # -- observability -------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        """Operational counters for drivers/dashboards: queue depths,
        recovery counters, aggregated page-pool stats (incl.
        ``alloc_failures``), and per-replica detector status."""
        pool: Optional[Dict[str, float]] = None
        for d in self.dec:
            # through the client seam (rule R003): an RPC client serves
            # page stats over the wire, there is no engine to reach into
            ps = getattr(d.client, "page_stats", None)
            st = ps() if callable(ps) else None
            if st is None:
                continue
            if pool is None:
                pool = {k: 0.0 for k in
                        ("pages", "in_use", "free", "peak_in_use", "allocs",
                         "frees", "alloc_failures", "leaked_pages",
                         "zero_copy_inserts", "reencoded_inserts",
                         "shares", "unshares", "shared_pages", "cow_copies",
                         "prefix_admits", "prefix_entries", "prefix_pages",
                         "prefix_evictions", "prefix_donations")}
            for k in pool:
                pool[k] += st.get(k, 0)
        probes = self.n_prefix_hits + self.n_prefix_partial \
            + self.n_prefix_miss
        prefix = {
            "hits": self.n_prefix_hits,
            "partial_hits": self.n_prefix_partial,
            "misses": self.n_prefix_miss,
            "hit_rate": ((self.n_prefix_hits + self.n_prefix_partial)
                         / probes if probes else 0.0),
            "hit_tokens": self.n_prefix_tokens_hit,
            "pins_held": len(self._pins),
        }
        out = {
            "epoch": self.epoch,
            "queued": len(self.queue),
            "chunks_in_flight": len(self._chunks),
            "transfers_in_flight": len(self.transfer_queue),
            "retries_pending": len(self.retry_queue),
            "counters": {"retries": self.n_retries,
                         "requeues": self.n_requeues,
                         "migrations": self.n_migrations,
                         "migrated_tokens": self.n_migrated_tokens,
                         "preemptions": self.n_preemptions,
                         "chunk_ticks": self.n_chunk_ticks,
                         "chunked_prefills": self.n_chunked_prefills,
                         "failed": self.n_failed},
            "page_pool": pool,
            "prefix": prefix,
            "replicas": [{"phase": h.phase, "idx": h.idx,
                          "status": h.status,
                          "suspect_why": h.suspect_why,
                          "ema_latency_s": round(h.ema_latency, 6)}
                         for h in self.pre + self.dec],
        }
        if self.sanitizer is not None:
            out["sanitizer"] = self.sanitizer.stats()
        return out

    # -- straggler mitigation -----------------------------------------------

    def refresh_routing_from_latency(self):
        """Bleed traffic away from slow replicas: reweight X/Y by inverse
        measured latency (keeps the TSTP structure, scales the masses).
        The ORIGINAL totals are preserved: when the TSTP deliberately shed
        mass (``Z.sum() < 1``, saturated fleet), renormalizing to 1 would
        silently route the unserved mass back onto the replicas the solver
        judged saturated."""
        if self.o is None:
            return
        lat_p = np.array([max(h.ema_latency, 1e-6) for h in self.pre])
        w = (1.0 / lat_p)
        w /= w.sum()
        x_total = self.o.X.sum()
        X = self.o.X * w
        if X.sum() > 0:
            self.o.X = X * (x_total / X.sum())
        lat_d = np.array([max(h.ema_latency, 1e-6) for h in self.dec])
        wd = (1.0 / lat_d)
        wd /= wd.sum()
        y_totals = self.o.Y.sum(axis=1, keepdims=True)
        Y = self.o.Y * wd[None, :]
        s = Y.sum(axis=1, keepdims=True)
        self.o.Y = np.where(s > 0, Y * y_totals / np.maximum(s, 1e-12),
                            self.o.Y)

    # -- plan epochs: live re-designation ------------------------------------

    def _install_routing(self, o: Optional[Orchestration]) -> bool:
        """Atomically swap the TSTP masses — only when their dimensions
        match the live replica lists (the invariant every epoch ends on)."""
        if (o is not None and o.X.shape[0] == len(self.pre)
                and o.Y.shape == (len(self.pre), len(self.dec))):
            self.o = o
            return True
        return False

    def _is_plan_bound(self) -> bool:
        return (self.plan is not None
                and all(h.group is not None for h in self.pre + self.dec))

    def apply_plan(self, delta) -> int:
        """Transition the running gateway to a new plan epoch.

        ``delta`` is a :class:`~repro.core.scheduler.PlanDelta` (or a new
        ``DeploymentPlan``, diffed against the bound plan). For each group
        in the delta, the live replica:

        * **keeps phase** — untouched (requests in flight stay in flight);
        * **flips** — new work stops routing to it (it leaves the routing
          tables this call rebuilds), in-flight decode requests are
          requeued through the existing failure path (DECODING -> QUEUED,
          tokens kept, regenerated prefix suppressed), then
          ``switch_phase()`` re-roles the RESIDENT parameters — no reload;
        * **died** — marked dead, its requests requeued (node failure
          composes as ``drop_nodes`` -> ``reschedule_lightweight`` ->
          ``plan_diff`` -> here).

        The new routing masses are installed only once the ``pre``/``dec``
        lists match the new plan's dimensions, so routing never sees a
        half-applied epoch. Returns the number of requeued requests."""
        if not isinstance(delta, sched.PlanDelta):
            if self.plan is None:
                raise ValueError("apply_plan needs a PlanDelta (or a plan-"
                                 "bound gateway to diff a new plan against)")
            delta = sched.plan_diff(self.plan, delta)
        if delta.added:
            raise ValueError(
                f"apply_plan cannot materialize replicas for new groups "
                f"{[list(g) for g, _ in delta.added]}: a live epoch "
                f"transition only re-designates resident replicas (run a "
                f"full redeploy for new groups)")
        now = self.clock()
        by_group: Dict[Tuple[int, ...], ReplicaHandle] = {}
        for h in self.pre + self.dec:
            if h.group is None:
                raise ValueError(
                    "apply_plan requires group-tagged replicas: construct "
                    "the gateway with plan= (or via gateway_from_plan)")
            by_group[h.group] = h
        old_dec = list(self.dec)
        n_requeued = 0
        # 1. drain flipping/dying decode replicas through the requeue path
        for g, old_ph, _new_ph in delta.flips:
            h = by_group.get(g)
            if h is not None and old_ph == "decode" and h.alive:
                n_requeued += self._requeue_resident(
                    h, now, f"phase flip decode->prefill on {list(g)}")
        for g, ph in delta.dropped:
            h = by_group.pop(g, None)
            if h is None:
                continue
            if h.alive:
                if ph == "decode":
                    n_requeued += self._requeue_resident(
                        h, now, f"group {list(g)} dropped")
                h.alive = False
            self.events.append(f"epoch {self.epoch + 1}: replica "
                               f"{ph}:{list(g)} dropped from plan")
        # 2. flip the drained replicas around their resident params
        for g, _old_ph, new_ph in delta.flips:
            h = by_group.get(g)
            if h is None:
                continue
            if not h.switchable:
                raise TypeError(
                    f"replica {h.phase}:{h.idx} (group {list(g)}) cannot "
                    f"switch phase: wrap the engine in a Replica to make "
                    f"it live-redesignatable")
            if h.alive:
                h.client.switch_phase(new_ph)
            h.phase = new_ph
        # 3. rebuild the live lists in the new plan's replica order
        new_pre, new_dec = [], []
        for r in delta.new_plan.prefill_replicas:
            h = by_group[sched._group_key(r.devices)]
            h.idx, h.phase = len(new_pre), "prefill"
            new_pre.append(h)
        for r in delta.new_plan.decode_replicas:
            h = by_group[sched._group_key(r.devices)]
            h.idx, h.phase = len(new_dec), "decode"
            new_dec.append(h)
        self.pre, self.dec = new_pre, new_dec
        # 4. retarget in-flight KV transfers (decode indices changed; some
        #    targets may have flipped away or died). Prefix-bound
        #    transfers (page handles / suffix wires) follow their replica
        #    or fall back to a full prefill — never reroute.
        new_idx = {id(h): j for j, h in enumerate(self.dec)}
        alive = [j for j, d in enumerate(self.dec) if d.alive]
        keep_t = []
        for t in self.transfer_queue:
            h_old = (old_dec[t.target] if t.target < len(old_dec) else None)
            j = new_idx.get(id(h_old)) if h_old is not None else None
            if t.replica_bound:
                if j is None or not self.dec[j].dispatchable:
                    self._requeue_handle(t.handle, now,
                                         "(prefix replica left the plan)")
                    continue
                t.target = j
                if t.handle.req.prefix_replica >= 0:
                    t.handle.req.prefix_replica = j
                keep_t.append(t)
                continue
            if j is None or not self.dec[j].alive:
                j = (max(alive, key=lambda jj: self.dec[jj].client.n_free())
                     if alive else 0)
            t.target = j
            keep_t.append(t)
        self.transfer_queue = keep_t
        # queued partial-hit requests hold pins by decode INDEX: remap to
        # the new plan's indices or fall back to a full prefill
        for h in list(self.queue):
            r = h.req
            if r.start_pos > 0 and 0 <= r.prefix_replica < len(old_dec):
                j = new_idx.get(id(old_dec[r.prefix_replica]))
                if j is None or not self.dec[j].dispatchable:
                    self._release_prefix(h)
                else:
                    r.prefix_replica = j
        # in-flight chunk jobs are sticky to their prefill replica (the
        # accumulated KV lives there): if it flipped away or died, restart
        # through the queue
        live_pre = {id(p) for p in self.pre if p.dispatchable}
        for c in list(self._chunks):
            if id(c.pre) not in live_pre:
                self._chunks.remove(c)
                self._requeue_handle(c.handle, now,
                                     "(chunk prefill replica left the plan)")
        # 5. rebuild the transport link table from the new replica->device
        #    map, then atomically install the new routing masses
        if hasattr(self.transport, "rebind_plan"):
            self.transport.rebind_plan(delta.new_plan)
        installed = self._install_routing(delta.new_plan.orchestration)
        if not installed:
            # solver produced no orchestration (or a stale-dimension one):
            # fall back to alive-uniform routing rather than keeping masses
            # indexed against replicas that no longer exist
            self.o = None
        self.plan = delta.new_plan
        self.epoch += 1
        self.events.append(
            f"epoch {self.epoch}: applied plan delta ({delta.describe()}); "
            f"{n_requeued} request(s) requeued, routing "
            f"{'installed' if installed else 'uniform (no orchestration)'}, "
            f"P:{len(self.pre)} D:{len(self.dec)}")
        return n_requeued

    def _requeue_resident(self, h: ReplicaHandle, now: float,
                          why: str) -> int:
        """Requeue every request resident on a decode replica (the same
        path decode-replica death takes): KV is dropped, tokens already
        delivered are kept, the fresh attempt's regenerated prefix is
        suppressed by ``_sync_tokens``."""
        n = 0
        for req in list(h.client.resident()):
            h.client.release(req)
            hd = self._by_req.get(id(req))
            if hd is None:
                continue
            self._requeue_handle(hd, now, f": {why}")
            n += 1
        return n

    # -- workload shift -> lightweight rescheduling --------------------------

    def maybe_reschedule(self, cluster, cfg: ModelConfig, plan=None,
                         rate: Optional[float] = None,
                         slo: Optional[SloSpec] = None, *,
                         search_fn=None):
        """Profiler-gated lightweight rescheduling (paper §3.4).

        When the profiler reports a workload shift, re-solves phase
        designation + TSTP for the OBSERVED workload and arrival rate (the
        caller-supplied ``rate`` is only a fallback for an empty window)
        and applies the result to the running gateway as a plan epoch
        (plan-bound gateways flip live replicas; legacy plan-less gateways
        just install the masses, and only when dimensions match).
        ``search_fn`` (same signature as
        :func:`repro.core.scheduler.reschedule_lightweight`) lets tests
        and drivers pin the search."""
        plan = plan if plan is not None else self.plan
        if plan is None:
            raise ValueError("maybe_reschedule needs a plan: pass one or "
                             "bind the gateway with plan=")
        if slo is None:
            raise ValueError("maybe_reschedule needs an SloSpec")
        if not self.profiler.shift_detected():
            return None
        wl = self.profiler.as_workload()
        stats = self.profiler.stats()
        if wl is None or stats is None:
            # fewer than 8 records in the window: the shift signal cannot
            # be trusted and there is no workload to re-plan for
            return None
        # offered load, measured at submit: under saturation (when a
        # reschedule matters most) the completion rate is capped by the
        # stale plan's capacity and would underestimate the true demand
        obs_rate = self.profiler.arrival_rate()
        if obs_rate is None or obs_rate <= 0:
            obs_rate = stats.rate if stats.rate > 0 else float(rate or 0.0)
        search = search_fn or sched.reschedule_lightweight
        new_plan = search(cluster, cfg, plan, wl, obs_rate, slo)
        delta = sched.plan_diff(plan, new_plan)
        if self._is_plan_bound():
            if delta.is_noop:
                # same designation, fresh masses for the observed workload:
                # a routing refresh, not a full epoch transition
                self._install_routing(new_plan.orchestration)
                self.plan = new_plan
            else:
                self.apply_plan(delta)
        else:
            if not self._install_routing(new_plan.orchestration):
                self.events.append(
                    "routing masses not installed: plan dimensions "
                    f"({len(new_plan.prefill_replicas)}P/"
                    f"{len(new_plan.decode_replicas)}D) do not match the "
                    f"live replicas ({len(self.pre)}P/{len(self.dec)}D) "
                    "and this gateway is not plan-bound")
            self.plan = new_plan
        self.profiler.set_baseline()
        self.events.append(
            f"lightweight rescheduling: {new_plan.search_seconds:.2f}s, "
            f"observed rate {obs_rate:.2f}/s, "
            f"P:{len(new_plan.prefill_replicas)} "
            f"D:{len(new_plan.decode_replicas)} ({delta.describe()})")
        return new_plan


# -- plan-bound construction --------------------------------------------------


def gateway_from_plan(plan, cfg: ModelConfig, params, *,
                      transport: Optional[Transport] = None,
                      max_seq: int = 64, max_slots: int = 4,
                      chunk_size: int = 4, rt=None,
                      prefill_kw: Optional[Dict] = None,
                      decode_kw: Optional[Dict] = None,
                      scheduler: Optional[SchedulerConfig] = None,
                      **gw_kw) -> Gateway:
    """Instantiate one phase-switchable :class:`Replica` per plan replica
    (all sharing ``params`` — the in-process stand-in for each group's
    resident sharded weights) and bind the gateway to the plan, so
    ``apply_plan`` / ``maybe_reschedule`` can run live epoch transitions.
    Engine kwargs are shared by every replica; per-phase extras go in
    ``prefill_kw`` / ``decode_kw``."""
    dkw = {"max_slots": max_slots, "chunk_size": chunk_size,
           **(decode_kw or {})}
    pres = [Replica(cfg, params, phase="prefill", max_seq=max_seq, rt=rt,
                    prefill_kw=prefill_kw, decode_kw=dkw)
            for _ in plan.prefill_replicas]
    decs = [Replica(cfg, params, phase="decode", max_seq=max_seq, rt=rt,
                    prefill_kw=prefill_kw, decode_kw=dkw)
            for _ in plan.decode_replicas]
    return Gateway(pres, decs, transport=transport, plan=plan,
                   scheduler=scheduler, **gw_kw)


# -- open-loop driving helpers ------------------------------------------------


def warmup_engines(prefills: Sequence[PrefillEngine],
                   decodes: Sequence[DecodeEngine], vocab_size: int, *,
                   compress: bool = True, backend: str = "auto",
                   prompt_lens: Sequence[int] = (8,), max_new: int = 2):
    """Compile the prefill/decode jit paths before an open-loop run so the
    first real request's TTFT measures serving, not XLA compilation. Pass
    the prompt lengths the trace will actually use — each distinct
    (power-of-two bucket, batch width) still compiles once. Engines are
    paired round-robin (jit caches are per-engine, so the full
    prefill x decode cross-product would add wall time, not coverage)."""
    rng = np.random.default_rng(0)
    for ln in prompt_lens:
        for k in range(max(len(prefills), len(decodes))):
            pre = prefills[k % len(prefills)]
            dec = decodes[k % len(decodes)]
            req = GenRequest(-1, rng.integers(
                1, vocab_size, int(ln)).astype(np.int32), max_new)
            dec.admit(AdmissionBatch(
                [AdmissionItem(r, f, ADMIT_FRESH, wire=w)
                 for r, w, f in pre.run([req], compress=compress,
                                        backend=backend)]), backend=backend)
            while dec.active:
                dec.step()


def warmup_gateway(gw: Gateway, vocab_size: int, *,
                   prompt_lens: Sequence[int] = (8,), max_new: int = 2):
    """:func:`warmup_engines` for a constructed :class:`Gateway`: drives
    the same compile-priming traffic through the replica CLIENT seams
    (rule R003 — works unchanged when the clients are RPC proxies), and
    marks the post-warmup point for the sanitizer's jit-retrace monitor.
    Heartbeats are refreshed afterwards so compile time is not mistaken
    for replica death."""
    rng = np.random.default_rng(0)
    pres = [h.client for h in gw.pre]
    decs = [h.client for h in gw.dec]
    budget = gw.scheduler.prefill_chunk_tokens
    for ln in prompt_lens:
        for k in range(max(len(pres), len(decs))):
            pre = pres[k % len(pres)]
            dec = decs[k % len(decs)]
            req = GenRequest(-1, rng.integers(
                1, vocab_size, int(ln)).astype(np.int32), max_new)
            sup = getattr(pre, "supports_suffix", None)
            if budget > 0 and callable(sup) and sup():
                # prime the chunked path: every suffix bucket the chunk
                # walk visits for this prompt length compiles here
                job = PartialPrefill(req)
                while not job.done:
                    pre.prefill_chunk([job], budget, compress=gw.compress,
                                      backend=gw.backend)
                items = [(req, job.wire(), job.first)]
            else:
                items = pre.prefill([req], compress=gw.compress,
                                    backend=gw.backend)
            rejected = dec.admit(AdmissionBatch(
                [AdmissionItem(r, f, ADMIT_FRESH, wire=w)
                 for r, w, f in items]), backend=gw.backend)
            if rejected:
                raise RuntimeError(f"warmup request rejected by decode "
                                   f"replica ({len(rejected)} items)")
            while dec.active:
                dec.step()
    gw.heartbeat_all()
    if gw.sanitizer is not None:
        gw.sanitizer.on_steady(gw)


def drive_open_loop(gw: Gateway, arrivals: Sequence[Tuple[float,
                                                          ServeRequest]], *,
                    time_scale: float = 1.0, max_iters: int = 200000,
                    on_token: Optional[Callable[[RequestHandle, int], None]]
                    = None,
                    tick: Optional[Callable[[Gateway], None]] = None,
                    tick_interval_s: float = 0.25) -> List[RequestHandle]:
    """Open-loop driver: submit each request at its trace arrival time
    (scaled by ``time_scale``) against the wall clock, pumping the gateway
    between arrivals, then drain. This is how a service is actually driven
    — dumping the whole trace at t=0 makes every E2E number meaningless.

    ``tick`` is the control-plane hook: it fires at most every
    ``tick_interval_s`` of driver-clock time with the gateway as argument
    — the place to run ``maybe_reschedule`` /
    ``refresh_routing_from_latency`` (or inject failures) against live
    traffic.

    Time flows through ``gw.clock`` / ``gw._sleep`` (rule R001): with the
    default wall clock the behavior is unchanged, while a
    :class:`~repro.serving.faults.VirtualClock` makes the whole open-loop
    run deterministic (idle waits advance virtual time instead of
    sleeping).
    """
    pending = sorted(arrivals, key=lambda a: a[0])
    gw.heartbeat_all()      # time spent in setup/warmup is not a failure
    t0 = gw.clock()
    handles: List[RequestHandle] = []
    i = 0
    it = 0
    last_tick = t0
    while i < len(pending) or gw.queue or gw._chunks \
            or gw.transfer_queue or gw.retry_queue \
            or any(d.alive and d.client.active for d in gw.dec):
        if tick is not None and gw.clock() - last_tick >= tick_interval_s:
            tick(gw)
            last_tick = gw.clock()
        now = gw.clock() - t0
        while i < len(pending) and pending[i][0] * time_scale <= now:
            handles.append(gw.submit(pending[i][1], on_token=on_token))
            i += 1
        busy = (gw.queue or gw._chunks or gw.transfer_queue
                or gw.retry_queue
                or any(d.alive and d.client.active for d in gw.dec))
        if busy:
            n = gw.pump()
            if n == 0 and not gw.queue and not gw._chunks \
                    and (gw.transfer_queue or gw.retry_queue) \
                    and not any(d.alive and d.client.active
                                for d in gw.dec):
                # only in-flight simulated wires remain: wait for t_ready
                # instead of burning the iteration budget (same wedge
                # guard as run_until_drained)
                gw._sleep(2e-4)
        elif i < len(pending):
            # idle until the next arrival — don't burn the iteration budget
            gw._sleep(min(pending[i][0] * time_scale - now, 5e-3))
        it += 1
        if it > max_iters:
            break
    gw.sanitize_check("drive_open_loop")
    return handles


def summarize_handles(handles: Sequence[RequestHandle]) -> Dict[str, object]:
    """TTFT/TPOT/E2E percentiles + goodput over one open-loop run.

    Goodput is deadline attainment: the fraction of *submitted* requests
    that finished AND met both their TTFT and E2E deadlines (an infinite
    deadline is trivially met)."""
    def pct(xs, q):
        xs = [x for x in xs if not math.isnan(x)]
        return float(np.percentile(xs, q)) if xs else math.nan

    done = [h for h in handles if h.state == DONE]
    good = [h for h in done
            if h.ttft <= h.request.ttft_deadline_s
            and h.e2e <= h.request.e2e_deadline_s]
    states: Dict[str, int] = {}
    for h in handles:
        states[h.state] = states.get(h.state, 0) + 1
    ttft = [h.ttft for h in done]
    tpot = [h.tpot for h in done]
    e2e = [h.e2e for h in done]
    toks = sum(len(h.tokens) for h in done)
    return {
        "n_submitted": len(handles), "n_done": len(done),
        "states": states, "tokens": toks,
        "goodput": len(good) / max(len(handles), 1),
        "ttft_p50_s": pct(ttft, 50), "ttft_p99_s": pct(ttft, 99),
        "tpot_p50_s": pct(tpot, 50), "tpot_p99_s": pct(tpot, 99),
        "e2e_p50_s": pct(e2e, 50), "e2e_p99_s": pct(e2e, 99),
    }
