"""Serving gateway (paper Appendix E as a *service* API).

The public surface of the serving stack: ``Gateway.submit(ServeRequest)``
returns a :class:`RequestHandle` carrying an explicit request lifecycle

    QUEUED -> PREFILLING -> TRANSFERRING -> DECODING -> DONE
                                 |               |
            CANCELLED / REJECTED / FAILED        +-> QUEUED (replica failure)

with streaming token delivery (callback and iterator), ``cancel()``,
per-request deadline/priority, and admission control that sheds requests
whose TTFT deadline is provably missed while still queued.

Replicas are reached only through the narrow :class:`PrefillClient` /
:class:`DecodeClient` interfaces and KV state moves only through a
:class:`~repro.serving.transport.Transport`, so a multi-host RPC
realization (each replica = a pod slice driven over the wire) slots in
without touching routing, heartbeats, or rescheduling logic
(DESIGN.md §5).

The legacy ``Coordinator`` entry points remain as a thin deprecated shim
over this class (``repro.serving.coordinator``).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import (Callable, Dict, Iterator, List, Optional, Protocol,
                    Sequence, Tuple)

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import scheduler as sched
from repro.core.orchestrator import Orchestration, SloSpec
from repro.serving.engine import DecodeEngine, GenRequest, PrefillEngine
from repro.serving.kv_transfer import KVWire
from repro.serving.profiler import WorkloadProfiler
from repro.serving.transport import (InProcessTransport, TransferTicket,
                                     Transport)

# -- request lifecycle --------------------------------------------------------

QUEUED = "QUEUED"
PREFILLING = "PREFILLING"
TRANSFERRING = "TRANSFERRING"
DECODING = "DECODING"
DONE = "DONE"
CANCELLED = "CANCELLED"
REJECTED = "REJECTED"
FAILED = "FAILED"

TERMINAL_STATES = frozenset({DONE, CANCELLED, REJECTED, FAILED})

_TRANSITIONS: Dict[str, frozenset] = {
    QUEUED: frozenset({PREFILLING, CANCELLED, REJECTED, FAILED}),
    PREFILLING: frozenset({TRANSFERRING, CANCELLED, FAILED}),
    TRANSFERRING: frozenset({DECODING, QUEUED, CANCELLED, FAILED}),
    DECODING: frozenset({DONE, QUEUED, CANCELLED, FAILED}),
    DONE: frozenset(), CANCELLED: frozenset(),
    REJECTED: frozenset(), FAILED: frozenset(),
}


@dataclass
class ServeRequest:
    """What a client submits: prompt + generation budget + SLO terms."""
    rid: int
    tokens: np.ndarray                   # prompt token ids (1D)
    max_new_tokens: int
    extras: Dict[str, np.ndarray] = field(default_factory=dict)
    priority: int = 0                    # higher dispatches first
    ttft_deadline_s: float = math.inf    # relative to submit time
    e2e_deadline_s: float = math.inf

    @classmethod
    def from_gen(cls, req: GenRequest, **kw) -> "ServeRequest":
        return cls(req.rid, req.tokens, req.max_new_tokens,
                   extras=req.extras, **kw)


class RequestHandle:
    """Live view of one request's journey through the gateway.

    Tokens stream into :attr:`tokens` as decode chunks complete (first
    tokens are observable long before ``run_until_drained`` returns);
    ``on_token`` fires per delivered token. Terminal state, a
    human-readable :attr:`reason` for REJECTED/FAILED, and per-request
    TTFT/TPOT/E2E metrics live here — engines never mutate timestamps.
    """

    def __init__(self, request: ServeRequest, gen: GenRequest,
                 gateway: "Gateway",
                 on_token: Optional[Callable[["RequestHandle", int], None]]
                 = None):
        self.request = request
        self.req = gen                   # engine-level unit (owns out_tokens)
        self.tokens: List[int] = []
        self.on_token = on_token
        self.state = QUEUED
        self.reason: Optional[str] = None
        self.restarts = 0
        self.t_submit = time.time()
        self.t_first = -1.0
        self.t_done = -1.0
        self.history: List[Tuple[float, str]] = [(self.t_submit, QUEUED)]
        self._gateway = gateway
        self._engine_seen = 0     # out_tokens consumed from current attempt

    # -- state machine ------------------------------------------------------

    @property
    def is_terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    def _transition(self, state: str, now: Optional[float] = None,
                    reason: Optional[str] = None):
        if state == self.state:
            return
        if state not in _TRANSITIONS[self.state]:
            raise RuntimeError(f"illegal transition {self.state} -> {state} "
                               f"for request {self.request.rid}")
        now = now if now is not None else time.time()
        self.state = state
        self.history.append((now, state))
        if reason is not None:
            self.reason = reason
        if state == DONE:
            self.t_done = now

    def _deliver(self, toks: Sequence[int], now: float):
        for t in toks:
            if self.t_first < 0:
                self.t_first = now
            self.tokens.append(int(t))
            if self.on_token is not None:
                self.on_token(self, int(t))

    def _requeue(self, now: float):
        """Decode-replica failure: KV is gone, go back through prefill.
        The DECODING -> QUEUED edge stays visible in :attr:`history`.
        Already-delivered tokens are KEPT — the restarted attempt's
        regenerated prefix is suppressed in ``_sync_tokens`` so streaming
        consumers never see a duplicate (greedy decoding regenerates the
        identical prefix)."""
        self._transition(QUEUED, now)
        self.restarts += 1
        self.req.out_tokens = []
        self._engine_seen = 0

    # -- client API ---------------------------------------------------------

    def cancel(self) -> bool:
        """Abort the request; frees its decode slot if mid-decode."""
        return self._gateway.cancel(self)

    def stream(self, *, max_iters: int = 100000) -> Iterator[int]:
        """Yield tokens as they are produced, pumping the gateway while
        waiting. Returns when the request reaches a terminal state."""
        sent = 0
        it = 0
        while True:
            while sent < len(self.tokens):
                yield self.tokens[sent]
                sent += 1
            if self.is_terminal:
                return
            if it >= max_iters:
                raise RuntimeError(f"request {self.request.rid} stalled in "
                                   f"{self.state}")
            self._gateway.pump()
            it += 1

    def result(self, *, max_iters: int = 100000) -> List[int]:
        """Block (pumping the gateway) until terminal; returns the tokens."""
        for _ in self.stream(max_iters=max_iters):
            pass
        return list(self.tokens)

    # -- metrics ------------------------------------------------------------

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_submit if self.t_first > 0 else math.nan

    @property
    def e2e(self) -> float:
        return self.t_done - self.t_submit if self.t_done > 0 else math.nan

    @property
    def tpot(self) -> float:
        if self.t_done < 0 or self.t_first < 0 or len(self.tokens) <= 1:
            return math.nan
        return (self.t_done - self.t_first) / (len(self.tokens) - 1)

    def metrics(self) -> Dict[str, object]:
        r = self.request
        return {"rid": r.rid, "state": self.state, "reason": self.reason,
                "n_tokens": len(self.tokens), "restarts": self.restarts,
                "ttft_s": self.ttft, "tpot_s": self.tpot, "e2e_s": self.e2e,
                "ttft_met": (self.state == DONE
                             and self.ttft <= r.ttft_deadline_s),
                "e2e_met": (self.state == DONE
                            and self.e2e <= r.e2e_deadline_s)}


# -- replica clients ----------------------------------------------------------


class PrefillClient(Protocol):
    """Everything the gateway needs from a prefill replica. A multi-host
    deployment implements this over RPC; the prompt goes out, wires and
    first tokens come back."""

    def prefill(self, reqs: List[GenRequest], *, compress: bool,
                backend: str) -> List[Tuple[GenRequest, KVWire, int]]:
        ...


class DecodeClient(Protocol):
    """Everything the gateway needs from a decode replica."""

    def admit(self, items: Sequence[Tuple[GenRequest, KVWire, int]], *,
              backend: str) -> List[Tuple[GenRequest, KVWire, int]]:
        ...

    def step(self) -> List[GenRequest]:
        ...

    def n_free(self) -> int:
        ...

    @property
    def active(self) -> int:
        ...

    def resident(self) -> List[GenRequest]:
        ...

    def release(self, req: GenRequest) -> bool:
        ...


class LocalPrefillClient:
    """In-process realization around a :class:`PrefillEngine`."""

    synchronous = True      # a blocking call that returns proves liveness

    def __init__(self, engine: PrefillEngine):
        self.engine = engine

    def prefill(self, reqs, *, compress, backend):
        return self.engine.run(reqs, compress=compress, backend=backend)


class LocalDecodeClient:
    """In-process realization around a :class:`DecodeEngine`."""

    synchronous = True      # a blocking call that returns proves liveness

    def __init__(self, engine: DecodeEngine):
        self.engine = engine

    def admit(self, items, *, backend):
        return self.engine.admit_batch(items, backend=backend)

    def step(self):
        return self.engine.step()

    def n_free(self) -> int:
        return len(self.engine.free_slots())

    @property
    def active(self) -> int:
        return self.engine.active

    def resident(self):
        return [r for r in self.engine.slots if r is not None]

    def release(self, req) -> bool:
        for i, r in enumerate(self.engine.slots):
            if r is req:
                self.engine.release(i)
                return True
        return False


def _as_prefill_client(obj) -> PrefillClient:
    return LocalPrefillClient(obj) if isinstance(obj, PrefillEngine) else obj


def _as_decode_client(obj) -> DecodeClient:
    return LocalDecodeClient(obj) if isinstance(obj, DecodeEngine) else obj


@dataclass
class ReplicaHandle:
    """Gateway-side view of one replica: liveness + latency tracking."""
    idx: int
    phase: str
    client: object
    alive: bool = True
    last_heartbeat: float = field(default_factory=time.time)
    ema_latency: float = 0.0            # straggler tracking
    min_latency: float = math.inf       # lower bound for deadline shedding

    def beat(self):
        self.last_heartbeat = time.time()

    @property
    def engine(self):
        """Underlying in-process engine, when there is one (local clients
        only — an RPC client has no engine attribute)."""
        return getattr(self.client, "engine", None)


@dataclass
class _Transfer:
    handle: RequestHandle
    ticket: TransferTicket
    first: int
    target: int


# -- the gateway --------------------------------------------------------------


class Gateway:
    """Request-lifecycle facade over phase-split serving replicas.

    Owns TSTP routing (X/Y masses from the orchestration), heartbeat
    failure detection, straggler-aware routing refresh, workload-shift
    rescheduling, deadline admission control, and the prefill ->
    transport -> decode pipeline. One ``pump()`` is one event-loop
    iteration; ``run_until_drained()`` drives until every submitted
    request reaches a terminal state.
    """

    def __init__(self, prefills: Sequence, decodes: Sequence, *,
                 transport: Optional[Transport] = None,
                 orchestration: Optional[Orchestration] = None,
                 compress: bool = True, backend: str = "auto",
                 heartbeat_timeout: float = 10.0, seed: int = 0):
        self.pre = [ReplicaHandle(i, "prefill", _as_prefill_client(e))
                    for i, e in enumerate(prefills)]
        self.dec = [ReplicaHandle(j, "decode", _as_decode_client(e))
                    for j, e in enumerate(decodes)]
        self.transport: Transport = transport or InProcessTransport()
        self.o = orchestration
        self.compress = compress
        self.backend = backend
        self.heartbeat_timeout = heartbeat_timeout
        self.rng = np.random.default_rng(seed)
        self.profiler = WorkloadProfiler()
        self.queue: List[RequestHandle] = []
        self.transfer_queue: List[_Transfer] = []
        self.done: List[RequestHandle] = []
        self.events: List[str] = []
        self._by_req: Dict[int, RequestHandle] = {}   # id(GenRequest) -> h
        self._decode_outage_reported = False

    # -- routing ------------------------------------------------------------

    def _X(self) -> np.ndarray:
        alive = np.array([r.alive for r in self.pre], float)
        if self.o is not None and self.o.X.shape[0] == len(self.pre):
            x = self.o.X * alive
        else:
            x = alive
        s = x.sum()
        return x / s if s > 0 else alive / max(alive.sum(), 1)

    def _Y(self, i: int) -> np.ndarray:
        alive = np.array([r.alive for r in self.dec], float)
        if self.o is not None and self.o.Y.shape == (len(self.pre),
                                                     len(self.dec)):
            y = self.o.Y[i] * alive
        else:
            y = alive
        s = y.sum()
        return y / s if s > 0 else alive / max(alive.sum(), 1)

    # -- request lifecycle --------------------------------------------------

    def submit(self, request, *,
               on_token: Optional[Callable[[RequestHandle, int], None]]
               = None) -> RequestHandle:
        """Admit a request into the gateway; returns its live handle.

        Accepts a :class:`ServeRequest` (the v2 API) or a bare
        :class:`GenRequest` (deprecated shim path: no deadlines, default
        priority)."""
        if isinstance(request, GenRequest):
            gen = request
            request = ServeRequest.from_gen(gen)
        else:
            gen = GenRequest(request.rid,
                             np.asarray(request.tokens, np.int32),
                             request.max_new_tokens,
                             extras=dict(request.extras))
        h = RequestHandle(request, gen, self, on_token=on_token)
        gen.t_submit = h.t_submit
        self._by_req[id(gen)] = h
        self.queue.append(h)
        return h

    def cancel(self, h: RequestHandle) -> bool:
        """Abort a request in any non-terminal state; a mid-decode cancel
        releases the slot (and its cache length) immediately."""
        if h.is_terminal:
            return False
        now = time.time()
        if h in self.queue:
            self.queue.remove(h)
        self.transfer_queue = [t for t in self.transfer_queue
                               if t.handle is not h]
        if h.state == DECODING:
            for d in self.dec:
                if d.client.release(h.req):
                    break
        h._transition(CANCELLED, now, reason="cancelled by client")
        self._finish(h)
        self.events.append(f"request {h.request.rid} cancelled "
                           f"({h.history[-2][1].lower()})")
        return True

    # -- admission control --------------------------------------------------

    def _min_prefill_estimate(self) -> float:
        """Best-case seconds of work between *now* and the first token for
        a request still in the queue: the fastest latency EVER observed on
        an alive prefill replica plus the smallest transport hop ever
        paid. Minima — not EMAs, which one jit-compile spike would inflate
        — and unmeasured components count as zero, so shedding stays
        conservative. Caveat: the minima are conditioned on the batch /
        bucket / wire shapes actually served so far; a request much
        smaller than everything previously observed could in principle
        beat them (the estimate is the tightest bound available without a
        per-shape cost model)."""
        mins = [r.min_latency for r in self.pre
                if r.alive and math.isfinite(r.min_latency)]
        est = min(mins) if mins else 0.0
        est += getattr(self.transport, "min_delay_s", 0.0) or 0.0
        return est

    def _shed_expired(self, now: float):
        if not self.queue:
            return
        est = self._min_prefill_estimate()
        keep = []
        for h in self.queue:
            dl = h.request.ttft_deadline_s
            waited = now - h.t_submit
            if math.isfinite(dl) and waited + est > dl:
                h._transition(
                    REJECTED, now,
                    reason=(f"TTFT deadline provably missed while queued: "
                            f"waited {waited:.3f}s + best-case "
                            f"{est:.3f}s > deadline {dl:.3f}s"))
                self._finish(h)
                self.events.append(
                    f"request {h.request.rid} rejected: ttft deadline")
            else:
                keep.append(h)
        self.queue = keep

    # -- event loop ---------------------------------------------------------

    def pump(self, *, max_prefill_batch: int = 4) -> int:
        """One gateway iteration; returns #finished this round."""
        now = time.time()
        self._check_heartbeats()
        self._shed_expired(now)
        # 1. dispatch queued prompts: drain EVERY alive prefill replica
        #    this round (the TSTP masses only order who gets fed first)
        if self.queue:
            self.queue.sort(key=lambda h: (-h.request.priority, h.t_submit))
            X = self._X()
            cand = [i for i in range(len(self.pre))
                    if self.pre[i].alive and X[i] > 0]
            if len(cand) > 1:
                p = X[cand] / X[cand].sum()
                cand = [int(i) for i in self.rng.choice(
                    cand, size=len(cand), replace=False, p=p)]
            for i in cand:
                if not self.queue:
                    break
                batch = self.queue[:max_prefill_batch]
                self.queue = self.queue[max_prefill_batch:]
                self._dispatch_prefill(i, batch)
        # 2. drain KV transfers whose wires have arrived into decode slots
        #    (prefill-side queueing: wires wait here if the target has no
        #    free slot, cf. Appendix E)
        self._drain_transfers()
        # 3. advance every decode replica one chunk of steps; stream every
        #    newly emitted token to its handle
        return self._step_decodes()

    def _dispatch_prefill(self, i: int, batch: List[RequestHandle]):
        t0 = time.time()
        for h in batch:
            h._transition(PREFILLING, t0)
        results = self.pre[i].client.prefill(
            [h.req for h in batch], compress=self.compress,
            backend=self.backend)
        t1 = time.time()
        self._track(self.pre[i], t1 - t0)
        Y = self._Y(i)
        routable = Y.sum() > 0
        for req, wire, first in results:
            h = self._by_req[id(req)]
            h._transition(TRANSFERRING, t1)
            # with no alive decode replica the target is a placeholder;
            # _drain_transfers holds the wire + events
            j = (int(self.rng.choice(len(self.dec), p=Y)) if routable else 0)
            ticket = self.transport.send(wire, i, j, now=t1)
            self.transfer_queue.append(_Transfer(h, ticket, first, j))

    def _drain_transfers(self):
        if not self.transfer_queue:
            return
        now = time.time()
        arrived = [t for t in self.transfer_queue if t.ticket.ready(now)]
        in_flight = [t for t in self.transfer_queue
                     if not t.ticket.ready(now)]
        if not arrived:
            return
        alive = [j for j, d in enumerate(self.dec) if d.alive]
        if not alive:
            # do NOT silently reroute to replica 0 (it is dead too) — keep
            # the wires queued and surface the outage once
            if not self._decode_outage_reported:
                self.events.append(
                    "all decode replicas dead; KV transfers stalled")
                self._decode_outage_reported = True
            return
        self._decode_outage_reported = False
        by_target: Dict[int, List[_Transfer]] = {}
        for t in arrived:
            j = t.target
            if not self.dec[j].alive:
                # reroute to the alive replica with the most free slots
                j = max(alive, key=lambda jj: self.dec[jj].client.n_free())
            by_target.setdefault(j, []).append(t)
        still = in_flight
        for j, items in by_target.items():
            n_free = self.dec[j].client.n_free()
            take, rest = items[:n_free], items[n_free:]
            if take:
                rejected = self.dec[j].client.admit(
                    [(t.handle.req, t.ticket.wire, t.first) for t in take],
                    backend=self.backend)
                rej_reqs = {id(r) for r, _, _ in rejected}
                t_adm = time.time()
                for t in take:
                    if id(t.handle.req) in rej_reqs:
                        rest.append(t)
                        continue
                    t.handle._transition(DECODING, t_adm)
                    self._sync_tokens(t.handle, t_adm)
            still.extend(rest)
        self.transfer_queue = still

    def _step_decodes(self) -> int:
        n_done = 0
        for handle in self.dec:
            if not handle.alive:
                continue
            t0 = time.time()
            finished = handle.client.step()
            t1 = time.time()
            if handle.client.active or finished:
                self._track(handle, t1 - t0)
            for req in handle.client.resident():
                self._sync_tokens(self._by_req[id(req)], t1)
            for req in finished:
                h = self._by_req[id(req)]
                self._sync_tokens(h, t1)
                h._transition(DONE, t1)
                self.profiler.record(len(req.tokens), len(req.out_tokens))
                self._finish(h)
                n_done += 1
        return n_done

    def _finish(self, h: RequestHandle):
        """Terminal bookkeeping: the GenRequest leaves the routing tables
        so a long-running service doesn't grow without bound (the handle
        itself stays in ``done`` until ``clear_finished``)."""
        self._by_req.pop(id(h.req), None)
        self.done.append(h)

    def clear_finished(self) -> List[RequestHandle]:
        """Hand over (and forget) terminal handles + events — call
        periodically from a long-running service loop to bound memory."""
        out, self.done = self.done, []
        self.events.clear()
        return out

    def _sync_tokens(self, h: RequestHandle, now: float):
        """Stream tokens the engines appended to ``req.out_tokens`` since
        the last pump into the handle (timestamps live on the handle, not
        the GenRequest). After a failure requeue the restarted attempt
        regenerates the already-delivered prefix (greedy decode is
        deterministic): those positions are swallowed instead of re-firing
        ``on_token`` or growing ``tokens`` twice."""
        out = h.req.out_tokens
        pos = h._engine_seen
        new = out[pos:]
        if not new:
            return
        h._engine_seen = len(out)
        replay = len(h.tokens) - pos        # delivered positions to skip
        if replay > 0:
            new = new[replay:]
        if new:
            h._deliver(new, now)

    def run_until_drained(self, *, max_iters: int = 10000,
                          poll_s: float = 2e-4) -> List[RequestHandle]:
        """Drive until every submitted request is terminal (or decode is
        wedged); returns terminal handles in completion order."""
        it = 0
        while (self.queue or self.transfer_queue
               or any(d.alive and d.client.active for d in self.dec)) \
                and it < max_iters:
            n = self.pump()
            it += 1
            if n == 0 and not self.queue and self.transfer_queue \
                    and not any(d.alive and d.client.active
                                for d in self.dec):
                # nothing computable until a simulated wire lands (or a
                # dead fleet recovers): don't burn max_iters busy-spinning
                time.sleep(poll_s)
        return self.done

    # -- fault tolerance ----------------------------------------------------

    def _check_heartbeats(self):
        now = time.time()
        for h in self.pre + self.dec:
            if not h.alive:
                continue
            if getattr(h.client, "synchronous", False):
                # an in-process client cannot miss a heartbeat: its calls
                # block, so wall time spent elsewhere (traffic gaps, jit
                # compilation) is not evidence of replica death — only
                # kill_replica takes a local replica down. Timeout-based
                # death is for asynchronous/remote clients.
                h.beat()
                continue
            if now - h.last_heartbeat > self.heartbeat_timeout:
                h.alive = False
                self.events.append(f"replica {h.phase}:{h.idx} timed out")
                self._recover_from(h)

    def kill_replica(self, phase: str, idx: int):
        """Failure injection (tests/benchmarks)."""
        group = self.pre if phase == "prefill" else self.dec
        group[idx].alive = False
        self.events.append(f"replica {phase}:{idx} killed")
        self._recover_from(group[idx])

    def _recover_from(self, h: ReplicaHandle):
        """Requests in a dead decode replica lose their KV — their handles
        transition DECODING -> QUEUED (visible in ``history``, counted in
        ``restarts``) and they re-enter the queue for a fresh prefill on a
        surviving replica."""
        if h.phase != "decode":
            return
        now = time.time()
        for req in h.client.resident():
            h.client.release(req)
            hd = self._by_req[id(req)]
            hd._requeue(now)
            self.queue.append(hd)
            self.events.append(f"request {req.rid} re-queued after "
                               f"decode:{h.idx} failure")

    def heartbeat_all(self):
        for h in self.pre + self.dec:
            if h.alive:
                h.beat()

    def _track(self, h: ReplicaHandle, dt: float):
        h.beat()
        h.ema_latency = 0.8 * h.ema_latency + 0.2 * dt if h.ema_latency \
            else dt
        h.min_latency = min(h.min_latency, dt)

    # -- straggler mitigation -----------------------------------------------

    def refresh_routing_from_latency(self):
        """Bleed traffic away from slow replicas: reweight X/Y by inverse
        measured latency (keeps the TSTP structure, scales the masses)."""
        if self.o is None:
            return
        lat_p = np.array([max(h.ema_latency, 1e-6) for h in self.pre])
        w = (1.0 / lat_p)
        w /= w.sum()
        X = self.o.X * w
        if X.sum() > 0:
            self.o.X = X / X.sum()
        lat_d = np.array([max(h.ema_latency, 1e-6) for h in self.dec])
        wd = (1.0 / lat_d)
        wd /= wd.sum()
        Y = self.o.Y * wd[None, :]
        s = Y.sum(axis=1, keepdims=True)
        self.o.Y = np.where(s > 0, Y / np.maximum(s, 1e-12), self.o.Y)

    # -- workload shift -> lightweight rescheduling --------------------------

    def maybe_reschedule(self, cluster, cfg: ModelConfig, plan, rate: float,
                         slo: SloSpec):
        if not self.profiler.shift_detected():
            return None
        wl = self.profiler.as_workload()
        new_plan = sched.reschedule_lightweight(cluster, cfg, plan, wl, rate,
                                                slo)
        self.o = new_plan.orchestration
        self.profiler.set_baseline()
        self.events.append(
            f"lightweight rescheduling: {new_plan.search_seconds:.2f}s, "
            f"P:{len(new_plan.prefill_replicas)} "
            f"D:{len(new_plan.decode_replicas)}")
        return new_plan


# -- open-loop driving helpers ------------------------------------------------


def warmup_engines(prefills: Sequence[PrefillEngine],
                   decodes: Sequence[DecodeEngine], vocab_size: int, *,
                   compress: bool = True, backend: str = "auto",
                   prompt_lens: Sequence[int] = (8,), max_new: int = 2):
    """Compile the prefill/decode jit paths before an open-loop run so the
    first real request's TTFT measures serving, not XLA compilation. Pass
    the prompt lengths the trace will actually use — each distinct
    (power-of-two bucket, batch width) still compiles once. Engines are
    paired round-robin (jit caches are per-engine, so the full
    prefill x decode cross-product would add wall time, not coverage)."""
    rng = np.random.default_rng(0)
    for ln in prompt_lens:
        for k in range(max(len(prefills), len(decodes))):
            pre = prefills[k % len(prefills)]
            dec = decodes[k % len(decodes)]
            req = GenRequest(-1, rng.integers(
                1, vocab_size, int(ln)).astype(np.int32), max_new)
            for r, w, f in pre.run([req], compress=compress,
                                   backend=backend):
                dec.admit(r, w, f, backend=backend)
            while dec.active:
                dec.step()


def drive_open_loop(gw: Gateway, arrivals: Sequence[Tuple[float,
                                                          ServeRequest]], *,
                    time_scale: float = 1.0, max_iters: int = 200000,
                    on_token: Optional[Callable[[RequestHandle, int], None]]
                    = None) -> List[RequestHandle]:
    """Open-loop driver: submit each request at its trace arrival time
    (scaled by ``time_scale``) against the wall clock, pumping the gateway
    between arrivals, then drain. This is how a service is actually driven
    — dumping the whole trace at t=0 makes every E2E number meaningless.
    """
    pending = sorted(arrivals, key=lambda a: a[0])
    gw.heartbeat_all()      # time spent in setup/warmup is not a failure
    t0 = time.time()
    handles: List[RequestHandle] = []
    i = 0
    it = 0
    while i < len(pending) or gw.queue or gw.transfer_queue \
            or any(d.alive and d.client.active for d in gw.dec):
        now = time.time() - t0
        while i < len(pending) and pending[i][0] * time_scale <= now:
            handles.append(gw.submit(pending[i][1], on_token=on_token))
            i += 1
        busy = (gw.queue or gw.transfer_queue
                or any(d.alive and d.client.active for d in gw.dec))
        if busy:
            n = gw.pump()
            if n == 0 and not gw.queue and gw.transfer_queue \
                    and not any(d.alive and d.client.active
                                for d in gw.dec):
                # only in-flight simulated wires remain: wait for t_ready
                # instead of burning the iteration budget (same wedge
                # guard as run_until_drained)
                time.sleep(2e-4)
        elif i < len(pending):
            # idle until the next arrival — don't burn the iteration budget
            time.sleep(min(pending[i][0] * time_scale - now, 5e-3))
        it += 1
        if it > max_iters:
            break
    return handles


def summarize_handles(handles: Sequence[RequestHandle]) -> Dict[str, object]:
    """TTFT/TPOT/E2E percentiles + goodput over one open-loop run.

    Goodput is deadline attainment: the fraction of *submitted* requests
    that finished AND met both their TTFT and E2E deadlines (an infinite
    deadline is trivially met)."""
    def pct(xs, q):
        xs = [x for x in xs if not math.isnan(x)]
        return float(np.percentile(xs, q)) if xs else math.nan

    done = [h for h in handles if h.state == DONE]
    good = [h for h in done
            if h.ttft <= h.request.ttft_deadline_s
            and h.e2e <= h.request.e2e_deadline_s]
    states: Dict[str, int] = {}
    for h in handles:
        states[h.state] = states.get(h.state, 0) + 1
    ttft = [h.ttft for h in done]
    tpot = [h.tpot for h in done]
    e2e = [h.e2e for h in done]
    toks = sum(len(h.tokens) for h in done)
    return {
        "n_submitted": len(handles), "n_done": len(done),
        "states": states, "tokens": toks,
        "goodput": len(good) / max(len(handles), 1),
        "ttft_p50_s": pct(ttft, 50), "ttft_p99_s": pct(ttft, 99),
        "tpot_p50_s": pct(tpot, 50), "tpot_p99_s": pct(tpot, 99),
        "e2e_p50_s": pct(e2e, 50), "e2e_p99_s": pct(e2e, 99),
    }
