"""KV-cache wire format for prefill -> decode transfer (paper §4).

One-shot int4 group-wise quantization on the sender, immediate dequantization
on the receiver; both phases compute in 16-bit. For SSM/hybrid archs the
"KV" is the recurrent-state snapshot (beyond-paper generalization, see
DESIGN.md §Arch-applicability): bounded-size tensors transferred the same
way (f32 states are sent raw — they are O(1)-sized).

Fast path (DESIGN.md §4): all of a request's layers are stacked into ONE
``ops.kv_quant`` pallas call (``extract``), and an entire prefill batch can
be quantized in a single call (``extract_batch``). Payloads stay device
arrays end to end; ``KVWire.materialize()`` is the single explicit
device->host synchronization point for deployments where the wire is a real
network hop. In-process, the decode side consumes device arrays directly and
no host round-trip ever happens.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
# the ONE layout contract (candidate groups + selection rule) — local
# copies of these constants are forbidden (lint rule R005)
from repro.kernels.kv_layout import GROUPS as _GROUPS
from repro.kernels.kv_layout import pick_group as _pick_group

# Row-tile size for the quant kernels; the kernel handles ragged tails
# (ceil-div grid), so one fixed block => one jit variant per flat shape.
_BLOCK_N = 256


@dataclass
class WireTensor:
    """Either a quantized (packed, scale, zero, orig_shape) or raw tensor.

    Payload arrays may be jax device arrays (fast path) or numpy arrays
    (after ``KVWire.materialize()`` — the explicit wire hop).
    """
    kind: str                      # "int4" | "raw"
    payload: Dict[str, np.ndarray]
    orig_shape: Tuple[int, ...] = ()
    dtype: str = "bfloat16"

    def nbytes(self) -> int:
        return int(sum(a.nbytes for a in self.payload.values()))


@dataclass
class KVWire:
    """Per-request transferable cache state."""
    request_len: int
    slots: Dict[str, Dict[str, WireTensor]]

    def nbytes(self) -> int:
        return sum(t.nbytes() for s in self.slots.values()
                   for t in s.values())

    def materialize(self) -> "KVWire":
        """Pull every payload to the host in ONE device synchronization.

        This is the single point where the wire leaves the device — call it
        when the transfer crosses a real network boundary; skip it for
        in-process handoff (the decode side consumes device arrays)."""
        tensors = [t for s in self.slots.values() for t in s.values()]
        host = jax.device_get([t.payload for t in tensors])
        for t, p in zip(tensors, host):
            t.payload = p
        return self


def _quantize_stacked(xs: Sequence[jnp.ndarray], backend: str,
                      group: Optional[int] = None) -> List[WireTensor]:
    """Quantize same-shaped tensors in ONE kernel launch.

    Group boundaries never cross a tensor (each tensor's element count is a
    multiple of the group width), so the result is bit-identical to
    quantizing each tensor separately. ``group`` overrides the group width
    (the padded-extract path needs position-aligned groups)."""
    shape = tuple(xs[0].shape)
    n = int(np.prod(shape))
    g = group if group else _pick_group(n)
    if n == 0 or g == 0:
        return [WireTensor("raw", {"x": x}, shape, str(x.dtype)) for x in xs]
    flat = jnp.concatenate([x.reshape(-1, g) for x in xs], axis=0)
    packed, scale, zero = ops.kv_quant(flat, backend=backend,
                                       block_n=_BLOCK_N)
    rows_per = n // g
    out = []
    for t, x in enumerate(xs):
        sl = slice(t * rows_per, (t + 1) * rows_per)
        out.append(WireTensor("int4", {"packed": packed[sl],
                                       "scale": scale[sl],
                                       "zero": zero[sl]},
                              shape, str(x.dtype)))
    return out


def _quantize(x: jnp.ndarray, backend: str) -> WireTensor:
    return _quantize_stacked([x], backend)[0]


def _dequantize(w: WireTensor, backend: str) -> jnp.ndarray:
    if w.kind == "raw":
        return jnp.asarray(w.payload["x"])
    out = ops.kv_dequant(jnp.asarray(w.payload["packed"]),
                         jnp.asarray(w.payload["scale"]),
                         jnp.asarray(w.payload["zero"]),
                         backend=backend)
    return out.reshape(w.orig_shape)


def _extract_one(cache, batch_index: int, length: int, compress: bool,
                 pad_to: Optional[int] = None
                 ) -> Tuple[Dict[str, Dict[str, WireTensor]],
                            List[Tuple[str, str, jnp.ndarray, int]]]:
    """Slice one request out of the cache; return (slots, pending-quant
    jobs). Jobs are quantized by the caller so they can be batched across
    requests. When ``pad_to`` is set, attention KV is sliced to the padded
    length (uniform shapes across a prefill bucket -> one kernel launch for
    the whole batch); the true length rides along for post-quant trimming."""
    slots: Dict[str, Dict[str, WireTensor]] = {}
    jobs: List[Tuple[str, str, jnp.ndarray, int]] = []
    for name, slot in cache.items():
        if name == "lengths":
            continue
        out: Dict[str, WireTensor] = {}
        if isinstance(slot, dict) and "k" in slot:        # attention KV
            s_cache = slot["k"].shape[2]
            ln = min(length, s_cache)
            lp = min(pad_to, s_cache) if pad_to else ln
            lp = max(lp, ln)
            for key in ("k", "v"):
                if compress:
                    t = slot[key][:, batch_index, :lp]     # (L, lp, Hkv, hd)
                    jobs.append((name, key, t, ln))
                else:
                    t = slot[key][:, batch_index, :ln]     # (L, len, Hkv, hd)
                    out[key] = WireTensor("raw", {"x": t}, tuple(t.shape),
                                          str(t.dtype))
        elif isinstance(slot, dict):                       # recurrent states
            for key, arr in slot.items():
                st = arr[:, batch_index]
                out[key] = WireTensor("raw", {"x": st},
                                      tuple(st.shape), str(st.dtype))
        elif getattr(slot, "ndim", 0) >= 4:  # flat decoder KV (whisper):
            # self_* arrays are per-token KV (trim to the request);
            # cross_* arrays span the encoder sequence (transfer whole)
            per_token = name.startswith("self_")
            s_cache = slot.shape[2]
            ln = min(length, s_cache) if per_token else s_cache
            lp = min(pad_to, s_cache) if (pad_to and per_token) else ln
            lp = max(lp, ln)
            if compress:
                jobs.append((name, "x", slot[:, batch_index, :lp], ln))
            else:
                t = slot[:, batch_index, :ln]
                out["x"] = WireTensor("raw", {"x": t}, tuple(t.shape),
                                      str(t.dtype))
        slots[name] = out
    return slots, jobs


def extract(cache, batch_index: int, length: int, *, compress: bool = True,
            backend: str = "auto") -> KVWire:
    """Pull one request's state out of a prefill cache pytree.

    All attention layers are quantized in one kernel launch per distinct
    tensor shape (usually exactly one); nothing is synced to the host."""
    return extract_batch(cache, [(batch_index, length)], compress=compress,
                         backend=backend)[0]


def _trim_wire_tensor(wt: WireTensor, ln: int) -> WireTensor:
    """Cut a padded-length int4 WireTensor down to the request's true
    length (lazy device slicing — no dequantization, no kernel launch).

    Requires the quantization groups to be position-aligned (group width
    divides Hkv*hd), which ``extract_batch`` checks before taking the
    padded path."""
    if wt.kind != "int4":
        return wt
    L, lp, Hkv, hd = wt.orig_shape
    if ln >= lp:
        return wt
    packed = wt.payload["packed"]
    g2 = packed.shape[1]                      # group//2 packed bytes
    ppr = (Hkv * hd) // (2 * g2)              # quant rows per position
    out = {}
    for key, a in wt.payload.items():
        out[key] = a.reshape(L, lp, ppr, a.shape[1])[:, :ln].reshape(
            -1, a.shape[1])
    return WireTensor("int4", out, (L, ln, Hkv, hd), wt.dtype)


def extract_batch(cache, requests: Sequence[Tuple[int, int]], *,
                  compress: bool = True, backend: str = "auto",
                  pad_to: Optional[int] = None) -> List[KVWire]:
    """Extract several (batch_index, length) requests, batching the
    quantization across requests AND layers: one ``ops.kv_quant`` call per
    distinct sliced-tensor shape. With ``pad_to`` (bucketed prefill), every
    request is sliced to the same padded length so the WHOLE batch is one
    kernel launch; the packed rows are then trimmed to each request's true
    length without leaving the device."""
    group = None
    if pad_to is not None:
        # padded-path precondition: groups must not straddle positions, so
        # each request's true-length rows can be sliced out post-quant
        for name, slot in cache.items():
            if name == "lengths":
                continue
            if isinstance(slot, dict) and "k" in slot:
                span = int(np.prod(slot["k"].shape[-2:]))
            elif not isinstance(slot, dict) and getattr(slot, "ndim", 0) >= 4:
                span = int(np.prod(slot.shape[-2:]))
            else:
                continue
            group = _pick_group(span)
            if not group:
                pad_to = None
                group = None
            break
    wires: List[KVWire] = []
    all_jobs: List[Tuple[int, str, str, jnp.ndarray, int]] = []
    for ri, (bi, length) in enumerate(requests):
        slots, jobs = _extract_one(cache, bi, length, compress, pad_to)
        wires.append(KVWire(request_len=length, slots=slots))
        all_jobs.extend((ri, name, key, t, ln) for name, key, t, ln in jobs)
    # group by shape so each group is one kernel launch
    by_shape: Dict[Tuple[int, ...], List[int]] = {}
    for j, (_, _, _, t, _) in enumerate(all_jobs):
        by_shape.setdefault(tuple(t.shape), []).append(j)
    for idxs in by_shape.values():
        wts = _quantize_stacked([all_jobs[j][3] for j in idxs], backend,
                                group=group)
        for j, wt in zip(idxs, wts):
            ri, name, key, _, ln = all_jobs[j]
            wires[ri].slots[name][key] = _trim_wire_tensor(wt, ln)
    return wires


def compress_wire(wire: KVWire, *, backend: str = "auto") -> KVWire:
    """int4-quantize a raw wire's positional tensors (one kernel launch
    per distinct shape), leaving recurrent-state snapshots raw.

    Quantizes with position-aligned groups — the same layout the
    padded-extract path produces — so the result of compressing a
    spliced chunked-prefill wire is bit-identical to a one-shot
    bucketed extraction of the same cache values, and paged decode
    engines can scatter its rows zero-copy."""
    slots: Dict[str, Dict[str, WireTensor]] = {}
    jobs: List[Tuple[str, str, jnp.ndarray]] = []
    for name, slot_wire in wire.slots.items():
        out: Dict[str, WireTensor] = {}
        for key, wt in slot_wire.items():
            if (wt.kind == "raw" and len(wt.orig_shape) == 4
                    and _pick_group(int(np.prod(wt.orig_shape[-2:])))):
                jobs.append((name, key, jnp.asarray(wt.payload["x"])))
            else:
                out[key] = wt
        slots[name] = out
    by_shape: Dict[Tuple[int, ...], List[int]] = {}
    for j, (_, _, t) in enumerate(jobs):
        by_shape.setdefault(tuple(t.shape), []).append(j)
    for shape, idxs in by_shape.items():
        group = _pick_group(int(np.prod(shape[-2:])))
        wts = _quantize_stacked([jobs[j][2] for j in idxs], backend,
                                group=group)
        for j, wt in zip(idxs, wts):
            name, key, _ = jobs[j]
            slots[name][key] = wt
    return KVWire(request_len=wire.request_len, slots=slots)


def concat_wires(wires: Sequence[KVWire], *,
                 backend: str = "auto") -> KVWire:
    """Splice per-chunk wires along the POSITION axis into one wire equal
    to a single extraction over the union of positions (chunked prefill).

    int4 payloads concatenate WITHOUT dequantizing: the padded-extract
    path quantizes with position-aligned groups (row ``t*ppr + r`` holds
    token ``t``'s ``r``-th group — the same layout contract that makes
    paged wire inserts zero-copy), so chunk boundaries are also group-row
    boundaries and the packed/scale/zero rows simply stack. Raw
    attention tensors concatenate on the position axis; a MIXED run
    (int4 prefix-cache wire + raw chunk wires) dequantizes the int4
    parts and splices raw — exactly the values a suffix prefill attends
    over either way. Recurrent-state snapshots are not positional and
    cannot be spliced (ValueError) — chunked prefill is gated on
    ``PrefillEngine.supports_suffix``, which excludes them."""
    wires = list(wires)
    if not wires:
        raise ValueError("concat_wires needs at least one wire")
    if len(wires) == 1:
        return wires[0]
    slots: Dict[str, Dict[str, WireTensor]] = {}
    for name in wires[0].slots:
        out: Dict[str, WireTensor] = {}
        for key in wires[0].slots[name]:
            wts = [w.slots[name][key] for w in wires]
            kinds = {wt.kind for wt in wts}
            lens = [wt.orig_shape[1] for wt in wts]
            if kinds == {"int4"}:
                L, _, Hkv, hd = wts[0].orig_shape
                g2 = wts[0].payload["packed"].shape[1]
                ppr = (Hkv * hd) // (2 * g2)      # quant rows per position
                payload = {}
                for pk in wts[0].payload:
                    parts = []
                    for wt, ln in zip(wts, lens):
                        a = jnp.asarray(wt.payload[pk])
                        parts.append(a.reshape(L, ln, ppr, a.shape[1]))
                    cat = jnp.concatenate(parts, axis=1)
                    payload[pk] = cat.reshape(-1, cat.shape[3])
                out[key] = WireTensor("int4", payload,
                                      (L, sum(lens), Hkv, hd),
                                      wts[0].dtype)
            elif (kinds <= {"raw", "int4"}
                  and all(len(wt.orig_shape) == 4 for wt in wts)):
                cat = jnp.concatenate(
                    [_dequantize(wt, backend) for wt in wts], axis=1)
                out[key] = WireTensor("raw", {"x": cat}, tuple(cat.shape),
                                      next(wt.dtype for wt in wts
                                           if wt.kind == "raw"))
            else:
                raise ValueError(
                    f"cannot splice wire slot {name}/{key}: "
                    f"kinds={sorted(kinds)}, shapes="
                    f"{[wt.orig_shape for wt in wts]} (recurrent-state "
                    f"snapshots are not positional)")
        slots[name] = out
    return KVWire(request_len=sum(w.request_len for w in wires),
                  slots=slots)


def dequantize_prefix_batch(wires: Sequence[KVWire], pad_to: int, *,
                            backend: str = "auto"):
    """Stack per-request prefix wires into ``transformer.prefill_suffix``
    inputs: a ``{slot: (k, v)}`` pytree of ``(L, B, pad_to, Hkv, hd)``
    bf16 arrays (prefix padded on the right; padding is masked by the
    suffix attention's ``prefix_len``) plus the ``(B,)`` true prefix
    lengths. One dequant per tensor; everything stays on device."""
    out: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]] = {}
    lens = jnp.asarray([w.request_len for w in wires], jnp.int32)
    for name in wires[0].slots:
        stacked = []
        for key in ("k", "v"):
            ts = []
            for w in wires:
                t = _dequantize(w.slots[name][key], backend)  # (L,ln,Hkv,hd)
                if t.shape[1] < pad_to:
                    t = jnp.pad(t, ((0, 0), (0, pad_to - t.shape[1]),
                                    (0, 0), (0, 0)))
                ts.append(t[:, :pad_to])
            stacked.append(jnp.stack(ts, axis=1))
        out[name] = (stacked[0], stacked[1])
    return out, lens


def insert(cache, wire: KVWire, batch_index: int, *, backend: str = "auto"):
    """Insert a transferred request state into a decode cache pytree."""
    return insert_batch(cache, [(wire, batch_index)], backend=backend)


def insert_batch(cache, items: Sequence[Tuple[KVWire, int]], *,
                 backend: str = "auto"):
    """Insert several (wire, slot_index) pairs, batching dequantization:
    one ``ops.kv_dequant`` call per distinct packed shape across all wires
    and layers."""
    # 1. collect + batch-dequantize all int4 tensors
    jobs: List[Tuple[int, str, str, WireTensor]] = []
    for wi, (wire, _) in enumerate(items):
        for name, slot_wire in wire.slots.items():
            for key, wt in slot_wire.items():
                if wt.kind == "int4":
                    jobs.append((wi, name, key, wt))
    deq: Dict[Tuple[int, str, str], jnp.ndarray] = {}
    by_shape: Dict[Tuple[Tuple[int, ...], Tuple[int, ...]], List[int]] = {}
    for j, (_, _, _, wt) in enumerate(jobs):
        k = (tuple(wt.payload["packed"].shape), tuple(wt.orig_shape))
        by_shape.setdefault(k, []).append(j)
    for (pshape, oshape), idxs in by_shape.items():
        packed = jnp.concatenate(
            [jnp.asarray(jobs[j][3].payload["packed"]) for j in idxs], axis=0)
        scale = jnp.concatenate(
            [jnp.asarray(jobs[j][3].payload["scale"]) for j in idxs], axis=0)
        zero = jnp.concatenate(
            [jnp.asarray(jobs[j][3].payload["zero"]) for j in idxs], axis=0)
        out = ops.kv_dequant(packed, scale, zero, backend=backend)
        rows = pshape[0]
        for t, j in enumerate(idxs):
            wi, name, key, wt = jobs[j]
            deq[(wi, name, key)] = out[t * rows:(t + 1) * rows].reshape(oshape)

    # 2. scatter into the cache (lazy device ops; no host sync)
    for wi, (wire, batch_index) in enumerate(items):
        L = wire.request_len
        for name, slot_wire in wire.slots.items():
            slot = cache[name]
            if "k" in slot_wire:
                k = deq.get((wi, name, "k"))
                if k is None:
                    k = _dequantize(slot_wire["k"], backend)
                v = deq.get((wi, name, "v"))
                if v is None:
                    v = _dequantize(slot_wire["v"], backend)
                s_cache = slot["k"].shape[2]
                upd = min(L, s_cache)
                cache[name]["k"] = slot["k"].at[:, batch_index, :upd].set(
                    k[:, -upd:].astype(slot["k"].dtype))
                cache[name]["v"] = slot["v"].at[:, batch_index, :upd].set(
                    v[:, -upd:].astype(slot["v"].dtype))
            elif "x" in slot_wire and not isinstance(slot, dict):
                # flat decoder KV (whisper); cross_* carries its own length
                t = deq.get((wi, name, "x"))
                if t is None:
                    t = _dequantize(slot_wire["x"], backend)
                upd = min(t.shape[1], slot.shape[2])
                cache[name] = slot.at[:, batch_index, :upd].set(
                    t[:, -upd:].astype(slot.dtype))
            else:
                for key, wt in slot_wire.items():
                    st = _dequantize(wt, backend)
                    cache[name][key] = slot[key].at[:, batch_index].set(
                        st.astype(slot[key].dtype))
        cache["lengths"] = cache["lengths"].at[batch_index].set(L)
    return cache


def wire_bytes_uncompressed(wire: KVWire) -> int:
    total = 0
    for s in wire.slots.values():
        for t in s.values():
            if t.kind == "int4":
                n = int(np.prod(t.orig_shape))
                total += n * 2
            else:
                total += t.nbytes()
    return total
