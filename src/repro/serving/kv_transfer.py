"""KV-cache wire format for prefill -> decode transfer (paper §4).

One-shot int4 group-wise quantization on the sender, immediate dequantization
on the receiver; both phases compute in 16-bit. For SSM/hybrid archs the
"KV" is the recurrent-state snapshot (beyond-paper generalization, see
DESIGN.md §Arch-applicability): bounded-size tensors transferred the same
way (f32 states are sent raw — they are O(1)-sized).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


@dataclass
class WireTensor:
    """Either a quantized (packed, scale, zero, orig_shape) or raw tensor."""
    kind: str                      # "int4" | "raw"
    payload: Dict[str, np.ndarray]
    orig_shape: Tuple[int, ...] = ()
    dtype: str = "bfloat16"

    def nbytes(self) -> int:
        return int(sum(a.nbytes for a in self.payload.values()))


@dataclass
class KVWire:
    """Per-request transferable cache state."""
    request_len: int
    slots: Dict[str, Dict[str, WireTensor]]

    def nbytes(self) -> int:
        return sum(t.nbytes() for s in self.slots.values()
                   for t in s.values())


def _quantize(x: jnp.ndarray, backend: str) -> WireTensor:
    shape = tuple(x.shape)
    n = int(np.prod(shape))
    # 128-wide quantization groups keep the scale/zero overhead at ~3% even
    # for small head_dims; fall back to smaller even groups, then raw.
    g = next((gg for gg in (128, 64, 32, 16, 8, 4, 2)
              if n % gg == 0), 0)
    if n == 0 or g == 0:
        return WireTensor("raw", {"x": np.asarray(x)}, shape, str(x.dtype))
    flat = x.reshape(-1, g)
    rows = flat.shape[0]
    block = next(b for b in (256, 128, 64, 32, 16, 8, 4, 2, 1)
                 if rows % b == 0)
    packed, scale, zero = ops.kv_quant(flat, backend=backend, block_n=block)
    return WireTensor("int4", {"packed": np.asarray(packed),
                               "scale": np.asarray(scale),
                               "zero": np.asarray(zero)},
                      shape, str(x.dtype))


def _dequantize(w: WireTensor, backend: str) -> jnp.ndarray:
    if w.kind == "raw":
        return jnp.asarray(w.payload["x"])
    out = ops.kv_dequant(jnp.asarray(w.payload["packed"]),
                         jnp.asarray(w.payload["scale"]),
                         jnp.asarray(w.payload["zero"]),
                         backend=backend)
    return out.reshape(w.orig_shape)


def extract(cache, batch_index: int, length: int, *, compress: bool = True,
            backend: str = "auto") -> KVWire:
    """Pull one request's state out of a prefill cache pytree."""
    slots: Dict[str, Dict[str, WireTensor]] = {}
    for name, slot in cache.items():
        if name == "lengths":
            continue
        out: Dict[str, WireTensor] = {}
        if isinstance(slot, dict) and "k" in slot:        # attention KV
            ln = min(length, slot["k"].shape[2])
            k = slot["k"][:, batch_index, :ln]             # (L, len, Hkv, hd)
            v = slot["v"][:, batch_index, :ln]
            if compress:
                out["k"] = _quantize(k, backend)
                out["v"] = _quantize(v, backend)
            else:
                out["k"] = WireTensor("raw", {"x": np.asarray(k)},
                                      tuple(k.shape))
                out["v"] = WireTensor("raw", {"x": np.asarray(v)},
                                      tuple(v.shape))
        elif isinstance(slot, dict):                       # recurrent states
            for key, arr in slot.items():
                st = arr[:, batch_index]
                out[key] = WireTensor("raw", {"x": np.asarray(st)},
                                      tuple(st.shape), str(st.dtype))
        slots[name] = out
    return KVWire(request_len=length, slots=slots)


def insert(cache, wire: KVWire, batch_index: int, *, backend: str = "auto"):
    """Insert a transferred request state into a decode cache pytree."""
    L = wire.request_len
    for name, slot_wire in wire.slots.items():
        slot = cache[name]
        if "k" in slot_wire:
            k = _dequantize(slot_wire["k"], backend)
            v = _dequantize(slot_wire["v"], backend)
            s_cache = slot["k"].shape[2]
            upd = min(L, s_cache)
            cache[name]["k"] = slot["k"].at[:, batch_index, :upd].set(
                k[:, -upd:].astype(slot["k"].dtype))
            cache[name]["v"] = slot["v"].at[:, batch_index, :upd].set(
                v[:, -upd:].astype(slot["v"].dtype))
        else:
            for key, wt in slot_wire.items():
                st = _dequantize(wt, backend)
                cache[name][key] = slot[key].at[:, batch_index].set(
                    st.astype(slot[key].dtype))
    cache["lengths"] = cache["lengths"].at[batch_index].set(L)
    return cache


def wire_bytes_uncompressed(wire: KVWire) -> int:
    total = 0
    for s in wire.slots.values():
        for t in s.values():
            if t.kind == "int4":
                n = int(np.prod(t.orig_shape))
                total += n * 2
            else:
                total += t.nbytes()
    return total
