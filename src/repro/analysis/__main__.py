"""CLI: ``python -m repro.analysis lint [--strict] [paths...]``.

Exits 1 when any finding survives the ``# repro: ignore[Rnnn]`` pragmas
(and, under ``--strict``, when a pragma suppresses nothing). Stdlib only —
safe to run before the accelerator stack is installed.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.lint import RULES, run_lint


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = ap.add_subparsers(dest="cmd", required=True)
    lint = sub.add_parser("lint", help="run invariant rules R001-R005")
    lint.add_argument("paths", nargs="*",
                      help="files/dirs relative to the repo root "
                           "(default: src/repro benchmarks)")
    lint.add_argument("--root", default=".",
                      help="repo root (default: cwd)")
    lint.add_argument("--strict", action="store_true",
                      help="also fail on unused ignore pragmas")
    lint.add_argument("--list-rules", action="store_true",
                      help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, desc in sorted(RULES.items()):
            print(f"{rid}  {desc}")
        return 0

    findings = run_lint(args.root, args.paths or None, strict=args.strict)
    for f in findings:
        print(f.format())
    n = len(findings)
    if n:
        print(f"\n{n} finding{'s' if n != 1 else ''} "
              f"(suppress a deliberate violation with "
              f"`# repro: ignore[Rnnn]` on the offending line)")
        return 1
    print("repro.analysis lint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
