"""CLI: ``python -m repro.analysis lint|check``.

* ``lint [--strict] [--format text|json|github] [paths...]`` — AST
  invariant rules R001–R008. Exits 1 when any finding survives the
  ``# repro: ignore[Rnnn]`` pragmas (and, under ``--strict``, when a
  pragma suppresses nothing).
* ``check [--depth N] [--quick] [--mutations] [--replay]`` — explicit-
  state model checking of the lifecycle / page-pool / chunked-prefill
  protocols; ``--mutations`` additionally plants known protocol bugs and
  asserts each is caught with a replayable counterexample.

Stdlib only (``--replay`` of lifecycle traces needs jax; everything else
is safe to run before the accelerator stack is installed).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.lint import RULES, run_lint


def _emit_lint(findings, fmt: str):
    if fmt == "json":
        print(json.dumps([{
            "rule": f.rule, "path": f.path, "line": f.line, "col": f.col,
            "message": f.message, "hint": f.hint} for f in findings],
            indent=2))
        return
    for f in findings:
        if fmt == "github":
            # GitHub Actions workflow command: annotates the PR diff
            msg = f.message + (f" (hint: {f.hint})" if f.hint else "")
            print(f"::error file={f.path},line={f.line},col={f.col},"
                  f"title={f.rule}::{msg}")
        else:
            print(f.format())


def _cmd_lint(args) -> int:
    if args.list_rules:
        for rid, desc in sorted(RULES.items()):
            print(f"{rid}  {desc}")
        return 0
    findings = run_lint(args.root, args.paths or None, strict=args.strict)
    _emit_lint(findings, args.format)
    n = len(findings)
    if n:
        if args.format == "text":
            print(f"\n{n} finding{'s' if n != 1 else ''} "
                  f"(suppress a deliberate violation with "
                  f"`# repro: ignore[Rnnn]` on the offending line)")
        return 1
    if args.format == "text":
        print("repro.analysis lint: clean")
    return 0


def _cmd_check(args) -> int:
    from repro.analysis import modelcheck as mc

    rc = 0
    results = mc.run_check(depth=args.depth, quick=args.quick)
    for r in results:
        status = "ok" if r.ok else f"{len(r.violations)} VIOLATION(S)"
        print(f"check[{r.model}]: {r.states} states, "
              f"{r.transitions} transitions, depth {r.depth}: {status}")
        for v in r.violations:
            print(v.format())
        if not r.ok:
            rc = 1
    if args.mutations:
        muts = mc.run_mutations(depth=args.depth,
                                lifecycle_replay=args.replay)
        for m in muts:
            verdict = "caught" if m.caught else "MISSED"
            rep = {True: ", replay confirmed", False: ", REPLAY DIVERGED",
                   None: ""}[m.replayed]
            print(f"mutation[{m.name}] ({m.model}): {verdict}{rep}")
            if m.caught:
                print(f"    {m.message}")
                print(f"    trace: {' -> '.join(m.trace) or '(initial)'}")
        missed = [m.name for m in muts if not m.caught]
        diverged = [m.name for m in muts if m.replayed is False]
        if missed:
            print(f"mutation harness: checker MISSED {missed}")
            rc = 1
        if diverged:
            print(f"mutation harness: counterexamples did not reproduce "
                  f"on the real code: {diverged}")
            rc = 1
        if not missed and not diverged:
            print(f"mutation harness: all {len(muts)} planted bugs caught")
    if rc == 0:
        print("repro.analysis check: clean")
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = ap.add_subparsers(dest="cmd", required=True)

    lint = sub.add_parser("lint", help="run invariant rules R001-R008")
    lint.add_argument("paths", nargs="*",
                      help="files/dirs relative to the repo root "
                           "(default: src/repro benchmarks tests)")
    lint.add_argument("--root", default=".",
                      help="repo root (default: cwd)")
    lint.add_argument("--strict", action="store_true",
                      help="also fail on unused ignore pragmas")
    lint.add_argument("--format", choices=("text", "json", "github"),
                      default="text",
                      help="text (default), json (machine-readable), or "
                           "github (::error workflow annotations)")
    lint.add_argument("--list-rules", action="store_true",
                      help="print the rule table and exit")

    chk = sub.add_parser(
        "check", help="model-check the lifecycle/pool/chunk protocols")
    chk.add_argument("--depth", type=int, default=12,
                     help="BFS event-depth bound (default 12)")
    chk.add_argument("--quick", action="store_true",
                     help="reduced depth for the tier-1 CI lane (<60s)")
    chk.add_argument("--mutations", action="store_true",
                     help="plant known protocol bugs and assert each is "
                          "caught with a replayable counterexample")
    chk.add_argument("--replay", action="store_true",
                     help="replay lifecycle counterexamples through the "
                          "real RequestHandle under VirtualClock "
                          "(requires jax; pool/chunk traces always "
                          "replay, stdlib)")
    args = ap.parse_args(argv)

    if args.cmd == "lint":
        return _cmd_lint(args)
    return _cmd_check(args)


if __name__ == "__main__":
    sys.exit(main())
