"""Static AST linter for the serving stack's correctness contracts.

``python -m repro.analysis lint [--strict] [paths...]`` — stdlib ``ast``
only, no third-party dependencies, so it runs in any CI image before jax
is even importable.

The rules encode invariants that used to live as "invariant for next
session" prose in CHANGES.md (now DESIGN.md §9):

* **R001** — no direct ``time.time()`` / ``time.monotonic()`` calls in
  ``serving/``: every timestamp must come from the gateway's injectable
  clock or chaos runs under ``VirtualClock`` silently read wall time.
  (References like ``clock: Callable = time.time`` parameter defaults are
  fine — only *calls* and ``field(default_factory=time.time)`` leak.)
* **R002** — no host-synchronizing calls (``.item()``, ``int()``/
  ``float()`` on array elements, ``np.asarray``, ``jax.device_get``)
  inside jit-reachable code in ``kernels/`` / ``models/``: ``lax.scan`` /
  ``fori_loop`` / ``while_loop`` bodies, ``jax.jit``-decorated or
  -wrapped functions, pallas kernels, and everything they call locally.
* **R003** — ``gateway.py`` / ``benchmarks/`` touch replicas only
  through the ``PrefillClient`` / ``DecodeClient`` / ``Transport``
  seams: no ``.engine`` / ``._engine`` attribute reach-through (an RPC
  realization has no engine attribute to reach). Also: the deleted
  ``Coordinator`` shim stays deleted — no
  ``repro.serving.coordinator`` imports, no ``Coordinator`` class in
  ``serving/`` (``Gateway`` is the one public entry point).
* **R004** — every transition to FAILED / REJECTED carries a ``reason``
  (and request state is never assigned directly — only through
  ``_transition``, which validates the state machine).
* **R005** — wire/page layout lockstep: the quantization group candidates,
  the group-selection rule, and the int4 nibble packing live ONLY in
  ``kernels/kv_layout.py``; ``kv_transfer.py`` / ``models/paged.py`` /
  the kernels must import them. A local copy is a drift waiting to
  corrupt zero-copy page insertion.
* **R006** — page refcount/free-list mutation lives ONLY in
  ``serving/page_pool.py``: no reaching into ``PagePool`` internals
  (``._free``, ``._owners``, ``._by_owner``, ``._grant``, ``._revoke``)
  from anywhere else. Prefix sharing means a page may have several
  owners; a caller that pokes the maps directly can free a page another
  owner still reads (silent KV corruption). Go through
  ``alloc``/``share``/``free``/``unshare``/``owned_by``/``owners_of``
  (state branching: ``snapshot``/``restore``/``canonicalize``).
* **R007 / R008** — wire-provenance dataflow rules (quantize once over
  the spliced whole; wire layout arithmetic stays in the layout
  modules). Implemented in :mod:`repro.analysis.dataflow`, run and
  scoped here.

Escape hatch: ``# repro: ignore[Rnnn]`` on the offending line (or the
line above) suppresses one rule there; ``--strict`` additionally fails on
pragmas that suppress nothing (so dead pragmas can't rot).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES: Dict[str, str] = {
    "R001": "no direct wall-clock reads in serving/ (use the injected "
            "clock)",
    "R002": "no host-sync calls in jit-reachable kernels/models code",
    "R003": "replicas are reached only through client/transport seams "
            "(and the deleted Coordinator shim stays deleted)",
    "R004": "FAILED/REJECTED transitions must carry a reason",
    "R005": "wire/page quantization layout must not drift (kv_layout is "
            "the single source of truth)",
    "R006": "page refcount/free-list mutation only in serving/page_pool.py "
            "(use the PagePool API, never its internals)",
    "R007": "quantize once, over the spliced whole: no double "
            "quantization, no per-chunk quantization, chunk wires stay "
            "raw until completion",
    "R008": "wire layout arithmetic (KVWire/WireTensor construction, "
            "ppr row math, payload splicing) only in the layout modules",
}

# the ONE module allowed to touch the refcount maps/free list (R006)
POOL_MODULE = "src/repro/serving/page_pool.py"

# the ONE module allowed to define the layout contract (R005)
LAYOUT_MODULE = "src/repro/kernels/kv_layout.py"

# only real rule ids (R001, W001, ...) count as pragmas — prose examples
# like "ignore[Rnnn]" in docstrings must not register as suppressions
_PRAGMA_RE = re.compile(r"#\s*repro:\s*ignore\[((?:\s*[RWE]\d{3}\s*,?)+)\]")


@dataclass
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    hint: str = ""

    def format(self) -> str:
        s = f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"
        if self.hint:
            s += f"\n    hint: {self.hint}"
        return s


# -- pragma handling ----------------------------------------------------------


def _pragmas(src: str) -> Dict[int, Set[str]]:
    """line -> set of rule ids suppressed on that line."""
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(src.splitlines(), start=1):
        m = _PRAGMA_RE.search(text)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


# -- R001: wall-clock reads in serving/ ---------------------------------------


def _is_time_attr(node: ast.AST) -> bool:
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "time"
            and node.attr in ("time", "monotonic", "monotonic_ns",
                              "time_ns"))


class _R001(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []

    def visit_Call(self, node: ast.Call):
        if _is_time_attr(node.func):
            self.findings.append(Finding(
                "R001", self.path, node.lineno, node.col_offset,
                f"direct time.{node.func.attr}() call in serving code",
                "take a `clock` callable (default `time.time` as a "
                "REFERENCE) and call `self.clock()` / the caller-passed "
                "`now` so VirtualClock runs stay deterministic"))
        for kw in node.keywords:
            if kw.arg == "default_factory" and _is_time_attr(kw.value):
                self.findings.append(Finding(
                    "R001", self.path, kw.value.lineno,
                    kw.value.col_offset,
                    "field(default_factory=time.time) stamps wall time at "
                    "construction",
                    "default to 0.0 (or pass the clock reading explicitly "
                    "at the construction site)"))
        self.generic_visit(node)


# -- R002: host syncs in jit-reachable code -----------------------------------

_FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _jit_like(node: ast.AST) -> bool:
    """`jit`, `jax.jit`, `pl.pallas_call`, `jax.checkpoint`, `remat` ..."""
    if isinstance(node, ast.Name):
        return node.id in ("jit", "pallas_call", "checkpoint", "remat")
    if isinstance(node, ast.Attribute):
        return node.attr in ("jit", "pallas_call", "checkpoint", "remat")
    return False


def _loop_body_args(call: ast.Call) -> List[ast.AST]:
    """Function-valued args of lax control-flow calls."""
    fn = call.func
    name = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else "")
    if name == "scan":
        return call.args[:1]
    if name == "fori_loop":
        return call.args[2:3]
    if name == "while_loop":
        return call.args[:2]
    if name in ("cond", "switch"):
        return [a for a in call.args[1:] if isinstance(a, _FuncNode)]
    return []


class _JitScopeCollector(ast.NodeVisitor):
    """Find every function node that jit (or a lax loop / pallas kernel)
    can reach, including module-local call-graph closure."""

    def __init__(self):
        self.defs_by_name: Dict[str, List[ast.AST]] = {}
        self.roots: List[ast.AST] = []

    def visit_FunctionDef(self, node):
        self.defs_by_name.setdefault(node.name, []).append(node)
        if any(self._decorator_jits(d) for d in node.decorator_list):
            self.roots.append(node)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    @staticmethod
    def _decorator_jits(dec: ast.AST) -> bool:
        if _jit_like(dec):
            return True
        if isinstance(dec, ast.Call):
            if _jit_like(dec.func):
                return True
            # functools.partial(jax.jit, ...)
            f = dec.func
            partial = (isinstance(f, ast.Attribute) and f.attr == "partial"
                       ) or (isinstance(f, ast.Name) and f.id == "partial")
            if partial and dec.args and _jit_like(dec.args[0]):
                return True
        return False

    def visit_Call(self, node: ast.Call):
        targets: List[ast.AST] = []
        if _jit_like(node.func) and node.args:
            targets.append(node.args[0])
        # functools.partial(jit_like, fn)
        f = node.func
        partial = (isinstance(f, ast.Attribute) and f.attr == "partial"
                   ) or (isinstance(f, ast.Name) and f.id == "partial")
        if partial and len(node.args) >= 2 and _jit_like(node.args[0]):
            targets.append(node.args[1])
        targets.extend(_loop_body_args(node))
        for t in targets:
            if isinstance(t, _FuncNode):
                self.roots.append(t)
            elif isinstance(t, ast.Name):
                self.roots.extend(self.defs_by_name.get(t.id, []))
        self.generic_visit(node)

    def reachable(self, tree: ast.Module) -> List[ast.AST]:
        # two passes: defs may appear after the call that names them
        self.visit(tree)
        self.visit(tree)
        seen: Set[int] = set()
        order: List[ast.AST] = []
        work = list(self.roots)
        while work:
            fn = work.pop()
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            order.append(fn)
            # local call-graph closure: names called inside a reachable
            # scope whose defs live in this module are reachable too
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Call) and isinstance(sub.func,
                                                            ast.Name):
                    work.extend(self.defs_by_name.get(sub.func.id, []))
        return order


def _subscripts_device_data(node: ast.AST) -> bool:
    """True when an int()/float() argument indexes what is plausibly an
    array (any subscript not rooted in a static `.shape` chain)."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Subscript):
            base = sub.value
            static = False
            for part in ast.walk(base):
                if isinstance(part, ast.Attribute) and part.attr in (
                        "shape", "ndim", "size"):
                    static = True
            if not static:
                return True
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute)\
                and sub.func.attr == "item":
            return True
    return False


class _R002(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []

    def run(self, tree: ast.Module):
        scopes = _JitScopeCollector().reachable(tree)
        reported: Set[Tuple[int, int]] = set()
        for scope in scopes:
            for node in ast.walk(scope):
                if not isinstance(node, ast.Call):
                    continue
                key = (node.lineno, node.col_offset)
                if key in reported:
                    continue
                bad = self._banned(node)
                if bad:
                    reported.add(key)
                    self.findings.append(Finding(
                        "R002", self.path, node.lineno, node.col_offset,
                        f"{bad} inside a jit-reachable scope forces a "
                        f"device->host sync (or breaks tracing)",
                        "keep the value on device (jnp ops / lax.cond); "
                        "sync once per chunk at the designated host "
                        "boundary instead"))
        return self.findings

    @staticmethod
    def _banned(node: ast.Call) -> Optional[str]:
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr == "item":
                return ".item()"
            if f.attr == "device_get":
                return "jax.device_get"
            if isinstance(f.value, ast.Name) and f.value.id in (
                    "np", "numpy", "onp") and f.attr in (
                    "asarray", "array", "frombuffer", "copy"):
                return f"np.{f.attr}"
        if isinstance(f, ast.Name):
            if f.id == "device_get":
                return "device_get"
            if f.id in ("int", "float") and node.args and \
                    _subscripts_device_data(node.args[0]):
                return f"{f.id}() on an array element"
        return None


# -- R003: replica reach-through ----------------------------------------------


class _R003(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []

    def visit_Attribute(self, node: ast.Attribute):
        if node.attr in ("engine", "_engine") and not self._allowed(
                node.value):
            self.findings.append(Finding(
                "R003", self.path, node.lineno, node.col_offset,
                f".{node.attr} attribute reach-through bypasses the "
                f"client/transport seams",
                "go through PrefillClient/DecodeClient (e.g. "
                "client.page_stats(), warmup_gateway(gw, ...)) — an RPC "
                "replica has no engine attribute to reach"))
        self.generic_visit(node)

    @staticmethod
    def _allowed(base: ast.AST) -> bool:
        # self.engine (a replica's own engine) and self.replica.engine
        # (the LocalReplicaClient property) are the defining sites
        if isinstance(base, ast.Name) and base.id == "self":
            return True
        return (isinstance(base, ast.Attribute) and base.attr == "replica"
                and isinstance(base.value, ast.Name)
                and base.value.id == "self")


# deleted admission shims: DecodeEngine.admit(AdmissionBatch) is the ONE
# entry point (gateway §"unified admission"); the per-source variants are
# gone and must not come back
_DELETED_ADMIT_SHIMS = ("admit_batch", "admit_prefix", "admit_migrated")


class _R003Coordinator(ast.NodeVisitor):
    """Deleted shims stay deleted. The Coordinator class/module (PR 2)
    and the per-source admission variants (``admit_batch`` /
    ``admit_prefix`` / ``admit_migrated``, folded into
    ``admit(AdmissionBatch)``) each reintroduce a second public entry
    point and fail ``--strict``."""

    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []

    def _flag_admit(self, node: ast.AST, what: str):
        self.findings.append(Finding(
            "R003", self.path, node.lineno, node.col_offset,
            f"{what} reintroduces a deleted admission shim",
            "wrap the items in AdmissionItem/AdmissionBatch and call "
            "admit(batch) — ADMIT_FRESH/CHUNKED/PREFIX_HIT/MIGRATED tag "
            "the source"))

    def visit_Call(self, node: ast.Call):
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in _DELETED_ADMIT_SHIMS):
            self._flag_admit(node, f"call to .{node.func.attr}(...)")
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef):
        if (node.name in _DELETED_ADMIT_SHIMS
                and self.path.startswith("src/repro/serving/")):
            self._flag_admit(node, f"def {node.name} in serving/")
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def _flag(self, node: ast.AST, what: str):
        self.findings.append(Finding(
            "R003", self.path, node.lineno, node.col_offset,
            f"{what} reintroduces the deleted Coordinator shim",
            "port to repro.serving.gateway.Gateway (submit/"
            "run_until_drained accept bare GenRequests)"))

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            if a.name.startswith("repro.serving.coordinator"):
                self._flag(node, f"import {a.name}")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        mod = node.module or ""
        if mod.startswith("repro.serving.coordinator") or (
                mod.endswith("serving") and any(
                    a.name == "coordinator" for a in node.names)):
            self._flag(node, f"from {mod} import ...")
        self.generic_visit(node)

    def visit_ClassDef(self, node: ast.ClassDef):
        if (node.name == "Coordinator"
                and self.path.startswith("src/repro/serving/")):
            self._flag(node, f"class {node.name} in serving/")
        self.generic_visit(node)


# -- R004: FAILED/REJECTED must carry a reason --------------------------------

_TERMINAL_BAD = ("FAILED", "REJECTED")


def _names_state(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name) and node.id in _TERMINAL_BAD:
        return node.id
    if isinstance(node, ast.Constant) and node.value in _TERMINAL_BAD:
        return str(node.value)
    return None


class _R004(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []

    def visit_Call(self, node: ast.Call):
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "_transition" \
                and node.args:
            st = _names_state(node.args[0])
            if st is not None:
                has_reason = len(node.args) >= 3 or any(
                    kw.arg == "reason"
                    and not (isinstance(kw.value, ast.Constant)
                             and kw.value.value is None)
                    for kw in node.keywords)
                if not has_reason:
                    self.findings.append(Finding(
                        "R004", self.path, node.lineno, node.col_offset,
                        f"transition to {st} without a reason",
                        "pass reason=... — operators debug terminal "
                        "states from RequestHandle.reason"))
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign):
        st = _names_state(node.value)
        if st is not None:
            for tgt in node.targets:
                if isinstance(tgt, ast.Attribute) and tgt.attr == "state":
                    self.findings.append(Finding(
                        "R004", self.path, node.lineno, node.col_offset,
                        f"direct state assignment to {st} bypasses the "
                        f"state machine",
                        "use RequestHandle._transition(..., reason=...) — "
                        "it validates the DESIGN.md §5 transition table"))
        self.generic_visit(node)


# -- R006: pool internals stay in the pool ------------------------------------

_POOL_INTERNALS = ("_free", "_owners", "_by_owner", "_grant", "_revoke")


class _R006(ast.NodeVisitor):
    """No ``PagePool`` internal access outside ``serving/page_pool.py``.

    ``self._owners`` (etc.) is allowed — a subclass extending the pool
    (the sanitizer's site-tracking pool) is still "in" the pool; any
    other base expression is a caller mutating refcount state around the
    share/free API and breaking the multi-owner invariant."""

    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []

    def visit_Attribute(self, node: ast.Attribute):
        if node.attr in _POOL_INTERNALS and not (
                isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            self.findings.append(Finding(
                "R006", self.path, node.lineno, node.col_offset,
                f".{node.attr} reaches into PagePool refcount internals",
                "refcount/free-list mutation lives only in "
                "serving/page_pool.py — use alloc/share/free/unshare/"
                "owned_by/owners_of/refcount/pages_in_use"))
        self.generic_visit(node)


# -- R005: layout lockstep ----------------------------------------------------

_R005_IMPORT_REQUIREMENTS: Dict[str, Tuple[str, ...]] = {
    "src/repro/serving/kv_transfer.py": ("pick_group",),
    "src/repro/models/paged.py": ("pick_group",),
    "src/repro/kernels/kv_quant.py": ("pack_nibbles",),
    "src/repro/kernels/ref.py": ("pack_nibbles",),
    "src/repro/kernels/paged_attention.py": ("kv_layout",
                                             "interleave_nibbles"),
}


def _imports_from_layout(tree: ast.Module) -> Set[str]:
    got: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module and \
                node.module.endswith("kernels.kv_layout"):
            got.update(a.name for a in node.names)
        elif isinstance(node, ast.ImportFrom) and node.module and \
                node.module.endswith("kernels"):
            got.update(a.name for a in node.names
                       if a.name == "kv_layout")
        elif isinstance(node, ast.Import):
            got.update(a.name.rsplit(".", 1)[-1] for a in node.names
                       if a.name.endswith("kv_layout"))
    return got


def _r005_file(path: str, tree: ast.Module) -> List[Finding]:
    """Per-file half of R005: no local copies of the layout contract."""
    if path == LAYOUT_MODULE or not path.startswith("src/repro/"):
        return []
    out: List[Finding] = []
    for node in ast.walk(tree):
        # (a) a local candidate-group tuple
        if isinstance(node, ast.Assign) and isinstance(node.value,
                                                       ast.Tuple):
            elts = node.value.elts
            ints = (len(elts) >= 2 and all(
                isinstance(e, ast.Constant) and isinstance(e.value, int)
                for e in elts))
            named = any(isinstance(t, ast.Name) and "GROUPS" in t.id.upper()
                        for t in node.targets)
            if ints and named:
                out.append(Finding(
                    "R005", path, node.lineno, node.col_offset,
                    "local quantization-group candidate tuple can drift "
                    "from kernels/kv_layout.GROUPS",
                    "import GROUPS/pick_group from repro.kernels.kv_layout"
                ))
        # (b) a local group-selection expression
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "next" and node.args and isinstance(
                node.args[0], ast.GeneratorExp):
            gen = node.args[0].generators[0]
            over_groups = isinstance(gen.iter, ast.Name) and \
                "GROUPS" in gen.iter.id.upper()
            mod_test = any(
                isinstance(c, ast.Compare)
                and isinstance(c.left, ast.BinOp)
                and isinstance(c.left.op, ast.Mod)
                for c in gen.ifs)
            if over_groups and mod_test:
                out.append(Finding(
                    "R005", path, node.lineno, node.col_offset,
                    "local group-selection logic can drift from "
                    "kv_layout.pick_group",
                    "call repro.kernels.kv_layout.pick_group(span)"))
        # (c) local nibble pack/unpack arithmetic
        if isinstance(node, ast.BinOp) and isinstance(
                node.right, ast.Constant):
            nibble = ((isinstance(node.op, ast.BitAnd)
                       and node.right.value == 0xF)
                      or (isinstance(node.op, (ast.LShift, ast.RShift))
                          and node.right.value == 4))
            if nibble:
                out.append(Finding(
                    "R005", path, node.lineno, node.col_offset,
                    "local int4 nibble arithmetic can drift from the "
                    "kv_layout packing order",
                    "use kv_layout.pack_nibbles / unpack_nibbles / "
                    "interleave_nibbles"))
    return out


def _r005_cross(trees: Dict[str, ast.Module]) -> List[Finding]:
    """Cross-file half: the consumers must actually import the contract."""
    out: List[Finding] = []
    for path, required in _R005_IMPORT_REQUIREMENTS.items():
        tree = trees.get(path)
        if tree is None:
            continue
        got = _imports_from_layout(tree)
        if not any(r in got for r in required):
            out.append(Finding(
                "R005", path, 1, 0,
                f"does not import the layout contract "
                f"({' / '.join(required)}) from kernels/kv_layout",
                "a module in the wire/page path that stops importing "
                "kv_layout has necessarily grown a local copy — move the "
                "logic back"))
    if LAYOUT_MODULE in trees:
        has_groups = any(
            isinstance(n, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "GROUPS"
                for t in n.targets)
            for n in ast.walk(trees[LAYOUT_MODULE]))
        if not has_groups:
            out.append(Finding(
                "R005", LAYOUT_MODULE, 1, 0,
                "layout module no longer defines GROUPS",
                "kv_layout.py owns the candidate tuple; do not move it"))
    return out


# -- rule scoping + driver ----------------------------------------------------


# R008: the modules that OWN the wire row-layout contract (plus the
# runtime auditor, which recomputes it on purpose to audit it)
_R008_BLESSED = ("src/repro/serving/kv_transfer.py",
                 "src/repro/serving/page_pool.py",
                 "src/repro/models/paged.py",
                 "src/repro/analysis/sanitizers.py")


def _in_scope(rule: str, path: str) -> bool:
    if rule == "R001":
        # chaos/virtual-clock discipline extends to the suites that drive
        # the gateway: a test or bench reading wall time silently stops
        # exercising VirtualClock paths
        return path.startswith(("src/repro/serving/", "tests/",
                                "benchmarks/"))
    if rule == "R002":
        return path.startswith(("src/repro/kernels/", "src/repro/models/"))
    if rule == "R003":
        return path == "src/repro/serving/gateway.py" \
            or path.startswith("benchmarks/")
    if rule == "R003ban":
        # the Coordinator ban applies everywhere the linter looks
        return path.startswith(("src/repro/", "benchmarks/", "examples/",
                                "tests/", "launch/"))
    if rule == "R004":
        return path.startswith(("src/repro/", "benchmarks/"))
    if rule == "R006":
        return path != POOL_MODULE and path.startswith(
            ("src/repro/", "benchmarks/"))
    if rule == "R007":
        return path.startswith(("src/repro/", "benchmarks/", "tests/"))
    if rule == "R008":
        return (path.startswith("src/repro/")
                and not path.startswith("src/repro/kernels/")
                and path not in _R008_BLESSED)
    return True


def lint_sources(files: Dict[str, str], *,
                 strict: bool = False) -> List[Finding]:
    """Lint a mapping of repo-relative posix paths -> source text.

    This is the testable core: the CLI builds the mapping from the tree,
    unit tests feed synthetic snippets. Returns findings with pragmas
    already applied (plus unused-pragma findings under ``strict``)."""
    from repro.analysis import dataflow  # deferred: dataflow imports Finding

    findings: List[Finding] = []
    trees: Dict[str, ast.Module] = {}
    pragmas: Dict[str, Dict[int, Set[str]]] = {}
    for path, src in files.items():
        try:
            tree = ast.parse(src)
        except SyntaxError as e:
            findings.append(Finding(
                "E000", path, e.lineno or 1, 0,
                f"syntax error: {e.msg}", ""))
            continue
        trees[path] = tree
        pragmas[path] = _pragmas(src)
        if _in_scope("R001", path):
            v = _R001(path)
            v.visit(tree)
            findings.extend(v.findings)
        if _in_scope("R002", path):
            findings.extend(_R002(path).run(tree))
        if _in_scope("R003", path):
            v = _R003(path)
            v.visit(tree)
            findings.extend(v.findings)
        if _in_scope("R003ban", path):
            v = _R003Coordinator(path)
            v.visit(tree)
            findings.extend(v.findings)
        if _in_scope("R004", path):
            v = _R004(path)
            v.visit(tree)
            findings.extend(v.findings)
        if _in_scope("R006", path):
            v = _R006(path)
            v.visit(tree)
            findings.extend(v.findings)
        if _in_scope("R007", path):
            v = dataflow._R007(path)
            v.visit(tree)
            findings.extend(v.findings)
        if _in_scope("R008", path):
            v = dataflow._R008(path)
            v.visit(tree)
            findings.extend(v.findings)
        findings.extend(_r005_file(path, tree))
    findings.extend(_r005_cross(trees))
    # apply pragmas (a pragma on the finding's line or the line above)
    used: Set[Tuple[str, int, str]] = set()
    kept: List[Finding] = []
    for f in findings:
        fp = pragmas.get(f.path, {})
        hit = None
        for ln in (f.line, f.line - 1):
            if f.rule in fp.get(ln, ()):
                hit = ln
                break
        if hit is None:
            kept.append(f)
        else:
            used.add((f.path, hit, f.rule))
    if strict:
        for path, per_line in pragmas.items():
            for ln, rules in per_line.items():
                for r in rules:
                    if (path, ln, r) not in used:
                        kept.append(Finding(
                            "W001", path, ln, 0,
                            f"unused `# repro: ignore[{r}]` pragma",
                            "delete it — dead pragmas hide future "
                            "violations"))
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return kept


DEFAULT_ROOTS = ("src/repro", "benchmarks", "tests")


def collect_files(root, paths: Optional[Sequence[str]] = None
                  ) -> Dict[str, str]:
    """Gather ``*.py`` under ``paths`` (default: the linted roots),
    keyed by repo-relative posix path."""
    import pathlib
    root = pathlib.Path(root)
    out: Dict[str, str] = {}
    for p in (paths or DEFAULT_ROOTS):
        base = root / p
        candidates = [base] if base.is_file() else sorted(
            base.rglob("*.py")) if base.is_dir() else []
        for f in candidates:
            rel = f.relative_to(root).as_posix()
            out[rel] = f.read_text()
    return out


def run_lint(root=".", paths: Optional[Sequence[str]] = None, *,
             strict: bool = False) -> List[Finding]:
    return lint_sources(collect_files(root, paths), strict=strict)
