"""Explicit-state model checker for the serving stack's protocols.

``python -m repro.analysis check [--depth N] [--quick]`` explores small
abstract models of three protocols by exhaustive BFS over every event
interleaving — the interleavings chaos traces only sample:

* **lifecycle** — the request state machine (QUEUED -> ... -> DONE) with
  crash / preempt / cancel / retry-exhaustion events injectable at every
  transition point. The model's *behavior* side is the event alphabet
  below (what the gateway does); its *legality* side is
  ``serving/protocol.TRANSITIONS`` — the SAME dict object
  ``RequestHandle._transition`` validates against at runtime, so there is
  no hand-copied table to drift. An edge the behavior needs but the table
  forbids (or vice versa, via the sanitizer drift audit) is a violation
  with the event trace that exposes it.
* **pagepool** — alloc / share / COW / free / donate over the REAL
  :class:`~repro.serving.page_pool.PagePool` (pure Python; branching via
  its ``snapshot``/``restore`` seam, rule R006). Checks refcount
  conservation, no free-at-refcount>0, no double-free, the
  donation-before-free retire ordering (``protocol.retire_steps``), and
  that a slot's append page is never shared (copy-on-write actually
  split it).
* **chunkedprefill** — PartialPrefill advance / cancel-mid-chunk /
  preempt-mid-chunk against ``protocol.chunk_take`` /
  ``chunk_complete`` / ``chunk_extract_compress``. Checks pages freed
  exactly once, no admission of incomplete jobs, and the quantize-once
  wire discipline (chunk wires stay RAW; the transport wire is
  quantized exactly once, over the spliced whole).

Counterexamples are event traces. ``replay_trace`` re-executes a trace
CONCRETELY through the real code the model binds to — a fresh real
``PagePool`` for the pool/chunk models, and (when jax is importable) the
real ``RequestHandle._transition`` under ``VirtualClock`` +
``REPRO_SANITIZE=1`` for lifecycle traces — confirming the violation
reproduces outside the model. The mutation harness (``run_mutations``)
plants known protocol bugs in ``serving/protocol.py``'s hooks (which the
engines execute too) and asserts the checker catches each with a
replayable trace.

Everything except lifecycle replay is stdlib-only: the tier-1 CI step
runs ``check --quick`` in an image without jax.
"""
from __future__ import annotations

import os
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.serving import protocol
from repro.serving.page_pool import PagePool


class ProtocolError(Exception):
    """An event the protocol performs raised where it must not (e.g. the
    pool rejected a free/share the retire ordering guarantees is valid)."""


@dataclass
class Violation:
    model: str
    message: str
    trace: Tuple[str, ...]          # event path from the initial state
    state: str = ""

    def format(self) -> str:
        path = " -> ".join(self.trace) if self.trace else "(initial state)"
        s = f"[{self.model}] {self.message}\n    trace: {path}"
        if self.state:
            s += f"\n    state: {self.state}"
        return s


@dataclass
class CheckResult:
    model: str
    depth: int
    states: int                     # distinct states visited
    transitions: int                # edges executed
    violations: List[Violation] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations


def explore(model, *, depth: int = 12, max_states: int = 500_000,
            max_violations: int = 16) -> CheckResult:
    """BFS over ``model``'s state space to ``depth`` events.

    A model provides ``name``, ``initial() -> state`` (hashable),
    ``events(state) -> Iterable[str]``, ``apply(state, event) -> state``
    (raising :class:`ProtocolError` on a protocol-level rejection),
    ``invariants(state) -> Iterable[str]`` and optionally
    ``final(visited) -> Iterable[Violation]`` for whole-space properties
    (reachability)."""
    init = model.initial()
    visited: Dict[object, Tuple[str, ...]] = {init: ()}
    frontier = deque([(init, 0)])
    violations: List[Violation] = [
        Violation(model.name, msg, (), repr(init))
        for msg in model.invariants(init)]
    transitions = 0
    max_depth = 0
    while frontier and len(violations) < max_violations:
        state, d = frontier.popleft()
        if d >= depth:
            continue
        trace = visited[state]
        for ev in model.events(state):
            try:
                nxt = model.apply(state, ev)
            except ProtocolError as e:
                violations.append(Violation(model.name, str(e),
                                            trace + (ev,), repr(state)))
                if len(violations) >= max_violations:
                    break
                continue
            transitions += 1
            if nxt in visited:
                continue
            ntrace = trace + (ev,)
            visited[nxt] = ntrace
            max_depth = max(max_depth, d + 1)
            for msg in model.invariants(nxt):
                violations.append(Violation(model.name, msg, ntrace,
                                            repr(nxt)))
            if len(visited) >= max_states:
                violations.append(Violation(
                    model.name, f"state-space bound {max_states} hit "
                    f"(model not closed — shrink it)", ntrace))
                frontier.clear()
                break
            frontier.append((nxt, d + 1))
    final = getattr(model, "final", None)
    if callable(final) and not violations:
        violations.extend(final(visited))
    return CheckResult(model.name, max_depth, len(visited), transitions,
                       violations)


# -- model 1: request lifecycle ----------------------------------------------


class LifecycleModel:
    """Every gateway event, injectable at every state, with a bounded
    restart budget. State = ``(lifecycle_state, restarts)``."""

    name = "lifecycle"

    # event -> intended destination; "requeue" resolves to QUEUED while
    # the restart budget lasts, FAILED after (gateway._requeue_handle)
    INTENT: Dict[str, str] = {
        "dispatch": protocol.PREFILLING,            # _dispatch_prefill
        "full_prefix_hit": protocol.TRANSFERRING,   # _try_prefix
        "shed_deadline": protocol.REJECTED,         # _shed_expired
        "abort_replan": protocol.FAILED,            # apply_plan overflow
        "cancel": protocol.CANCELLED,               # Gateway.cancel
        "prefill_ok": protocol.TRANSFERRING,        # _send_wire
        "prefill_crash": "requeue",                 # ReplicaCrashError
        "admit": protocol.DECODING,                 # _drain_transfers
        "transfer_retries_exhausted": "requeue",    # _schedule_retry
        "decode_died_in_transfer": "requeue",       # _recover_from
        "finish": protocol.DONE,                    # _step_decodes
        "preempt_migrate": protocol.TRANSFERRING,   # handle_preemption
        "decode_crash": "requeue",                  # _recover_from
    }

    EVENTS: Dict[str, Tuple[str, ...]] = {
        protocol.QUEUED: ("dispatch", "full_prefix_hit", "shed_deadline",
                          "abort_replan", "cancel"),
        protocol.PREFILLING: ("prefill_ok", "prefill_crash", "cancel"),
        protocol.TRANSFERRING: ("admit", "transfer_retries_exhausted",
                                "decode_died_in_transfer", "cancel"),
        protocol.DECODING: ("finish", "preempt_migrate", "decode_crash",
                            "cancel"),
    }

    def __init__(self, table: Optional[Dict[str, frozenset]] = None,
                 max_restarts: int = 2):
        # default: the LIVE table — the one the gateway enforces
        self.table = table if table is not None else protocol.TRANSITIONS
        self.max_restarts = max_restarts

    def initial(self):
        return (protocol.QUEUED, 0)

    def events(self, state) -> Tuple[str, ...]:
        st, _ = state
        if st in protocol.TERMINAL_STATES:
            return ()               # terminal states absorb
        return self.EVENTS.get(st, ())

    def resolve(self, state, event) -> Tuple[str, int]:
        """Destination + new restart count the gateway intends for
        ``event`` in ``state`` (shared with the replay driver)."""
        st, r = state
        dst = self.INTENT[event]
        if dst == "requeue":
            if r >= self.max_restarts:
                return protocol.FAILED, r
            return protocol.QUEUED, r + 1
        return dst, r

    def apply(self, state, event):
        st, _ = state
        dst, r = self.resolve(state, event)
        if dst not in self.table.get(st, frozenset()):
            raise ProtocolError(
                f"gateway event {event!r} needs edge {st} -> {dst}, which "
                f"the transition table forbids (legal from {st}: "
                f"{sorted(self.table.get(st, frozenset()))})")
        return (dst, r)

    def invariants(self, state) -> Iterable[str]:
        st, r = state
        if st not in self.table:
            yield f"state {st!r} is missing from the transition table"
        if r > self.max_restarts:
            yield (f"restart count {r} exceeds the max_restarts bound "
                   f"{self.max_restarts} — requeue without exhaustion check")
        if st in protocol.TERMINAL_STATES and self.table.get(st):
            yield (f"terminal state {st} has outgoing edges "
                   f"{sorted(self.table[st])} — terminal must absorb")

    def final(self, visited) -> Iterable[Violation]:
        reached = {s for s, _ in visited}
        if protocol.DONE not in reached:
            yield Violation(self.name,
                            "DONE is unreachable from QUEUED", ())
        # livelock freedom: every reachable state reaches a terminal
        for state, trace in visited.items():
            if self._terminal_reachable(state):
                continue
            yield Violation(
                self.name,
                f"no terminal state reachable from {state[0]} "
                f"(restarts={state[1]}) — requests can live-lock there",
                trace, repr(state))

    def _terminal_reachable(self, state) -> bool:
        seen = {state}
        work = [state]
        while work:
            s = work.pop()
            if s[0] in protocol.TERMINAL_STATES:
                return True
            for ev in self.events(s):
                try:
                    n = self.apply(s, ev)
                except ProtocolError:
                    continue
                if n not in seen:
                    seen.add(n)
                    work.append(n)
        return False


def check_table_drift() -> List[Violation]:
    """The runtime sanitizer keeps a deliberately independent copy of the
    lifecycle table (``sanitizers._LEGAL``); protocol.TRANSITIONS is the
    enforced original. They must agree edge-for-edge."""
    from repro.analysis.sanitizers import _LEGAL
    out: List[Violation] = []
    for st in sorted(set(protocol.TRANSITIONS) | set(_LEGAL)):
        a = frozenset(protocol.TRANSITIONS.get(st, frozenset()))
        b = frozenset(_LEGAL.get(st, ()))
        if a != b:
            out.append(Violation(
                "lifecycle",
                f"transition-table drift at {st}: protocol has "
                f"{sorted(a)}, sanitizer audit has {sorted(b)}", ()))
    return out


# -- model 2: PagePool alloc/share/COW/free/donate ----------------------------

PX = "prefix-cache"                 # the index's owner tag (prefix_cache.py)
_TW = 8                             # model table width (page-chain bound)


class PoolModel:
    """Drives the REAL :class:`PagePool` through the engine's page
    protocol: fresh admits, retires (``protocol.retire_steps`` — donate
    to the prefix index, then free), cancels, full-prefix-hit admits with
    the ``protocol.cow_boundary`` copy-on-write split, and evictions.

    State = ``(pool.state_key(), slots, donated-chain set)``; branching
    rewinds the pool via its ``snapshot``/``restore`` seam. Page size 4;
    "mid" requests end mid-page (7 tokens, 2 pages — the COW case),
    "aligned" ones end on a page boundary (8 tokens, 3 pages)."""

    name = "pagepool"
    LN = {"mid": 7, "aligned": 8}

    def __init__(self, pool_factory: Optional[Callable[[], PagePool]] = None,
                 n_slots: int = 2, n_pages: int = 7, page_size: int = 4):
        self.page_size = page_size
        self.n_slots = n_slots
        self._factory = pool_factory or (
            lambda: PagePool(n_pages + 1, page_size))
        self.pool = self._factory()
        self._snaps: Dict[object, object] = {}

    def _need(self, ln: int) -> int:
        return -(-(ln + 1) // self.page_size)   # budget = ln + 1 decode tok

    def initial(self):
        self.pool.canonicalize()
        slots = (None,) * self.n_slots
        state = (self.pool.state_key(), slots, ())
        self._snaps[state] = self.pool.snapshot()
        return state

    def events(self, state) -> List[str]:
        self.pool.restore(self._snaps[state])
        _, slots, chains = state
        evs: List[str] = []
        for s, held in enumerate(slots):
            if held is None:
                evs += [f"admit_fresh_mid:{s}", f"admit_fresh_aligned:{s}"]
                for ci in range(len(chains)):
                    if self._chain_live(chains[ci]):
                        evs.append(f"admit_prefix:{s}:{ci}")
            else:
                evs += [f"retire:{s}", f"release:{s}"]
        for p in self.pool_pages_evictable(state):
            evs.append(f"evict:{p}")
        return evs

    def _chain_live(self, entry) -> bool:
        chain, _ = entry
        return all(PX in self.pool.owners_of(p) for p in chain)

    def pool_pages_evictable(self, state) -> List[int]:
        return [p for p in self.pool.owned_by(PX)
                if self.pool.owners_of(p) == frozenset({PX})]

    def apply(self, state, event):
        self.pool.restore(self._snaps[state])
        _, slots, chains = state
        slots = list(slots)
        chains = list(chains)
        parts = event.split(":")
        op = parts[0]
        try:
            if op in ("admit_fresh_mid", "admit_fresh_aligned"):
                s = int(parts[1])
                ln = self.LN["mid" if op.endswith("mid") else "aligned"]
                pages = self.pool.alloc(self._need(ln), s)
                if pages is None:
                    return state            # pool full: admission rejected
                slots[s] = (tuple(pages), ln)
            elif op == "admit_prefix":
                s, ci = int(parts[1]), int(parts[2])
                chain, ln = chains[ci]
                need_total = min(self._need(ln), _TW)
                n_extra = max(need_total - len(chain), 0)
                cow = protocol.cow_needed(ln, self.page_size, _TW,
                                          len(chain))
                alloced = self.pool.alloc(n_extra + int(cow), s)
                if alloced is None:
                    return state
                self.pool.share(list(chain), s)
                new_chain = list(chain) + (alloced[:n_extra] if cow
                                           else alloced)
                if cow:
                    cow_at = protocol.cow_boundary(ln, self.page_size, _TW)
                    repl = alloced[-1]
                    self.pool.unshare([new_chain[cow_at]], s)
                    new_chain[cow_at] = repl
                slots[s] = (tuple(new_chain), ln)
            elif op == "retire":
                s = int(parts[1])
                chain, ln = slots[s]
                n_used = -(-ln // self.page_size)
                donated = tuple(chain[:n_used])
                for step in protocol.retire_steps(donate=True):
                    if step == "donate":
                        fresh = [p for p in donated
                                 if PX not in self.pool.owners_of(p)]
                        if fresh:
                            self.pool.share(fresh, PX)
                        if (donated, ln) not in chains:
                            chains.append((donated, ln))
                    elif step == "free":
                        self.pool.free(list(chain), owner=s)
                slots[s] = None
            elif op == "release":
                s = int(parts[1])
                chain, _ = slots[s]
                self.pool.free(list(chain), owner=s)
                slots[s] = None
            elif op == "evict":
                p = int(parts[1])
                self.pool.unshare([p], PX)
                chains = [(c, ln) for c, ln in chains if p not in c]
            else:
                raise AssertionError(f"unknown event {event}")
        except ValueError as e:
            raise ProtocolError(f"PagePool rejected {event}: {e}") from e
        self.pool.canonicalize()
        new = (self.pool.state_key(), tuple(slots), tuple(chains))
        if new not in self._snaps:
            self._snaps[new] = self.pool.snapshot()
        return new

    def invariants(self, state) -> Iterable[str]:
        self.pool.restore(self._snaps[state])
        pool = self.pool
        _, slots, _ = state
        if pool.n_free + pool.n_in_use != pool.capacity:
            yield (f"refcount conservation broken: {pool.n_free} free + "
                   f"{pool.n_in_use} in use != capacity {pool.capacity} "
                   f"(a page is free while still referenced, or leaked)")
        for p in pool.pages_in_use():
            if pool.refcount(p) < 1:
                yield f"page {p} is in use with refcount 0"
        for s, held in enumerate(slots):
            if held is None:
                continue
            chain, ln = held
            for p in chain:
                if s not in pool.owners_of(p):
                    yield (f"slot {s} decodes from page {p} without "
                           f"holding a reference (use-after-free)")
            app = protocol.cow_boundary(ln, self.page_size, _TW)
            if app < len(chain) and pool.refcount(chain[app]) > 1:
                yield (f"slot {s}'s append page {chain[app]} is shared by "
                       f"{sorted(map(repr, pool.owners_of(chain[app])))} — "
                       f"copy-on-write was skipped; decode appends would "
                       f"corrupt KV other readers still attend over")


# -- model 3: chunked-prefill admission ---------------------------------------


class ChunkModel:
    """One PartialPrefill job walked through every interleaving of
    advance / cancel / preempt / admit / finish, with its admission pages
    drawn from a REAL :class:`PagePool`.

    State = ``(phase, pos, wire taints, transport taint, requeues,
    pool key)``. Prompt length 5, chunk budget 2 (so completion lands
    mid-budget and an off-by-one in ``protocol.chunk_complete`` is
    reachable)."""

    name = "chunkedprefill"
    N = 5                           # prompt tokens
    BUDGET = 2                      # chunk token budget per advance
    PAGES = 2                       # admission reservation

    def __init__(self, pool_factory: Optional[Callable[[], PagePool]] = None):
        self._factory = pool_factory or (lambda: PagePool(4, 4))
        self.pool = self._factory()
        self._snaps: Dict[object, object] = {}

    def initial(self):
        self.pool.canonicalize()
        state = ("chunking", 0, (), "", 0, self.pool.state_key())
        self._snaps[state] = self.pool.snapshot()
        return state

    def events(self, state) -> List[str]:
        phase, pos, wires, _, requeues, _ = state
        done = protocol.chunk_complete(pos, self.N)
        evs: List[str] = []
        if phase == "chunking":
            if not done:
                evs.append("advance")
                if pos > 0 and requeues < 1:
                    evs.append("preempt_mid_chunk")
            if done:
                evs.append("admit")
            evs.append("cancel")
        elif phase == "admitted":
            evs += ["finish", "cancel"]
        return evs

    def apply(self, state, event):
        phase, pos, wires, transport, requeues, _ = state
        self.pool.restore(self._snaps[state])
        try:
            if event == "advance":
                take = protocol.chunk_take(self.N - pos, self.BUDGET, True)
                taint = ("quant" if protocol.chunk_extract_compress()
                         else "raw")
                wires = wires + (taint,)
                pos += take
                if protocol.chunk_complete(pos, self.N):
                    # completion: ONE quantization over the spliced whole
                    transport = ("double-quant" if "quant" in wires
                                 else "quant-once")
            elif event == "preempt_mid_chunk":
                # the prefill replica died: accumulated chunk wires die
                # with it; the job requeues and restarts from scratch
                pos, wires, transport = 0, (), ""
                requeues += 1
            elif event == "cancel":
                if phase == "admitted":
                    self.pool.free(self.pool.owned_by("job"), owner="job")
                phase, wires = "cancelled", ()
            elif event == "admit":
                pages = self.pool.alloc(self.PAGES, "job")
                if pages is None:
                    return state
                phase = "admitted"
            elif event == "finish":
                self.pool.free(self.pool.owned_by("job"), owner="job")
                phase = "done"
            else:
                raise AssertionError(f"unknown event {event}")
        except ValueError as e:
            raise ProtocolError(
                f"PagePool rejected {event}: {e} (pages must be freed "
                f"exactly once)") from e
        self.pool.canonicalize()
        new = (phase, pos, wires, transport, requeues,
               self.pool.state_key())
        if new not in self._snaps:
            self._snaps[new] = self.pool.snapshot()
        return new

    def invariants(self, state) -> Iterable[str]:
        phase, pos, wires, transport, _, _ = state
        if phase in ("admitted", "done") and pos < self.N:
            yield (f"job admitted with only {pos}/{self.N} prompt tokens "
                   f"prefilled — the admission wire is missing the prompt "
                   f"tail's KV")
        if phase == "chunking" and "quant" in wires:
            yield ("chunk wire quantized before job completion — chunks "
                   "must stay RAW so the resumable prefix is exact "
                   "(quantize once, over the spliced whole)")
        if transport == "double-quant":
            yield ("transport wire quantized twice (per-chunk quantization "
                   "followed by completion compression)")
        self.pool.restore(self._snaps[state])
        if self.pool.n_free + self.pool.n_in_use != self.pool.capacity:
            yield "refcount conservation broken in the admission pool"
        if phase in ("done", "cancelled") and self.pool.owned_by("job"):
            yield (f"terminal job still holds pages "
                   f"{self.pool.owned_by('job')} — leak")


# -- drivers ------------------------------------------------------------------

MODELS: Dict[str, Callable[[], object]] = {
    "lifecycle": LifecycleModel,
    "pagepool": PoolModel,
    "chunkedprefill": ChunkModel,
}


def run_check(*, depth: int = 12, quick: bool = False,
              models: Optional[Iterable[str]] = None) -> List[CheckResult]:
    """Explore every protocol model; quick mode shrinks the depth so the
    tier-1 CI step stays well under its 60s budget."""
    if quick:
        depth = min(depth, 8)
    out: List[CheckResult] = []
    for name in (models or MODELS):
        res = explore(MODELS[name](), depth=depth)
        if name == "lifecycle":
            res.violations.extend(check_table_drift())
        out.append(res)
    return out


# -- counterexample replay ----------------------------------------------------


def replay_trace(model_name: str, trace: Iterable[str]) -> Optional[str]:
    """Re-execute a counterexample trace CONCRETELY (no search) through
    the real code the model binds to; returns the reproduced failure
    message, or None when the trace completes cleanly (not reproduced).

    pagepool / chunkedprefill traces run against a fresh real
    :class:`PagePool` via the model's own ``apply`` (which performs real
    ``alloc``/``share``/``free`` calls); lifecycle traces drive the real
    ``RequestHandle._transition`` — the gateway's enforcement point —
    under ``VirtualClock`` with ``REPRO_SANITIZE=1`` (requires jax)."""
    if model_name == "lifecycle":
        return _replay_lifecycle(list(trace))
    model = MODELS[model_name]()
    state = model.initial()
    for ev in trace:
        try:
            state = model.apply(state, ev)
        except ProtocolError as e:
            return str(e)
        bad = list(model.invariants(state))
        if bad:
            return bad[0]
    return None


def _replay_lifecycle(trace: List[str]) -> Optional[str]:
    """Drive the REAL RequestHandle through the trace's events. The
    handle's ``_transition`` validates against the live (possibly
    mutated) ``protocol.TRANSITIONS``; an illegal edge raises the
    gateway's own RuntimeError — the reproduction. A trace that
    completes is additionally audited by the sanitizer's independent
    state-machine auditor."""
    from types import SimpleNamespace

    import numpy as np

    from repro.analysis.sanitizers import SanitizerError, TransitionAuditor
    from repro.serving import gateway as gw
    from repro.serving.faults import VirtualClock

    clock = VirtualClock()
    stub = SimpleNamespace(clock=clock)
    sreq = gw.ServeRequest(rid=0, tokens=np.arange(4, dtype=np.int32),
                           max_new_tokens=2)
    handle = gw.RequestHandle(sreq, gw.GenRequest(0, sreq.tokens, 2), stub)
    model = LifecycleModel()
    state = model.initial()
    old = os.environ.get("REPRO_SANITIZE")
    os.environ["REPRO_SANITIZE"] = "1"
    try:
        for ev in trace:
            dst, r = model.resolve(state, ev)
            clock.advance(0.001)
            try:
                handle._transition(dst, now=clock(),
                                   reason=f"modelcheck replay: {ev}")
            except RuntimeError as e:
                return f"RequestHandle rejected {ev!r}: {e}"
            handle.restarts = r
            state = (dst, r)
        auditor = TransitionAuditor()
        try:
            auditor.audit(handle, context="modelcheck replay")
        except SanitizerError as e:
            return str(e)
        return None
    finally:
        if old is None:
            del os.environ["REPRO_SANITIZE"]
        else:
            os.environ["REPRO_SANITIZE"] = old


# -- mutation harness ---------------------------------------------------------


@dataclass
class MutationResult:
    name: str
    model: str
    caught: bool
    trace: Tuple[str, ...]
    message: str
    replayed: Optional[bool]        # None: replay unavailable (no jax)


@contextmanager
def _patched(obj, attr, value):
    orig = getattr(obj, attr)
    setattr(obj, attr, value)
    try:
        yield
    finally:
        setattr(obj, attr, orig)


@contextmanager
def _drop_edge(src: str, dst: str):
    orig = protocol.TRANSITIONS[src]
    protocol.TRANSITIONS[src] = frozenset(orig - {dst})
    try:
        yield
    finally:
        protocol.TRANSITIONS[src] = orig


class _LeakyPool(PagePool):
    """Planted bug: ``free`` returns a page to the free list even while
    other owners still reference it (free-at-refcount>0)."""

    def _revoke(self, page, owner):
        owners = self._owners.get(page, set())
        owners.discard(owner)
        held = self._by_owner.get(owner)
        if held is not None:
            held.discard(page)
            if not held:
                del self._by_owner[owner]
        self._free.append(page)     # BUG: frees despite live references
        return True


MUTATIONS: Dict[str, Tuple[str, str, Callable]] = {
    # name -> (model it must trip, description, contextmanager factory)
    "lifecycle-missing-migration-edge": (
        "lifecycle",
        "drop DECODING -> TRANSFERRING (preemption drains cannot migrate)",
        lambda: _drop_edge(protocol.DECODING, protocol.TRANSFERRING)),
    "lifecycle-missing-crash-requeue": (
        "lifecycle",
        "drop PREFILLING -> QUEUED (prefill crash cannot requeue)",
        lambda: _drop_edge(protocol.PREFILLING, protocol.QUEUED)),
    "retire-free-before-donate": (
        "pagepool",
        "retire frees the chain before donating it to the prefix index",
        lambda: _patched(protocol, "retire_steps",
                         lambda donate: (("free", "donate") if donate
                                         else ("free",)))),
    "retire-double-free": (
        "pagepool",
        "retire releases the slot's references twice",
        lambda: _patched(protocol, "retire_steps",
                         lambda donate: ("donate", "free", "free"))),
    "cow-skip-tail": (
        "pagepool",
        "copy-on-write skips the chain's tail page (boundary off-by-one)",
        lambda: _patched(protocol, "cow_needed",
                         lambda ln, ps, tw, cl:
                         protocol.cow_boundary(ln, ps, tw) < cl - 1)),
    "chunk-admit-incomplete": (
        "chunkedprefill",
        "completion check off by one chunk (admits a job missing the "
        "prompt tail)",
        lambda: _patched(protocol, "chunk_complete",
                         lambda pos, n: pos >= n - ChunkModel.BUDGET)),
    "chunk-per-chunk-quant": (
        "chunkedprefill",
        "chunk extraction quantizes each chunk wire (raw-until-complete "
        "broken; transport double-quantized)",
        lambda: _patched(protocol, "chunk_extract_compress",
                         lambda: True)),
    "pool-free-at-refcount": (
        "pagepool",
        "pool frees a page other owners still reference",
        lambda: _patched(PoolModel, "__init__",
                         _leaky_pool_init)),
}


def _leaky_pool_init(self, pool_factory=None, n_slots=2, n_pages=7,
                     page_size=4):
    PoolModel.__wrapped_init__(self,
                               pool_factory=lambda: _LeakyPool(
                                   n_pages + 1, page_size),
                               n_slots=n_slots, n_pages=n_pages,
                               page_size=page_size)


PoolModel.__wrapped_init__ = PoolModel.__init__


def run_mutations(*, depth: int = 10, replay: bool = True,
                  lifecycle_replay: bool = False) -> List[MutationResult]:
    """Plant each known protocol bug, assert the checker catches it, and
    replay the counterexample through the real code to confirm.
    Pool/chunk replays are stdlib (a fresh real PagePool); lifecycle
    replay drives the real gateway RequestHandle and needs jax, so it is
    opt-in (``lifecycle_replay``) — off, those report ``replayed=None``."""
    out: List[MutationResult] = []
    for name, (model_name, _desc, ctx) in MUTATIONS.items():
        with ctx():
            res = explore(MODELS[model_name](), depth=depth)
            caught = bool(res.violations)
            trace: Tuple[str, ...] = ()
            message = ""
            replayed: Optional[bool] = None
            if caught:
                # prefer a violation with a non-empty replayable trace
                v = next((v for v in res.violations if v.trace),
                         res.violations[0])
                trace, message = v.trace, v.message
                if replay and trace:
                    if model_name == "lifecycle" and not (
                            lifecycle_replay and _jax_available()):
                        replayed = None
                    else:
                        replayed = replay_trace(model_name,
                                                trace) is not None
        out.append(MutationResult(name, model_name, caught, trace,
                                  message, replayed))
    return out


def _jax_available() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False
