"""Runtime sanitizers for the serving stack (``REPRO_SANITIZE=1``).

Where ``repro.analysis.lint`` proves properties of the *source*, this
module audits the *running* system — the class of bug a static rule
cannot see: a page leaked because an exception skipped the release path,
a double free that only happens under preemption racing a finish, a
request whose recorded history disagrees with its final state, a jit
that quietly retraces every step in steady state, a migrated wire that
silently re-encoded because its rows stopped lining up with page rows.

Everything here is opt-in and cheap enough for CI: the gateway installs
the hooks only when ``REPRO_SANITIZE=1`` (see :func:`sanitize_enabled`),
and violations raise :class:`SanitizerError` with the captured
allocation/free sites so the failure points at the buggy call site, not
at the audit that noticed it.

This module imports jax-adjacent serving code lazily so that
``python -m repro.analysis lint`` stays importable in a bare CI image.
"""
from __future__ import annotations

import os
import traceback
from typing import Dict, List, Optional, Sequence, Tuple


class SanitizerError(RuntimeError):
    """An enforced runtime invariant was violated."""


def sanitize_enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "").strip() not in (
        "", "0", "false", "no")


def _site(skip: int = 2, limit: int = 8) -> str:
    """Compact formatted stack of the caller's caller (the interesting
    frame), newest last."""
    frames = traceback.extract_stack()[:-skip]
    frames = [f for f in frames if "/repro/analysis/" not in f.filename]
    return "".join(traceback.format_list(frames[-limit:]))


# -- page-pool sanitizer ------------------------------------------------------


def make_sanitized_pool(num_pages: int, page_size: int):
    """A :class:`~repro.serving.page_pool.PagePool` that remembers WHERE
    every live page was allocated and where every dead page was freed, so
    double-free / use-after-free / leak reports carry the offending call
    sites instead of just a page number."""
    from repro.serving.page_pool import PagePool

    class SanitizedPagePool(PagePool):
        def __init__(self, n, ps):
            super().__init__(n, ps)
            self._alloc_site: Dict[int, str] = {}
            self._free_site: Dict[int, str] = {}
            self._share_site: Dict[Tuple[int, object], str] = {}

        def alloc(self, n, owner):
            pages = super().alloc(n, owner)
            if pages:
                site = _site()
                for p in pages:
                    self._alloc_site[p] = site
                    self._free_site.pop(p, None)
            return pages

        def share(self, pages, owner):
            super().share(pages, owner)
            site = _site()
            for p in pages:
                self._share_site[(p, owner)] = site

        def free(self, pages, owner=None):
            for p in pages:
                owners = self._owners.get(p)
                if owners is None:
                    prior = self._free_site.get(p)
                    if prior is not None:
                        raise SanitizerError(
                            f"double free of page {p}: already freed "
                            f"at:\n{prior}second free at:\n{_site()}")
                    raise SanitizerError(
                        f"free of never-allocated page {p} at:\n{_site()}")
                if owner is not None and owner not in owners:
                    held = (f"slot {next(iter(owners))}" if len(owners) == 1
                            else str(sorted(map(repr, owners))))
                    raise SanitizerError(
                        f"use-after-free hazard: releasing page {p} as "
                        f"owner {owner!r} but it is owned by {held} "
                        f"(allocated at:\n{self._alloc_site.get(p, '?')})"
                        f"\nrelease attempted at:\n{_site()}")
            site = _site()
            super().free(pages, owner)
            for p in pages:
                if p not in self._owners:       # refcount hit 0: truly freed
                    self._free_site[p] = site
                    self._alloc_site.pop(p, None)
                if owner is not None:
                    self._share_site.pop((p, owner), None)

        def check_empty(self, context: str = ""):
            """Assert no live pages remain (drained gateway teardown)."""
            if self._owners:
                lines = []
                for p in sorted(self._owners):
                    who = sorted(map(repr, self._owners[p]))
                    lines.append(
                        f"  page {p} (held by {who}) allocated at:\n"
                        f"{self._alloc_site.get(p, '    <unknown>')}")
                raise SanitizerError(
                    f"page leak{' in ' + context if context else ''}: "
                    f"{len(self._owners)} page(s) still referenced after "
                    f"drain:\n" + "\n".join(lines))

    return SanitizedPagePool(num_pages, page_size)


def audit_paged_engine(engine, context: str = ""):
    """Cross-check a DecodeEngine's references against its pool's
    refcounted owner map. The engine's legitimate reference holders are
    its slot chains, its prefix-cache index, and its in-flight pins —
    every in-use page must be covered by at least one of them (else a
    release path leaked it), and every reference the engine holds must be
    backed by a live refcount under the matching owner tag (else a stale
    table row points at freed or re-owned pages)."""
    pool = getattr(engine, "pool", None)
    if pool is None:
        return
    where = f" in {context}" if context else ""
    slot_pages = getattr(engine, "_slot_pages", {})
    cache = getattr(engine, "prefix_cache", None)
    cache_pages = set(cache.page_set()) if cache is not None else set()
    pins = dict(getattr(engine, "_pins", {}))
    referenced = ({p for ps in slot_pages.values() for p in ps}
                  | cache_pages
                  | {p for ps in pins.values() for p in ps})
    in_use = set(pool.pages_in_use())
    leaked = sorted(in_use - referenced)
    if leaked:
        sites = ""
        alloc_site = getattr(pool, "_alloc_site", {})
        for p in leaked[:4]:
            if p in alloc_site:
                sites += f"\npage {p} allocated at:\n{alloc_site[p]}"
        raise SanitizerError(
            f"page leak{where}: pool holds pages {leaked} that no slot "
            f"chain, prefix-cache entry, or pin references (a release "
            f"path skipped pool.free/unshare)" + sites)
    expected = [(s, p) for s, ps in slot_pages.items() for p in ps]
    if cache is not None:
        expected += [(cache.owner, p) for p in cache_pages]
    expected += [(tag, p) for tag, ps in pins.items() for p in ps]
    for owner, p in expected:
        owners = pool.owners_of(p)
        if not owners:
            raise SanitizerError(
                f"use-after-free{where}: {owner!r} references freed "
                f"page {p}")
        if owner not in owners:
            raise SanitizerError(
                f"page ownership mismatch{where}: page {p} referenced by "
                f"{owner!r} in the engine but refcounted for "
                f"{sorted(map(repr, owners))} in the pool")


# -- request state-machine auditor --------------------------------------------

# independent copy of the DESIGN.md §5 transition table — deliberately NOT
# imported from gateway.py, so a drive-by edit there trips the audit here
_LEGAL: Dict[str, Tuple[str, ...]] = {
    # QUEUED -> TRANSFERRING is the full-prefix-hit fast path: every
    # prompt token is already resident in the decode replica's radix
    # cache, so the request skips the prefill stage outright and only a
    # page-handle "wire" (no tensors) moves (DESIGN.md §10).
    "QUEUED": ("PREFILLING", "TRANSFERRING", "CANCELLED", "REJECTED",
               "FAILED"),
    "PREFILLING": ("TRANSFERRING", "QUEUED", "CANCELLED", "FAILED"),
    "TRANSFERRING": ("DECODING", "QUEUED", "CANCELLED", "FAILED"),
    "DECODING": ("DONE", "QUEUED", "TRANSFERRING", "CANCELLED", "FAILED"),
    "DONE": (),
    "CANCELLED": (),
    "REJECTED": (),
    "FAILED": (),
}
_NEEDS_REASON = ("FAILED", "REJECTED")


class TransitionAuditor:
    """Validates finished requests against the §5 state machine using the
    recorded ``history`` — catching both illegal edges and state fields
    that were assigned around ``_transition``."""

    def __init__(self):
        self.audited = 0
        self.illegal = 0

    def audit(self, handle, context: str = ""):
        where = f" in {context}" if context else ""
        rid = getattr(getattr(handle, "request", None), "rid",
                      getattr(handle, "rid", "?"))
        hist = list(getattr(handle, "history", ()))
        state = _state_name(handle.state)
        self.audited += 1
        if not hist:
            self._fail(f"request {rid}{where}: empty history")
        chain = [_state_name(s) for _, s in hist]
        if chain[0] != "QUEUED":
            self._fail(f"request {rid}{where}: history starts at "
                       f"{chain[0]}, not QUEUED")
        for prev, nxt in zip(chain, chain[1:]):
            if nxt not in _LEGAL.get(prev, ()):
                self._fail(
                    f"request {rid}{where}: illegal transition "
                    f"{prev} -> {nxt} (history: {' -> '.join(chain)}); "
                    f"legal from {prev}: {list(_LEGAL.get(prev, ()))}")
        if chain[-1] != state:
            self._fail(
                f"request {rid}{where}: state is {state} but history "
                f"ends at {chain[-1]} — state was assigned without "
                f"_transition (history: {' -> '.join(chain)})")
        if state in _NEEDS_REASON and not getattr(handle, "reason", None):
            self._fail(f"request {rid}{where}: terminal {state} carries "
                       f"no reason")
        times = [t for t, _ in hist]
        if any(b < a for a, b in zip(times, times[1:])):
            self._fail(f"request {rid}{where}: history timestamps go "
                       f"backwards ({times})")

    def _fail(self, msg: str):
        self.illegal += 1
        raise SanitizerError(msg + "\n    (DESIGN.md §5 / lint rule R004 "
                             "govern this state machine)")


def _state_name(s) -> str:
    return getattr(s, "name", str(s))


# -- jit retrace monitor ------------------------------------------------------


class RetraceMonitor:
    """Flags steady-state recompiles in the DECODE loop: after warmup, a
    decode replica's jit caches must be size-stable (DESIGN.md §3 — the
    loop is fixed-shape by construction), so growth means a shape or a
    hashable static argument is churning per step (a silent 100x
    slowdown). Prefill is deliberately out of scope: it compiles one
    variant per pow2 length bucket and admitted batch shape, bounded but
    trace-dependent, so its cache may legitimately grow past warmup."""

    def __init__(self):
        self._baseline: Optional[Dict[str, int]] = None

    @staticmethod
    def _sizes(gw) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for kind, handles in (("dec", gw.dec),):
            for i, h in enumerate(handles):
                client = getattr(h, "client", None)
                n = getattr(client, "jit_cache_size", None)
                if callable(n):
                    n = n()
                if n is not None:
                    out[f"{kind}[{i}]"] = int(n)
        return out

    def mark_steady(self, gw):
        """Snapshot cache sizes; call once the pipeline is warm."""
        self._baseline = self._sizes(gw)

    def check(self, gw, context: str = ""):
        if self._baseline is None:
            return
        now = self._sizes(gw)
        grew = {k: (self._baseline.get(k, 0), v) for k, v in now.items()
                if v > self._baseline.get(k, v)}
        if grew:
            where = f" in {context}" if context else ""
            detail = ", ".join(f"{k}: {a} -> {b}" for k, (a, b)
                               in sorted(grew.items()))
            raise SanitizerError(
                f"steady-state jit retrace{where}: compile caches grew "
                f"after warmup ({detail}) — a shape or static arg is "
                f"churning per step")


# -- wire alignment -----------------------------------------------------------


def check_wire_alignment(wire, cfg, context: str = ""):
    """A migrated (decode->decode) wire must scatter zero-copy: its int4
    rows must already be page rows. Re-encoding here means
    ``extract_slot_wire`` / ``insert_wires`` drifted apart and every
    migration silently pays a dequant+quant round-trip."""
    from repro.models import paged
    from repro.serving.page_pool import _wire_rows_aligned
    g = paged.page_group(cfg)
    ppr = paged.groups_per_token(cfg)
    bad: List[str] = []
    for name, slot_wire in wire.slots.items():
        for key, wt in slot_wire.items():
            if wt.kind == "int4" and not _wire_rows_aligned(wt, g, ppr):
                pk = wt.payload["packed"]
                bad.append(f"{name}.{key}: rows {tuple(pk.shape)} vs "
                           f"expected ({wire.request_len * wt.orig_shape[0] * ppr}, {g // 2})")
            elif wt.kind != "int4":
                bad.append(f"{name}.{key}: kind={wt.kind!r} (not int4)")
    if bad:
        where = f" in {context}" if context else ""
        raise SanitizerError(
            f"misaligned migration wire{where} — insertion will silently "
            f"re-encode (group g={g}, ppr={ppr}):\n  "
            + "\n  ".join(bad)
            + "\n    (layout contract: kernels/kv_layout.py, rule R005)")


# -- gateway-level orchestration ----------------------------------------------


class GatewaySanitizer:
    """The hooks a Gateway installs under ``REPRO_SANITIZE=1``:

    * every finished/terminal request runs :class:`TransitionAuditor`;
    * on drain, live decode replicas get :func:`audit_paged_engine` and
      the retrace check; at teardown sanitized pools ``check_empty``.
    """

    def __init__(self):
        self.transitions = TransitionAuditor()
        self.retrace = RetraceMonitor()

    def on_finish(self, handle):
        self.transitions.audit(handle, context="on_finish")

    def on_steady(self, gw):
        self.retrace.mark_steady(gw)

    def check(self, gw, context: str = "drain"):
        for h in gw.done:
            if not _LEGAL.get(_state_name(h.state), ("x",)):  # terminal
                self.transitions.audit(h, context=context)
        for d in gw.dec:
            if not getattr(d, "alive", True):
                continue  # a crashed replica's residents are accounted
            ps = getattr(d.client, "page_stats", None)
            stats = ps() if callable(ps) else None
            if stats and stats.get("leaked_pages"):
                raise SanitizerError(
                    f"{context}: decode[{d.idx}] reports "
                    f"{stats['leaked_pages']} leaked page(s)")
            eng = getattr(d, "engine", None)   # None for RPC clients
            if eng is not None:
                audit_paged_engine(eng, context=f"{context}/dec[{d.idx}]")
        self.retrace.check(gw, context=context)

    def stats(self) -> Dict[str, int]:
        return {"transitions_audited": self.transitions.audited,
                "transition_violations": self.transitions.illegal}
