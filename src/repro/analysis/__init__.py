"""Static analysis + model checking + runtime sanitizers for the
serving stack.

Three halves:

* ``repro.analysis.lint`` (+ ``repro.analysis.dataflow``) — stdlib-
  ``ast`` rules R001–R008 over ``src/repro``, ``benchmarks`` and
  ``tests`` (``python -m repro.analysis lint``). Pure stdlib:
  importable (and runnable) without jax.
* ``repro.analysis.modelcheck`` — explicit-state model checker for the
  request-lifecycle / page-pool / chunked-prefill protocols
  (``python -m repro.analysis check``), with a mutation harness that
  proves the checker catches planted protocol bugs. Stdlib except
  lifecycle-counterexample replay through the real gateway.
* ``repro.analysis.sanitizers`` — opt-in runtime audits gated on
  ``REPRO_SANITIZE=1``: page leak/double-free/use-after-free tracking,
  request state-machine audits, jit retrace counters, migration-wire
  alignment.

This package root imports nothing heavy; symbols load lazily so the
lint/check CLIs work in an image with no accelerator stack.
"""
from __future__ import annotations

_SANITIZER_SYMBOLS = (
    "SanitizerError", "sanitize_enabled", "make_sanitized_pool",
    "audit_paged_engine", "TransitionAuditor", "RetraceMonitor",
    "check_wire_alignment", "GatewaySanitizer",
)

_MODELCHECK_SYMBOLS = (
    "explore", "run_check", "run_mutations", "replay_trace",
    "check_table_drift", "Violation", "CheckResult", "MutationResult",
    "LifecycleModel", "PoolModel", "ChunkModel", "MUTATIONS",
)

__all__ = ("lint_sources", "run_lint", "Finding", "RULES",
           ) + _SANITIZER_SYMBOLS + _MODELCHECK_SYMBOLS


def __getattr__(name):
    if name in ("lint_sources", "run_lint", "Finding", "RULES",
                "collect_files"):
        from repro.analysis import lint as _lint
        return getattr(_lint, name)
    if name in _SANITIZER_SYMBOLS:
        from repro.analysis import sanitizers as _san
        return getattr(_san, name)
    if name in _MODELCHECK_SYMBOLS:
        from repro.analysis import modelcheck as _mc
        return getattr(_mc, name)
    raise AttributeError(name)
