"""Static invariant linter + runtime sanitizers for the serving stack.

Two halves:

* ``repro.analysis.lint`` — stdlib-``ast`` rules R001–R005 over
  ``src/repro`` and ``benchmarks`` (``python -m repro.analysis lint``).
  Pure stdlib: importable (and runnable) without jax.
* ``repro.analysis.sanitizers`` — opt-in runtime audits gated on
  ``REPRO_SANITIZE=1``: page leak/double-free/use-after-free tracking,
  request state-machine audits, jit retrace counters, migration-wire
  alignment.

This package root imports nothing heavy; sanitizer symbols load lazily
so the lint CLI works in an image with no accelerator stack.
"""
from __future__ import annotations

_SANITIZER_SYMBOLS = (
    "SanitizerError", "sanitize_enabled", "make_sanitized_pool",
    "audit_paged_engine", "TransitionAuditor", "RetraceMonitor",
    "check_wire_alignment", "GatewaySanitizer",
)

__all__ = ("lint_sources", "run_lint", "Finding", "RULES",
           ) + _SANITIZER_SYMBOLS


def __getattr__(name):
    if name in ("lint_sources", "run_lint", "Finding", "RULES",
                "collect_files"):
        from repro.analysis import lint as _lint
        return getattr(_lint, name)
    if name in _SANITIZER_SYMBOLS:
        from repro.analysis import sanitizers as _san
        return getattr(_san, name)
    raise AttributeError(name)
