"""Wire-provenance dataflow lint: the R007/R008 taint rules.

Stdlib ``ast`` only, same framework as :mod:`repro.analysis.lint` (which
runs these visitors and applies pragmas/scoping). Where R001–R006 are
syntactic pattern rules, these two track *provenance* — what a value IS
(a raw chunk wire, an int4-quantized transport wire, a payload array)
through assignments, loop targets and comprehensions within a function.

* **R007 — quantize once, over the spliced whole.** Chunked prefill
  extracts each chunk RAW (``run(..., compress=False)`` — spelled
  ``compress=protocol.chunk_extract_compress()`` in the engine) and
  accumulates the wires on ``job.wires``; ONLY when the job completes is
  the splice (``concat_wires``) compressed, exactly once. Flagged:
  ``compress_wire`` applied to an already-quantized value (double
  quantization destroys the affine scales), ``compress_wire`` applied to
  a single chunk wire (per-chunk quantization breaks bit-identity with
  one-shot extraction: group statistics differ per chunk), and appending
  a quantized wire to a ``.wires`` chunk list (the resumable prefix must
  stay exact floats).
* **R008 — wire layout arithmetic stays in the layout modules.** The
  position-aligned group-row mapping (row ``t*ppr + r`` holds token
  ``t``'s r-th group, group width from ``kv_layout.pick_group``) is what
  makes wire splices and zero-copy page inserts line up. Outside the
  modules that OWN that contract (``kv_transfer.py``, ``page_pool.py``,
  ``models/paged.py``, ``kernels/``, and the runtime auditor
  ``analysis/sanitizers.py``), code must treat wires as opaque: no
  direct ``KVWire``/``WireTensor`` construction, no ``ppr``/
  ``groups_per_token`` row arithmetic, no manual concatenation of
  ``.payload`` arrays (splice with ``concat_wires``).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from repro.analysis.lint import Finding

# taint lattice values
QUANT = "quant"          # int4-quantized wire (compress_wire output)
RAW = "raw"              # raw-float wire (chunk extraction, concat splice)
CHUNK = "chunk"          # one element of a ``.wires`` chunk list
WIRELIST = "wirelist"    # a ``.wires`` attribute itself

_RAW_SOURCES = ("concat_wires", "extract_kv", "extract_resident",
                "extract_slot_wire")


def _callee(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _is_raw_compress_kw(value: ast.AST) -> bool:
    """``compress=False`` or ``compress=<...>.chunk_extract_compress()``
    — the two sanctioned raw-extraction spellings."""
    if isinstance(value, ast.Constant) and value.value is False:
        return True
    return (isinstance(value, ast.Call)
            and _callee(value) == "chunk_extract_compress")


class _TaintScope:
    def __init__(self):
        self.env: Dict[str, str] = {}


class _R007(ast.NodeVisitor):
    """Function-local wire taint tracking (flow = source order)."""

    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []
        self._scopes = [_TaintScope()]

    # -- taint computation ---------------------------------------------------

    def _env(self) -> Dict[str, str]:
        return self._scopes[-1].env

    def taint_of(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name):
            return self._env().get(node.id)
        if isinstance(node, ast.Attribute):
            if node.attr == "wires":
                return WIRELIST
            return None
        if isinstance(node, ast.Subscript):
            if self.taint_of(node.value) == WIRELIST:
                return CHUNK
            return None
        if isinstance(node, ast.Call):
            name = _callee(node)
            if name == "compress_wire":
                return QUANT
            if name in _RAW_SOURCES:
                return RAW
            if name == "run":
                return RAW if self._run_is_raw(node) else QUANT
            if name == "materialize" and isinstance(node.func,
                                                    ast.Attribute):
                return self.taint_of(node.func.value)  # wire hop preserves
            return None
        if isinstance(node, ast.IfExp):
            a, b = self.taint_of(node.body), self.taint_of(node.orelse)
            return a if a == b else None
        return None

    @staticmethod
    def _run_is_raw(node: ast.Call) -> bool:
        for kw in node.keywords:
            if kw.arg == "compress":
                return _is_raw_compress_kw(kw.value)
        return False                    # run() defaults to compress=True

    # -- scope / binding -----------------------------------------------------

    def visit_FunctionDef(self, node):
        self._scopes.append(_TaintScope())
        self.generic_visit(node)
        self._scopes.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Assign(self, node):
        self.generic_visit(node)
        if len(node.targets) == 1:
            self._bind(node.targets[0], node.value)

    def _bind(self, target: ast.AST, value: ast.AST):
        if isinstance(target, ast.Name):
            t = self.taint_of(value)
            if t is None:
                self._env().pop(target.id, None)
            else:
                self._env()[target.id] = t
        elif (isinstance(target, ast.Tuple)
              and isinstance(value, ast.Tuple)
              and len(target.elts) == len(value.elts)):
            for tgt, val in zip(target.elts, value.elts):
                self._bind(tgt, val)

    def _bind_loop_target(self, target: ast.AST, it: ast.AST):
        if self.taint_of(it) == WIRELIST and isinstance(target, ast.Name):
            self._env()[target.id] = CHUNK
        elif (isinstance(it, ast.Call) and _callee(it) == "run"
              and isinstance(target, ast.Tuple)
              and len(target.elts) == 3
              and isinstance(target.elts[1], ast.Name)):
            # run() yields (req, wire, first_token) triples
            self._env()[target.elts[1].id] = (
                RAW if self._run_is_raw(it) else QUANT)

    def visit_For(self, node):
        self._bind_loop_target(node.target, node.iter)
        self.generic_visit(node)

    def _visit_comp(self, node):
        for gen in node.generators:
            self._bind_loop_target(gen.target, gen.iter)
        self.generic_visit(node)

    visit_ListComp = visit_SetComp = visit_GeneratorExp = _visit_comp
    visit_DictComp = _visit_comp

    # -- violations ----------------------------------------------------------

    def visit_Call(self, node):
        name = _callee(node)
        if name == "compress_wire" and node.args:
            t = self.taint_of(node.args[0])
            if t == QUANT:
                self.findings.append(Finding(
                    "R007", self.path, node.lineno, node.col_offset,
                    "double quantization: compress_wire applied to an "
                    "already-quantized wire",
                    "quantize exactly once — int4 groups re-quantized "
                    "lose their affine scales"))
            elif t == CHUNK:
                self.findings.append(Finding(
                    "R007", self.path, node.lineno, node.col_offset,
                    "quantizing a single chunk wire before the job "
                    "completes",
                    "splice the chunks with concat_wires(job.wires) at "
                    "completion and compress the whole — per-chunk group "
                    "statistics break bit-identity with one-shot "
                    "extraction"))
        elif (name in ("append", "extend")
              and isinstance(node.func, ast.Attribute)
              and self.taint_of(node.func.value) == WIRELIST):
            for arg in node.args:
                if self.taint_of(arg) == QUANT:
                    self.findings.append(Finding(
                        "R007", self.path, node.lineno, node.col_offset,
                        "appending a quantized wire to a chunk list — "
                        "chunk wires must stay RAW until the job "
                        "completes",
                        "extract chunks with compress="
                        "protocol.chunk_extract_compress() (False); the "
                        "resumable prefix must be exact floats"))
        self.generic_visit(node)


# -- R008 ---------------------------------------------------------------------

_SPLICE_CALLS = ("concatenate", "stack", "vstack", "hstack")
_LAYOUT_NAMES = ("ppr", "groups_per_token")


def _mentions_layout_name(node: ast.AST) -> Optional[str]:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id in _LAYOUT_NAMES:
            return n.id
        if isinstance(n, ast.Attribute) and n.attr in _LAYOUT_NAMES:
            return n.attr
    return None


def _mentions_payload(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Attribute) and n.attr == "payload"
               for n in ast.walk(node))


class _R008(ast.NodeVisitor):
    """Wires are opaque outside the layout-owning modules."""

    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []

    def visit_Call(self, node):
        name = _callee(node)
        if name in ("KVWire", "WireTensor"):
            self.findings.append(Finding(
                "R008", self.path, node.lineno, node.col_offset,
                f"constructs {name}(...) outside the wire-layout modules",
                "wires are produced only by kv_transfer (extract_kv / "
                "concat_wires / compress_wire) and page_pool "
                "(extract_slot_wire) — their row layout is a cross-module "
                "contract"))
        elif name == "groups_per_token":
            self.findings.append(Finding(
                "R008", self.path, node.lineno, node.col_offset,
                "computes the wire's groups-per-token layout outside the "
                "layout modules",
                "treat wires as opaque; splice with concat_wires, insert "
                "with page_pool.insert_wires"))
        elif name in _SPLICE_CALLS and any(_mentions_payload(a)
                                           for a in node.args):
            self.findings.append(Finding(
                "R008", self.path, node.lineno, node.col_offset,
                f"manually splices wire payload arrays with {name}()",
                "use kv_transfer.concat_wires — chunk boundaries must "
                "stay group-row aligned, which only the layout modules "
                "guarantee"))
        self.generic_visit(node)

    def visit_BinOp(self, node):
        if isinstance(node.op, (ast.Mult, ast.FloorDiv, ast.Mod)):
            hit = (_mentions_layout_name(node.left)
                   or _mentions_layout_name(node.right))
            if hit:
                self.findings.append(Finding(
                    "R008", self.path, node.lineno, node.col_offset,
                    f"group-row arithmetic over `{hit}` outside the "
                    f"layout modules",
                    "the row `t*ppr + r` mapping belongs to kv_layout / "
                    "kv_transfer / page_pool — go through their APIs"))
                return              # one finding per expression tree
        self.generic_visit(node)
