"""Pipeline parallelism over a mesh axis via shard_map + collective_permute.

The paper's deployment plans combine TP x PP per replica; on TPU pods the
natural PP cut is the inter-pod ("pod") axis — only activations cross the
DCN, matching the paper's heuristic that slow links carry pipeline (not
tensor) traffic.

GPipe loop schedule: rank 0 injects one microbatch per step, activations
hop rank->rank+1 with collective_permute, rank S-1 collects outputs;
n_steps = M + S - 1, bubble fraction (S-1)/(M+S-1). Stages run "garbage"
during fill/drain (masked on collection) — the standard SPMD formulation.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(fn: Callable, params_stacked, x, *, mesh,
                   axis: str = "pod", n_microbatch: int = None):
    """Run ``fn(stage_params, microbatch) -> microbatch`` as a pipeline.

    params_stacked: pytree with a leading stage axis (== mesh.shape[axis]),
    sharded one-stage-per-rank along ``axis``. x: (B, ...) global batch
    (replicated across ``axis``; microbatches enter at rank 0).
    """
    S = mesh.shape[axis]
    B = x.shape[0]
    M = n_microbatch or S
    assert B % M == 0, (B, M)

    def local(params_local, x_full):
        p = jax.tree.map(lambda a: a[0], params_local)
        rank = lax.axis_index(axis)
        mb = x_full.reshape((M, B // M) + x_full.shape[1:])
        n_steps = M + S - 1
        buf = jnp.zeros_like(mb[0])
        outbuf = jnp.zeros_like(mb)

        def step(carry, i):
            buf, outbuf = carry
            inject = mb[jnp.clip(i, 0, M - 1)]
            cur = jnp.where((rank == 0) & (i < M), inject, buf)
            out = fn(p, cur)
            idx = i - (S - 1)
            upd = lax.dynamic_update_index_in_dim(
                outbuf, out, jnp.clip(idx, 0, M - 1), 0)
            outbuf = jnp.where((rank == S - 1) & (idx >= 0), upd, outbuf)
            nxt = lax.ppermute(out, axis,
                               [(j, j + 1) for j in range(S - 1)])
            return (buf if S == 1 else nxt, outbuf), None

        (_, outbuf), _ = lax.scan(step, (buf, outbuf), jnp.arange(n_steps))
        # results live on the last rank; replicate via masked psum
        outbuf = lax.psum(
            jnp.where(rank == S - 1, outbuf, jnp.zeros_like(outbuf)), axis)
        return outbuf.reshape((B,) + x_full.shape[1:])

    spec_p = jax.tree.map(lambda _: P(axis), params_stacked)
    return shard_map(local, mesh=mesh,
                     in_specs=(spec_p, P()),
                     out_specs=P(), check_rep=False)(params_stacked, x)


def pipeline_stage_specs(mesh, params_stacked, axis: str = "pod"):
    """NamedSharding specs placing stage i of the stacked params on rank i."""
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda a: NamedSharding(mesh, P(*((axis,) + (None,) * (a.ndim - 1)))),
        params_stacked)
