"""Logical-axis sharding rules (flax-style) + per-param PartitionSpecs.

Models annotate intermediates with *logical* axes ("batch", "seq", "embed",
"heads", "kv_seq", ...). The launcher installs a mapping logical->mesh axis
for the active mesh; outside a mesh context the annotations are no-ops, so
smoke tests on one CPU device run the identical code path.
"""
from __future__ import annotations

import re
import threading
from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


DEFAULT_RULES = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "kv_seq": "model",       # decode KV cache: sequence-sharded (flash-decode)
    "experts": "model",
    "mamba_inner": "model",
    "state": None,
}

# Sequence-parallel variant: long-context activations shard seq over model.
SEQPAR_RULES = dict(DEFAULT_RULES, seq="model", kv_seq="model")

# MQA/GQA fix-up (heads % model != 0): shard attention on the QUERY sequence,
# force-replicate the (tiny) KV — stops XLA sharding head_dim and emitting
# partial-sum all-reduces per attention block. "qseq"/"kseq" are only
# constrained inside attention; the rest of the model keeps DEFAULT rules.
ATTN_QSEQ_RULES = dict(DEFAULT_RULES, qseq="model", kseq="force_replicated")


def set_rules(mesh: Optional[Mesh], rules: Optional[dict] = None):
    _state.mesh = mesh
    _state.rules = dict(rules) if rules is not None else None


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextmanager
def use_rules(mesh: Optional[Mesh], rules: Optional[dict] = None):
    old = (getattr(_state, "mesh", None), getattr(_state, "rules", None))
    set_rules(mesh, rules if rules is not None else DEFAULT_RULES)
    try:
        yield
    finally:
        set_rules(*old)


def _resolve(mesh, rules, logical_axes, ndim):
    spec = [None] * ndim
    forced = False
    for i, ax in enumerate(logical_axes):
        if ax is None:
            continue
        target = rules.get(ax)
        if target == "force_replicated":
            forced = True  # emit the constraint even if fully-None
            continue
        if target is None:
            continue
        names = target if isinstance(target, tuple) else (target,)
        names = tuple(n for n in names if n in mesh.shape)
        if names:
            spec[i] = names if len(names) > 1 else names[0]
    return P(*spec), forced


def constrain(x, *logical_axes):
    """with_sharding_constraint on a logical spec; no-op without rules.

    A rule value of "force_replicated" pins the constraint even when every
    dim resolves to None (explicit replication — used to stop XLA SPMD from
    inventing partial-sum shardings, e.g. head_dim splits under MQA)."""
    mesh = getattr(_state, "mesh", None)
    rules = getattr(_state, "rules", None)
    if mesh is None or rules is None:
        return x
    spec, forced = _resolve(mesh, rules, logical_axes, x.ndim)
    # per-axis divisibility: drop (replicate) any axis that does not divide
    kept = []
    for i, s in enumerate(spec):
        if s is None:
            kept.append(None)
            continue
        names = s if isinstance(s, tuple) else (s,)
        size = 1
        for nm in names:
            size *= mesh.shape[nm]
        kept.append(s if x.shape[i] % size == 0 else None)
    if forced or any(s is not None for s in kept):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*kept)))
    return x


# ---------------------------------------------------------------------------
# Per-parameter PartitionSpecs (pattern-matched on the param tree path)
# ---------------------------------------------------------------------------

# (regex on "/"-joined path, spec builder given leaf ndim). Specs are written
# for the *unstacked* param; a leading scan/stack dim is padded with None.
_PARAM_PATTERNS = [
    (r"embed$",                lambda: P(None, "model")),            # (V, d)
    (r"unembed$",              lambda: P(None, "model")),            # (d, V)
    (r"pos_embed$",            lambda: P(None, None)),
    (r"(wq|wk|wv)/w$",         lambda: P(None, "model")),            # col-par
    (r"wo/w$",                 lambda: P("model", None)),            # row-par
    (r"(wi|w_up|ffn_wi|w_in)/w$", lambda: P(None, "model")),
    (r"(w_down|ffn_wo)/w$",    lambda: P("model", None)),
    (r"moe/wi$",               lambda: P("model", None, None)),      # (E,d,f) EP
    (r"moe/wo$",               lambda: P("model", None, None)),
    (r"moe/router$",           lambda: P(None, None)),
    (r"shared/wi/w$",          lambda: P(None, "model")),
    (r"shared/wo/w$",          lambda: P("model", None)),
    (r"in_proj/w$",            lambda: P(None, "model")),            # mamba
    (r"conv_w$",               lambda: P(None, "model")),            # (K, di)
    (r"conv_b$",               lambda: P("model",)),
    (r"x_proj/w$",             lambda: P("model", None)),
    (r"dt_proj/w$",            lambda: P(None, "model")),
    (r"A_log$",                lambda: P("model", None)),
    (r"D$",                    lambda: P("model",)),
    (r"w_if/w$",               lambda: P(None, None)),               # tiny gates
    (r"skip$",                 lambda: P("model",)),
]


def param_spec(path: str, ndim: int, n_stacked_dims: int = 0) -> P:
    for pat, builder in _PARAM_PATTERNS:
        if re.search(pat, path):
            spec = builder()
            if len(spec) + n_stacked_dims == ndim:
                return P(*([None] * n_stacked_dims + list(spec)))
            return P(*([None] * ndim))  # rank mismatch (e.g. bias): replicate
    return P(*([None] * ndim))  # default: replicated


def tree_paths(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(tree_paths(v, f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(tree_paths(v, f"{prefix}/{i}"))
    else:
        out[prefix] = tree
    return out


def params_pspec_tree(params, *, stacked_prefixes=("blocks",)):
    """PartitionSpec pytree matching ``params``; block params get a leading
    None for the scan-stacking dimension."""
    def rec(tree, prefix="", stacked=False):
        if isinstance(tree, dict):
            return {k: rec(v, f"{prefix}/{k}" if prefix else str(k),
                           stacked or k in stacked_prefixes)
                    for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            t = type(tree)
            return t(rec(v, f"{prefix}/{i}", stacked)
                     for i, v in enumerate(tree))
        n_stack = 1 if stacked else 0
        return param_spec(prefix, tree.ndim if hasattr(tree, "ndim")
                          else len(tree.shape), n_stack)
    return rec(params)
