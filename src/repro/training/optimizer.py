"""Optimizers (AdamW) + gradient transformations for large-scale training.

Includes an int8 error-feedback gradient compressor for the DP all-reduce —
a distributed-optimization trick for low-bandwidth (cloud) links, in the same
spirit as the paper's KV-cache wire compression.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression (for low-bandwidth DP links)
# ---------------------------------------------------------------------------


def ef_int8_compress(g, error):
    """Quantize g+error to int8 with per-tensor scale; returns (q, scale,
    new_error)."""
    gf = g.astype(jnp.float32) + error
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def ef_int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def make_train_step(api, opt_cfg: AdamWConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Gradients reduce across the data axes implicitly via pjit (parameters are
    replicated over data -> XLA inserts the gradient all-reduce).
    """
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss(p, batch))(params)
        params, opt_state, stats = adamw_update(opt_cfg, grads, opt_state,
                                                params)
        stats["loss"] = loss
        return params, opt_state, stats

    return train_step
