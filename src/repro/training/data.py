"""Synthetic-but-structured data pipeline: deterministic token streams with
document packing, sharded per data-parallel rank, infinitely resumable
(state = (epoch_seed, step) — restart-safe for checkpoint/restore).

The generator produces Zipf-distributed tokens with local n-gram structure so
the loss actually decreases during the example training runs (pure-uniform
tokens would pin the loss at ln(V)).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    zipf_a: float = 1.3


class PackedLM:
    """Documents sampled, concatenated, chunked to seq_len (+1 for targets)."""

    def __init__(self, cfg: DataConfig, *, rank: int = 0, world: int = 1):
        assert cfg.global_batch % world == 0
        self.cfg = cfg
        self.rank, self.world = rank, world
        self.local_batch = cfg.global_batch // world
        self.step = 0

    def _doc(self, rng) -> np.ndarray:
        n = max(8, int(rng.exponential(self.cfg.mean_doc_len)))
        # zipf body with a per-doc offset -> learnable bigram structure
        base = rng.zipf(self.cfg.zipf_a, n) % (self.cfg.vocab_size - 2)
        shift = rng.integers(1, 17)
        mix = (base + np.roll(base, 1) * shift) % (self.cfg.vocab_size - 2)
        doc = np.concatenate([[self.cfg.vocab_size - 1], mix.astype(np.int64)])
        return doc

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        need = self.cfg.seq_len + 1
        toks = np.zeros((self.local_batch, need), np.int64)
        for b in range(self.local_batch):
            rng = np.random.default_rng(
                (self.cfg.seed, step, self.rank, b))
            buf = []
            total = 0
            while total < need:
                d = self._doc(rng)
                buf.append(d)
                total += len(d)
            row = np.concatenate(buf)[:need]
            toks[b] = row
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            b = self.batch_at(self.step)
            self.step += 1  # state() now points at the NEXT batch, so a
            yield b          # checkpoint taken mid-loop resumes correctly

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict):
        self.step = int(state["step"])
