"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.

LLaMA+Mistral mix with sliding-window attention (window 4096) -> sub-quadratic,
so the long_500k cell runs for this arch. [arXiv:2401.16818; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    mlp_type="silu",
    norm_type="rmsnorm",
    rope_theta=10000.0,
    sliding_window=4096,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="h2o-danube-3-4b-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        sliding_window=32, attn_chunk_q=16, attn_chunk_kv=16, vocab_chunk=32,
        remat=False)
