"""Architecture config registry: ``get_config(arch)`` / ``get_reduced(arch)``.

Assigned pool (10 archs) + the paper's own LLaMA-30B.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import (LONG_500K, DECODE_32K, PREFILL_32K, SHAPES,
                                TRAIN_4K, ModelConfig, ShapeSpec)

from repro.configs import (command_r_35b, gemma_2b, h2o_danube_3_4b,
                           jamba_v01_52b, llama4_maverick_400b_a17b,
                           llama_30b, llava_next_mistral_7b,
                           qwen3_moe_235b_a22b, stablelm_3b, whisper_base,
                           xlstm_125m)

_MODULES = {
    "stablelm-3b": stablelm_3b,
    "gemma-2b": gemma_2b,
    "h2o-danube-3-4b": h2o_danube_3_4b,
    "command-r-35b": command_r_35b,
    "whisper-base": whisper_base,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b,
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "jamba-v0.1-52b": jamba_v01_52b,
    "xlstm-125m": xlstm_125m,
    "llama-30b": llama_30b,
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "llama-30b")
ALL_ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return _MODULES[arch].CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _MODULES[arch].reduced()


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]


def all_cells():
    """Every runnable (arch, shape) dry-run cell, plus documented skips."""
    runnable, skipped = [], []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name in cfg.shape_cells():
                runnable.append((arch, shape.name))
            else:
                skipped.append((arch, shape.name,
                                "long_500k requires sub-quadratic attention; "
                                f"{arch} is pure full-attention"))
    return runnable, skipped


__all__ = [
    "ModelConfig", "ShapeSpec", "SHAPES", "TRAIN_4K", "PREFILL_32K",
    "DECODE_32K", "LONG_500K", "ASSIGNED_ARCHS", "ALL_ARCHS",
    "get_config", "get_reduced", "get_shape", "all_cells",
]
