"""llama-30b — the paper's own serving model (ThunderServe §5.1 deploys
LLaMA-30B on the heterogeneous cloud). 60L d_model=6656 52H MHA d_ff=17920
vocab=32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-30b",
    family="dense",
    num_layers=60,
    d_model=6656,
    num_heads=52,
    num_kv_heads=52,
    head_dim=128,
    d_ff=17920,
    vocab_size=32000,
    mlp_type="silu",
    norm_type="rmsnorm",
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="llama-30b-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        attn_chunk_q=16, attn_chunk_kv=16, vocab_chunk=32, remat=False)
