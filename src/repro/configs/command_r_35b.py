"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.

Cohere Command-R: parallel attention+FFN residual blocks, LayerNorm, no bias,
tied embeddings, logit softcap absent. [hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    mlp_type="silu",
    norm_type="layernorm",
    parallel_block=True,
    rope_theta=8000000.0,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="command-r-35b-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        attn_chunk_q=16, attn_chunk_kv=16, vocab_chunk=32, remat=False)
