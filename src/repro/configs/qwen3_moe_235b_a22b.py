"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) expert d_ff=1536
vocab=151936, MoE 128 experts top-8, QK-norm. [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                  # per-expert hidden dim
    vocab_size=151936,
    mlp_type="silu",
    norm_type="rmsnorm",
    qk_norm=True,
    rope_theta=1000000.0,
    moe_num_experts=128,
    moe_top_k=8,
    moe_every=1,
    moe_d_ff=1536,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-moe-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=96, vocab_size=256,
        moe_num_experts=8, moe_top_k=2, moe_d_ff=96,
        attn_chunk_q=16, attn_chunk_kv=16, vocab_chunk=32, remat=False)
