"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000. Mistral-7B backbone; anyres vision tower is a STUB —
input_specs() provides 576 precomputed patch embeddings prepended to the
text tokens (patch count folds into seq_len budget).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    mlp_type="silu",
    norm_type="rmsnorm",
    rope_theta=1000000.0,
    num_patches=576,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="llava-next-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, num_patches=8,
        attn_chunk_q=16, attn_chunk_kv=16, vocab_chunk=32, remat=False)
