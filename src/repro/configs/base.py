"""Unified model/shape configuration for all assigned architectures.

Every architecture in the assignment pool is expressible as a ``ModelConfig``.
``reduced()`` returns a small same-family config for CPU smoke tests; the full
configs are only ever lowered abstractly (ShapeDtypeStruct) by the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Shapes (assigned): every (arch x shape) cell is defined by one of these.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

_KINDS_CACHE: dict = {}
_COUNT_CACHE: dict = {}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | audio | vlm | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # Block style
    mlp_type: str = "silu"          # silu (SwiGLU) | geglu | gelu
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    parallel_block: bool = False     # parallel attn+FFN residual (command-r)
    qk_norm: bool = False            # per-head RMSNorm on q,k (qwen3)
    attn_bias: bool = False
    mlp_bias: bool = False
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0          # fraction of head_dim rotated
    sliding_window: int = 0          # 0 -> full attention
    attn_logit_softcap: float = 0.0
    embed_scale: bool = False        # scale embeddings by sqrt(d_model) (gemma)
    tie_embeddings: bool = False

    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1               # MoE replaces FFN on layers l % moe_every == moe_offset
    moe_offset: int = 0
    moe_d_ff: int = 0                # expert hidden dim (0 -> d_ff)
    moe_shared_expert: bool = False  # llama4-style shared expert
    moe_capacity_factor: float = 1.25

    # Hybrid (jamba): attention on layers l % attn_period == attn_offset, Mamba elsewhere
    attn_period: int = 0
    attn_offset: int = 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0           # 0 -> ceil(d_model / 16)

    # Encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame embeddings (stub frontend)

    # VLM (llava): precomputed patch embeddings prepended to text tokens
    num_patches: int = 0

    # xLSTM
    xlstm_slstm_every: int = 0       # sLSTM on layers l % every == offset; 0 -> none
    xlstm_slstm_offset: int = 1
    xlstm_chunk: int = 64            # mLSTM chunkwise parallel chunk size

    # numerics / runtime
    dtype: str = "bfloat16"
    decode_cache_layout: str = "s_hkv"  # "s_hkv"=(B,S,Hkv,hd) | "hkv_s"=
    #   (B,Hkv,S,hd) flash-decode layout: contraction-innermost, no transpose
    remat: bool = True
    attn_chunk_q: int = 512          # blocked-attention tile sizes (jnp path)
    attn_chunk_kv: int = 1024
    vocab_chunk: int = 2048          # sequence chunk for chunked xent
    scan_layers: bool = True
    use_pallas: bool = False         # TPU runtime path; dry-run/CPU uses jnp path

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.moe_num_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.mamba_dt_rank == 0:
            object.__setattr__(self, "mamba_dt_rank", -(-self.d_model // 16))

    # -- derived quantities -------------------------------------------------

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    def is_attn_layer(self, l: int) -> bool:
        """Hybrid interleave: which layers are attention (vs Mamba)."""
        if self.family != "hybrid":
            return self.family != "ssm"
        return l % self.attn_period == self.attn_offset

    def is_moe_layer(self, l: int) -> bool:
        if not self.moe_num_experts:
            return False
        return l % self.moe_every == self.moe_offset

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind string, e.g. ('attn+moe', 'mamba+mlp', ...).
        Cached: hot in the scheduler's cost-model inner loop."""
        cached = _KINDS_CACHE.get(self)
        if cached is not None:
            return cached
        kinds = []
        for l in range(self.num_layers):
            if self.family == "ssm":
                mix = "slstm" if (self.xlstm_slstm_every and
                                  l % self.xlstm_slstm_every == self.xlstm_slstm_offset) else "mlstm"
            elif self.family == "hybrid" and not self.is_attn_layer(l):
                mix = "mamba"
            else:
                mix = "attn"
            ffn = "moe" if self.is_moe_layer(l) else ("mlp" if self.d_ff else "none")
            kinds.append(f"{mix}+{ffn}")
        _KINDS_CACHE[self] = tuple(kinds)
        return _KINDS_CACHE[self]

    def param_count(self) -> int:
        """Total parameter count (analytic). Cached."""
        hit = _COUNT_CACHE.get((self, "total"))
        if hit is not None:
            return hit
        d, hd = self.d_model, self.head_dim
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for l in range(self.num_layers):
            kind = self.layer_kinds()[l]
            if "attn" in kind:
                n += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            elif "mamba" in kind:
                di, ds, dtr = self.mamba_d_inner, self.mamba_d_state, self.mamba_dt_rank
                n += d * 2 * di + di * self.mamba_d_conv + di * (dtr + 2 * ds)
                n += dtr * di + di * ds + di + di * d
            elif "slstm" in kind or "mlstm" in kind:
                di = 2 * d
                n += d * di * 2 + di * 3 * (di // 4 if "mlstm" in kind else 1)
                n += di * d
            if "moe" in kind:
                gate_mult = 3 if self.mlp_type in ("silu", "geglu") else 2
                n += d * self.moe_num_experts  # router
                n_exp = self.moe_num_experts + (1 if self.moe_shared_expert else 0)
                n += n_exp * gate_mult * d * self.moe_d_ff
            elif "mlp" in kind:
                gate_mult = 3 if self.mlp_type in ("silu", "geglu") else 2
                n += gate_mult * d * self.d_ff
            n += 2 * d  # norms
        if self.is_encoder_decoder:
            for _ in range(self.num_encoder_layers):
                n += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d  # self
                n += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d  # cross (decoder side counted here)
                gate_mult = 3 if self.mlp_type in ("silu", "geglu") else 2
                n += gate_mult * d * self.d_ff + 2 * d
        _COUNT_CACHE[(self, "total")] = n
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts). Cached."""
        if not self.moe_num_experts:
            return self.param_count()
        hit = _COUNT_CACHE.get((self, "active"))
        if hit is not None:
            return hit
        n = self.param_count()
        gate_mult = 3 if self.mlp_type in ("silu", "geglu") else 2
        per_expert = gate_mult * self.d_model * self.moe_d_ff
        n_moe_layers = sum(self.is_moe_layer(l) for l in range(self.num_layers))
        inactive = n_moe_layers * (self.moe_num_experts - self.moe_top_k) * per_expert
        _COUNT_CACHE[(self, "active")] = n - inactive
        return n - inactive

    def supports_long_context(self) -> bool:
        """Sub-quadratic attention / bounded state -> long_500k runnable."""
        return (self.family in ("ssm", "hybrid")) or self.sliding_window > 0

    def shape_cells(self) -> Tuple[str, ...]:
        cells = ["train_4k", "prefill_32k", "decode_32k"]
        if self.supports_long_context():
            cells.append("long_500k")
        return tuple(cells)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
