"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2 (every other layer), Mamba+attention 1:7
interleave (attention on layers l % 8 == 4, Mamba elsewhere).
Mamba layers keep O(1) decode state -> long_500k runnable; its 4 attention
layers use a sliding-window fallback (4096) for the long_500k cell, noted
in DESIGN.md. [arXiv:2403.19887; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    mlp_type="silu",
    norm_type="rmsnorm",
    rope_theta=0.0,              # jamba uses no positional encoding
    moe_num_experts=16,
    moe_top_k=2,
    moe_every=2,
    moe_offset=1,
    moe_d_ff=14336,
    attn_period=8,
    attn_offset=4,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="jamba-smoke", num_layers=8, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=96, vocab_size=256,
        moe_num_experts=4, moe_top_k=2, moe_d_ff=96,
        attn_period=4, attn_offset=2, mamba_d_state=4, mamba_d_conv=2,
        attn_chunk_q=16, attn_chunk_kv=16, vocab_chunk=32, remat=False)
