"""xlstm-125m [ssm] — 12L d_model=768 4H (kv=4) d_ff=0 vocab=50304.

sLSTM + mLSTM blocks (no separate FFN; blocks carry their own up/down
projections). Ratio ~ xLSTM[7:1]: sLSTM on layers l % 6 == 1 (2 of 12),
mLSTM elsewhere. Fully recurrent decode state -> long_500k runnable.
[arXiv:2405.04517; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,                      # blocks have internal projections
    vocab_size=50304,
    mlp_type="gelu",
    norm_type="layernorm",
    rope_theta=0.0,
    xlstm_slstm_every=6,
    xlstm_slstm_offset=1,
    xlstm_chunk=64,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="xlstm-smoke", num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, head_dim=32, vocab_size=256,
        xlstm_slstm_every=2, xlstm_slstm_offset=1, xlstm_chunk=8,
        vocab_chunk=32, remat=False)
