"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.

[hf:stabilityai/stablelm-2-1_6b; unverified]. StableLM-2 family: LayerNorm,
SwiGLU MLP, partial rotary (25%), MHA (kv=32 == heads).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    mlp_type="silu",
    norm_type="layernorm",
    rotary_pct=0.25,
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="stablelm-3b-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        attn_chunk_q=16, attn_chunk_kv=16, vocab_chunk=32, remat=False)
