"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 experts top-1 + shared expert, interleaved dense/MoE
(every other layer) which lands total params ~400B / active ~17B.
Early-fusion multimodality is out of backbone scope (text path only).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    mlp_type="silu",
    norm_type="rmsnorm",
    rope_theta=500000.0,
    moe_num_experts=128,
    moe_top_k=1,
    moe_every=2,                 # MoE on odd layers, dense FFN on even
    moe_offset=1,
    moe_d_ff=8192,
    moe_shared_expert=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="llama4-maverick-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=96, vocab_size=256,
        moe_num_experts=8, moe_top_k=1, moe_d_ff=96, moe_every=2, moe_offset=1,
        attn_chunk_q=16, attn_chunk_kv=16, vocab_chunk=32, remat=False)
