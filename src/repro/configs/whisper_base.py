"""whisper-base [audio] — 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.

Encoder-decoder; conv frontend is a STUB — input_specs() provides precomputed
frame embeddings (1500 frames, d_model). Decoder: causal self-attn + cross-attn.
The assigned seq_len applies to the DECODER token stream (the transformer
backbone under test); the encoder length is the fixed 1500-frame stub.
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,               # decoder layers
    num_encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    mlp_type="gelu",
    norm_type="layernorm",
    attn_bias=True,
    mlp_bias=True,
    is_encoder_decoder=True,
    encoder_seq=1500,
    rope_theta=0.0,             # whisper uses learned/sinusoidal abs positions
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-base-smoke", num_layers=2, num_encoder_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        vocab_size=256, encoder_seq=24, attn_chunk_q=16, attn_chunk_kv=16,
        vocab_chunk=32, remat=False)
