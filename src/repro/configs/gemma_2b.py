"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.

GeGLU, head_dim=256 (q_dim 2048), MQA, embed scaling, RMSNorm.
[arXiv:2403.08295; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp_type="geglu",
    norm_type="rmsnorm",
    rope_theta=10000.0,
    embed_scale=True,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="gemma-2b-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=1, head_dim=16, d_ff=128, vocab_size=256,
        attn_chunk_q=16, attn_chunk_kv=16, vocab_chunk=32, remat=False)
