from repro.checkpoint.sharded import (load_checkpoint, save_checkpoint,  # noqa: F401
                                      AsyncCheckpointer, latest_step)
