"""Sharded checkpointing: one .npy per pytree leaf + JSON manifest.

Per-host: each process writes only its addressable shards (single-host CPU
writes everything). An async writer thread overlaps serialization with
training (checkpoint/restart is the first line of fault tolerance at
1000-node scale; restore is tested in tests/test_checkpoint.py).

Layout:
  <dir>/step_000120/manifest.json
  <dir>/step_000120/<flat-key>.npy
"""
from __future__ import annotations

import json
import pathlib
import queue
import re
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}[{i}]"))
    else:
        out[prefix] = tree
    return out


def _unflatten_into(spec, flat: Dict[str, Any], prefix=""):
    if isinstance(spec, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}.{k}" if prefix else
                                   str(k)) for k, v in spec.items()}
    if isinstance(spec, tuple):
        return tuple(_unflatten_into(v, flat, f"{prefix}[{i}]")
                     for i, v in enumerate(spec))
    if isinstance(spec, list):
        return [_unflatten_into(v, flat, f"{prefix}[{i}]")
                for i, v in enumerate(spec)]
    return flat[prefix]


def _key_to_fname(key: str) -> str:
    return re.sub(r"[^\w.\-\[\]]", "_", key) + ".npy"


def save_checkpoint(path, tree, step: int, *, extra: Optional[dict] = None):
    d = pathlib.Path(path) / f"step_{step:08d}"
    tmp = d.with_suffix(".tmp")
    tmp.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "keys": {}, "extra": extra or {}}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16:
            arr = arr.view(np.uint16)
            manifest["keys"][key] = {"file": _key_to_fname(key),
                                     "dtype": "bfloat16"}
        else:
            manifest["keys"][key] = {"file": _key_to_fname(key),
                                     "dtype": str(arr.dtype)}
        np.save(tmp / _key_to_fname(key), arr)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if d.exists():
        import shutil
        shutil.rmtree(d)
    tmp.rename(d)  # atomic publish: partial checkpoints never load
    return d


def latest_step(path) -> Optional[int]:
    p = pathlib.Path(path)
    if not p.exists():
        return None
    steps = [int(x.name.split("_")[1]) for x in p.iterdir()
             if x.is_dir() and x.name.startswith("step_")
             and (x / "manifest.json").exists()]
    return max(steps) if steps else None


def load_checkpoint(path, like_tree, step: Optional[int] = None
                    ) -> Tuple[Any, int, dict]:
    """Restore into the structure of `like_tree` (shapes/dtypes verified)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = pathlib.Path(path) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat = {}
    for key, meta in manifest["keys"].items():
        arr = np.load(d / meta["file"])
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16)
        flat[key] = jax.numpy.asarray(arr)
    tree = _unflatten_into(like_tree, flat)
    return tree, manifest["step"], manifest.get("extra", {})


class AsyncCheckpointer:
    """Background writer: save() returns immediately; wait() joins."""

    def __init__(self, path):
        self.path = path
        self._q: "queue.Queue" = queue.Queue()
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()
        self._pending = 0
        self._lock = threading.Lock()

    def _loop(self):
        while True:
            item = self._q.get()
            if item is None:
                break
            tree, step, extra = item
            try:
                save_checkpoint(self.path, tree, step, extra=extra)
            finally:
                with self._lock:
                    self._pending -= 1

    def save(self, tree, step: int, *, extra: Optional[dict] = None):
        # device_get now so the training loop can donate/overwrite buffers
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        with self._lock:
            self._pending += 1
        self._q.put((host_tree, step, extra))

    def wait(self):
        while True:
            with self._lock:
                if self._pending == 0:
                    return
            import time
            time.sleep(0.01)
