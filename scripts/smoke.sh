#!/usr/bin/env bash
# Tier-1 smoke: the repo's canonical test command (see ROADMAP.md), plus —
# when SMOKE_E2E=1 — the open-loop streaming example (paged int4-resident
# decode cache by default) and the serving-API / rescheduling benches
# (both under a timeout), so the request-lifecycle path is exercised end
# to end on every PR. SMOKE_TIER1=0 skips the pytest stage (CI's e2e-bench
# job runs it in the separate tier1 job).
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${SMOKE_TIER1:-1}" == "1" ]]; then
    echo "== invariant lint (repro.analysis, DESIGN.md §9) =="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m repro.analysis lint --strict
    echo "== protocol model check, quick (repro.analysis, DESIGN.md §12) =="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m repro.analysis check --quick
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
fi

if [[ "${SMOKE_E2E:-0}" == "1" ]]; then
    echo "== protocol model check, full depth + mutation harness (§12) =="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 600 \
        python -m repro.analysis check --depth 12 --mutations --replay
    echo "== open-loop streaming serve_e2e (paged KV cache) =="
    timeout 600 python examples/serve_e2e.py \
        --requests 6 --rate 2 --max-new 6
    echo "== serving_api bench (goodput per transport) =="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 600 \
        python -m benchmarks.run --suite serving_api --quick
    test -s BENCH_serving_api.json
    echo "== live reschedule demo (epoch transition on a running gateway) =="
    timeout 600 python examples/reschedule_demo.py --live
    echo "== rescheduling bench (sim + live flip disruption window) =="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 600 \
        python -m benchmarks.run --suite rescheduling --quick
    test -s BENCH_rescheduling.json
    echo "== paged_kv bench (capacity + tok/s vs dense) =="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 600 \
        python -m benchmarks.run --suite paged_kv --quick
    test -s BENCH_paged_kv.json
    echo "== prefix_cache bench (Zipf hit rate, warm TTFT vs no-sharing) =="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 600 \
        python -m benchmarks.run --suite prefix_cache --quick
    test -s BENCH_prefix_cache.json
    echo "== chunked-prefill e2e (token-budget scheduler, sanitizers on) =="
    REPRO_SANITIZE=1 timeout 600 python examples/serve_e2e.py \
        --requests 6 --rate 2 --max-new 6 --chunk-tokens 16
    echo "== continuous_batching bench (chunked vs one-shot TTFT p99) =="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 600 \
        python -m benchmarks.run --suite continuous_batching --quick
    test -s BENCH_continuous_batching.json
    echo "== chaos demo (injected crash + preemption, KV-page migration) =="
    REPRO_SANITIZE=1 timeout 600 python examples/serve_e2e.py \
        --requests 8 --rate 3 --max-new 32 --chaos
    echo "== fault_tolerance bench (SLO attainment vs no-handling) =="
    REPRO_SANITIZE=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 600 \
        python -m benchmarks.run --suite fault_tolerance --quick
    test -s BENCH_fault_tolerance.json
fi
