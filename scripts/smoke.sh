#!/usr/bin/env bash
# Tier-1 smoke: the repo's canonical test command (see ROADMAP.md), plus —
# when SMOKE_E2E=1 — the open-loop streaming example and the serving-API
# goodput bench (both under a timeout), so the request-lifecycle path is
# exercised end to end on every PR.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

if [[ "${SMOKE_E2E:-0}" == "1" ]]; then
    echo "== open-loop streaming serve_e2e =="
    timeout 600 python examples/serve_e2e.py \
        --requests 6 --rate 2 --max-new 6
    echo "== serving_api bench (goodput per transport) =="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 600 \
        python -m benchmarks.run --suite serving_api --quick
    test -s BENCH_serving_api.json
    echo "== live reschedule demo (epoch transition on a running gateway) =="
    timeout 600 python examples/reschedule_demo.py --live
    echo "== rescheduling bench (sim + live flip disruption window) =="
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} timeout 600 \
        python -m benchmarks.run --suite rescheduling --quick
    test -s BENCH_rescheduling.json
fi
