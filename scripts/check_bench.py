#!/usr/bin/env python
"""Bench-regression gate: fail CI when a higher-is-better metric drops vs
the committed baseline (or a lower-is-better metric rises).

Compares every numeric leaf whose key is in ``--metrics`` (dotted path,
found recursively; default ``tokens_per_s``) of a freshly produced
BENCH_*.json against the committed baseline copy of the same file. A leaf
regresses when

    fresh < baseline * (1 - tolerance)        (default tolerance 20%)

Latency-style leaves named in ``--lower-metrics`` gate in the opposite
direction: they regress when

    fresh > baseline * (1 + tolerance)

Leaves present only in the baseline or only in the fresh file are SKIPPED
(new suites and retired metrics don't break the gate), as is a missing
baseline file entirely — the gate only ever compares what both sides have.

Usage (CI snapshots baselines before the bench run overwrites them):

    cp BENCH_throughput.json BENCH_paged_kv.json ci-baselines/
    python -m benchmarks.run --suite throughput ...
    python scripts/check_bench.py --baseline-dir ci-baselines \\
        BENCH_throughput.json BENCH_paged_kv.json [--tolerance 0.2]
    python scripts/check_bench.py --metrics slo_attainment \\
        --baseline-dir ci-baselines BENCH_fault_tolerance.json
    python scripts/check_bench.py --metrics hit_rate \\
        --lower-metrics ttft_p50 --baseline-dir ci-baselines \\
        BENCH_prefix_cache.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_METRICS = "tokens_per_s"


def metric_leaves(obj, metrics, prefix: str = ""):
    """Yield (dotted_path, value) for every numeric leaf keyed in metrics."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            path = f"{prefix}.{k}" if prefix else str(k)
            if k in metrics and isinstance(v, (int, float)) \
                    and not isinstance(v, bool):
                yield path, float(v)
            else:
                yield from metric_leaves(v, metrics, path)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from metric_leaves(v, metrics, f"{prefix}[{i}]")


def check_file(fresh_path: Path, baseline_path: Path, tolerance: float,
               metrics=frozenset((DEFAULT_METRICS,)),
               lower_metrics=frozenset()) -> list:
    """Returns a list of failure strings (empty = pass)."""
    if not baseline_path.exists():
        print(f"  {fresh_path}: no committed baseline "
              f"({baseline_path}) — skipped")
        return []
    if not fresh_path.exists():
        return [f"{fresh_path}: bench output missing (suite did not run?)"]
    allm = frozenset(metrics) | frozenset(lower_metrics)
    fresh = dict(metric_leaves(json.loads(fresh_path.read_text()), allm))
    base = dict(metric_leaves(json.loads(baseline_path.read_text()),
                              allm))
    failures = []
    for path in sorted(base):
        if path not in fresh:
            print(f"  {fresh_path}:{path}: absent in fresh output — skipped")
            continue
        b, f = base[path], fresh[path]
        if b <= 0:
            continue
        leaf = path.rsplit(".", 1)[-1]
        if leaf in lower_metrics:
            rise = f / b - 1.0
            status = "FAIL" if rise > tolerance else "ok"
            print(f"  {fresh_path}:{path}: baseline {b:.3f} -> fresh "
                  f"{f:.3f} ({rise*100:+.1f}%) [lower-is-better {status}]")
            if rise > tolerance:
                failures.append(
                    f"{fresh_path}:{path} rose {rise*100:.1f}% "
                    f"(> {tolerance*100:.0f}% tolerance, lower is better): "
                    f"{b:.3f} -> {f:.3f}")
            continue
        drop = 1.0 - f / b
        status = "FAIL" if drop > tolerance else "ok"
        print(f"  {fresh_path}:{path}: baseline {b:.3f} -> fresh {f:.3f} "
              f"({-drop*100:+.1f}%) [{status}]")
        if drop > tolerance:
            failures.append(
                f"{fresh_path}:{path} dropped {drop*100:.1f}% "
                f"(> {tolerance*100:.0f}% tolerance): "
                f"{b:.3f} -> {f:.3f}")
    for path in sorted(set(fresh) - set(base)):
        print(f"  {fresh_path}:{path}: new metric "
              f"({fresh[path]:.3f}) — no baseline, skipped")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+",
                    help="fresh BENCH_*.json files to gate")
    ap.add_argument("--baseline-dir", default="ci-baselines",
                    help="directory holding the committed baseline copies")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional metric drop (default 0.2)")
    ap.add_argument("--metrics", default=DEFAULT_METRICS,
                    help="comma-separated leaf keys to gate, all "
                         "higher-is-better (default: tokens_per_s)")
    ap.add_argument("--lower-metrics", default="",
                    help="comma-separated leaf keys gated in the opposite "
                         "direction (latency-style, lower is better)")
    args = ap.parse_args()
    metrics = frozenset(m for m in args.metrics.split(",") if m)
    lower = frozenset(m for m in args.lower_metrics.split(",") if m)

    failures = []
    for f in args.files:
        fresh = Path(f)
        failures += check_file(fresh, Path(args.baseline_dir) / fresh.name,
                               args.tolerance, metrics, lower)
    if failures:
        print("\nbench regression gate FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        return 1
    print("\nbench regression gate: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
