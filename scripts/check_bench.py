#!/usr/bin/env python
"""Bench-regression gate: fail CI when tokens/s drops vs the committed
baseline.

Compares every numeric ``tokens_per_s`` leaf (dotted path, found
recursively) of a freshly produced BENCH_*.json against the committed
baseline copy of the same file. A leaf regresses when

    fresh < baseline * (1 - tolerance)        (default tolerance 20%)

Leaves present only in the baseline or only in the fresh file are SKIPPED
(new suites and retired metrics don't break the gate), as is a missing
baseline file entirely — the gate only ever compares what both sides have.

Usage (CI snapshots baselines before the bench run overwrites them):

    cp BENCH_throughput.json BENCH_paged_kv.json ci-baselines/
    python -m benchmarks.run --suite throughput ...
    python scripts/check_bench.py --baseline-dir ci-baselines \\
        BENCH_throughput.json BENCH_paged_kv.json [--tolerance 0.2]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

METRIC_KEY = "tokens_per_s"


def metric_leaves(obj, prefix: str = ""):
    """Yield (dotted_path, value) for every numeric tokens_per_s leaf."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            path = f"{prefix}.{k}" if prefix else str(k)
            if k == METRIC_KEY and isinstance(v, (int, float)):
                yield path, float(v)
            else:
                yield from metric_leaves(v, path)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from metric_leaves(v, f"{prefix}[{i}]")


def check_file(fresh_path: Path, baseline_path: Path,
               tolerance: float) -> list:
    """Returns a list of failure strings (empty = pass)."""
    if not baseline_path.exists():
        print(f"  {fresh_path}: no committed baseline "
              f"({baseline_path}) — skipped")
        return []
    if not fresh_path.exists():
        return [f"{fresh_path}: bench output missing (suite did not run?)"]
    fresh = dict(metric_leaves(json.loads(fresh_path.read_text())))
    base = dict(metric_leaves(json.loads(baseline_path.read_text())))
    failures = []
    for path in sorted(base):
        if path not in fresh:
            print(f"  {fresh_path}:{path}: absent in fresh output — skipped")
            continue
        b, f = base[path], fresh[path]
        if b <= 0:
            continue
        drop = 1.0 - f / b
        status = "FAIL" if drop > tolerance else "ok"
        print(f"  {fresh_path}:{path}: baseline {b:.1f} -> fresh {f:.1f} "
              f"({-drop*100:+.1f}%) [{status}]")
        if drop > tolerance:
            failures.append(
                f"{fresh_path}:{path} dropped {drop*100:.1f}% "
                f"(> {tolerance*100:.0f}% tolerance): "
                f"{b:.1f} -> {f:.1f} tok/s")
    for path in sorted(set(fresh) - set(base)):
        print(f"  {fresh_path}:{path}: new metric "
              f"({fresh[path]:.1f}) — no baseline, skipped")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+",
                    help="fresh BENCH_*.json files to gate")
    ap.add_argument("--baseline-dir", default="ci-baselines",
                    help="directory holding the committed baseline copies")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional tokens/s drop (default 0.2)")
    args = ap.parse_args()

    failures = []
    for f in args.files:
        fresh = Path(f)
        failures += check_file(fresh, Path(args.baseline_dir) / fresh.name,
                               args.tolerance)
    if failures:
        print("\nbench regression gate FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        return 1
    print("\nbench regression gate: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
