"""Data pipeline: determinism, resumability, rank disjointness, learnability."""
import numpy as np

from repro.training.data import DataConfig, PackedLM


def test_deterministic():
    cfg = DataConfig(vocab_size=1024, seq_len=64, global_batch=4, seed=7)
    a = PackedLM(cfg).batch_at(3)
    b = PackedLM(cfg).batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_targets_are_shifted_tokens():
    cfg = DataConfig(vocab_size=1024, seq_len=64, global_batch=2)
    b = PackedLM(cfg).batch_at(0)
    # targets[t] == tokens[t+1] by construction of the packing
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_rank_sharding_disjoint():
    cfg = DataConfig(vocab_size=1024, seq_len=64, global_batch=4)
    r0 = PackedLM(cfg, rank=0, world=2).batch_at(0)
    r1 = PackedLM(cfg, rank=1, world=2).batch_at(0)
    assert r0["tokens"].shape[0] == 2
    assert not np.array_equal(r0["tokens"], r1["tokens"])


def test_resume_state():
    cfg = DataConfig(vocab_size=1024, seq_len=32, global_batch=2)
    d = PackedLM(cfg)
    it = iter(d)
    for _ in range(3):
        next(it)
    st = d.state()
    want = d.batch_at(st["step"])
    d2 = PackedLM(cfg)
    d2.restore(st)
    got = next(iter(d2))
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_token_distribution_not_uniform():
    cfg = DataConfig(vocab_size=512, seq_len=256, global_batch=4)
    b = PackedLM(cfg).batch_at(0)
    counts = np.bincount(b["tokens"].ravel(), minlength=512)
    # zipf-ish: top-16 tokens should dominate
    top = np.sort(counts)[-16:].sum() / counts.sum()
    assert top > 0.25, top
