"""Hypothesis property-based tests on system invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep; skip cleanly if absent
from hypothesis import given, settings, strategies as st

from repro.core import costmodel as cm
from repro.core import parallel as par
from repro.core.cluster import make_paper_cloud
from repro.configs import get_config
from repro.kernels import ref

CLUSTER = make_paper_cloud()
CFG = get_config("llama-30b")


# ---------------------------------------------------------------------------
# int4 quantization properties
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
def test_quant_roundtrip_error_bound(rows, half_g, seed):
    G = 2 * half_g
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, G), jnp.float32)
    x = x * (1 + (seed % 13))
    packed, scale, zero = ref.kv_quant_ref(x)
    back = ref.kv_dequant_ref(packed, scale, zero, dtype=jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert (err <= np.asarray(scale) / 2 + 1e-5).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
def test_quant_idempotent(rows, seed):
    """quant(dequant(quant(x))) == quant(x) (fixed point)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, 16), jnp.float32)
    p1, s1, z1 = ref.kv_quant_ref(x)
    y = ref.kv_dequant_ref(p1, s1, z1, dtype=jnp.float32)
    p2, s2, z2 = ref.kv_quant_ref(y)
    y2 = ref.kv_dequant_ref(p2, s2, z2, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-4)


# ---------------------------------------------------------------------------
# blocked attention == reference for arbitrary shapes
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.sampled_from([1, 2, 4]), st.integers(2, 5),
       st.integers(2, 6), st.sampled_from([8, 16]), st.booleans(),
       st.integers(0, 2 ** 31 - 1))
def test_blocked_attention_matches_ref(B, g, sq8, sk8, hd, causal, seed):
    from repro.models.layers import attention, attention_ref
    Sq, Sk = sq8 * 8, sk8 * 8
    if causal and Sq > Sk:
        Sq = Sk
    Hk = 2
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (B, Sq, Hk * g, hd), jnp.float32)
    k = jax.random.normal(k2, (B, Sk, Hk, hd), jnp.float32)
    v = jax.random.normal(k3, (B, Sk, Hk, hd), jnp.float32)
    qo = jnp.full((B,), Sk - Sq, jnp.int32) if causal else None
    blocked = attention(q, k, v, causal=causal, q_offset=qo,
                        chunk_q=8, chunk_kv=8)
    full = attention_ref(q, k, v, causal=causal, q_offset=qo)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(full),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# scheduler invariants
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.sets(st.integers(0, 31), min_size=4, max_size=16))
def test_pipeline_partition_sums_to_layers(devices):
    devices = sorted(devices)
    for pc in par.enumerate_configs(CLUSTER, CFG, devices):
        assert sum(pc.layer_partition) == CFG.num_layers
        assert all(p >= 1 for p in pc.layer_partition)
        # every stage's layers fit its memory
        per_layer = CFG.param_count() * cm.BYTES / CFG.num_layers
        embed = CFG.vocab_size * CFG.d_model * cm.BYTES
        for s, stage in enumerate(pc.stages):
            mem = sum(CLUSTER.devices[i].chip.hbm_bytes for i in stage) * 0.9
            assert pc.layer_partition[s] * per_layer + embed <= mem * 1.001


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4),
       st.integers(0, 2 ** 31 - 1))
def test_tstp_mass_conservation(m, n, seed):
    from repro.core.orchestrator import solve_tstp
    rng = np.random.default_rng(seed)
    D = rng.random((m, n))
    cap_p = rng.random(m) * 2
    cap_d = rng.random(n) * 2
    o = solve_tstp(D, cap_p, cap_d, rate=1.0)
    assert o.Z.sum() <= 1 + 1e-6
    assert (o.Z >= -1e-9).all()
    assert (o.Z.sum(1) <= np.minimum(cap_p, 1) + 1e-6).all()
    assert (o.Z.sum(0) <= np.minimum(cap_d, 1) + 1e-6).all()
    # objective is optimal for the relaxation: compare against greedy mass
    assert o.attainment <= D.max() * min(1.0, cap_p.sum(), cap_d.sum()) + 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(64, 4096), st.integers(1, 64))
def test_cost_model_latency_monotone_in_tokens(tokens, batch):
    pc = cm.ParallelConfig(tp=2, pp=1, stages=[[8, 9]],
                           layer_partition=[CFG.num_layers])
    t1 = cm.prefill_latency(CLUSTER, CFG, pc, tokens)
    t2 = cm.prefill_latency(CLUSTER, CFG, pc, tokens * 2)
    assert t2 >= t1
    d1 = cm.decode_step_latency(CLUSTER, CFG, pc, batch, 1024)
    d2 = cm.decode_step_latency(CLUSTER, CFG, pc, batch * 2, 1024)
    assert d2 >= d1


@settings(max_examples=10, deadline=None)
@given(st.integers(128, 8192))
def test_kv_transfer_compression_speedup(n_tokens):
    t_raw = cm.kv_transfer_time(CLUSTER, CFG, [0], [4], n_tokens,
                                compress=False)
    t_c = cm.kv_transfer_time(CLUSTER, CFG, [0], [4], n_tokens,
                              compress=True)
    assert t_c < t_raw
