"""End-to-end system behaviour: the full ThunderServe pipeline — schedule on
a heterogeneous cluster, serve real (reduced-config) models through phase
splitting with int4 KV transfer, survive a failure, adapt to a workload
shift via lightweight rescheduling."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, get_reduced
from repro.core import scheduler
from repro.core.cluster import make_paper_cloud
from repro.core.orchestrator import SloSpec
from repro.core.simulator import simulate
from repro.core.workload import CODING, CONVERSATION, generate
from repro.models import build
from repro.serving.engine import DecodeEngine, GenRequest, PrefillEngine
from repro.serving.gateway import Gateway

SLO = SloSpec(ttft_s=2.0, tpot_s=0.15, e2e_s=30.0)


def test_schedule_then_simulate_beats_random_dispatch():
    """Fig. 12's orchestration claim: TSTP routing > random dispatch."""
    cfg = get_config("llama-30b")
    cluster = make_paper_cloud()
    plan = scheduler.schedule(cluster, cfg, CONVERSATION, 2.0, SLO,
                              n_step=12, seed=0, patience=10)
    reqs = generate(CONVERSATION, rate=2.0, duration=40, seed=5)
    res = simulate(cluster, cfg, plan.replicas, plan.orchestration, reqs,
                   SLO)
    res_rand = simulate(cluster, cfg, plan.replicas, None, reqs, SLO)
    assert res.e2e_attain >= res_rand.e2e_attain - 0.05


def test_full_pipeline_real_models():
    """Real computation end-to-end: scheduler-shaped serving topology with
    reduced models; every request completes and produces tokens."""
    cfg = get_reduced("llama-30b")
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    pre = [PrefillEngine(cfg, params, max_seq=64)]
    dec = [DecodeEngine(cfg, params, max_slots=4, max_seq=64),
           DecodeEngine(cfg, params, max_slots=4, max_seq=64)]
    gw = Gateway(pre, dec, backend="ref", compress=True)
    rng = np.random.default_rng(0)
    n = 8
    for rid in range(n):
        gw.submit(GenRequest(
            rid, rng.integers(1, cfg.vocab_size,
                              int(rng.choice([8, 12, 16]))).astype(np.int32),
            max_new_tokens=5))
    done = gw.run_until_drained(max_iters=400)
    assert len(done) == n
    for h in done:
        assert len(h.req.out_tokens) == 5
        assert h.t_done >= h.t_first >= h.t_submit


def test_workload_shift_triggers_lightweight_reschedule():
    cfg = get_config("llama-30b")
    cluster = make_paper_cloud()
    plan = scheduler.schedule(cluster, cfg, CODING, 2.0, SLO, n_step=10,
                              seed=0, patience=8)
    # gateway with a profiler observing a coding->conversation shift
    cfg_small = get_reduced("llama-30b")
    api = build(cfg_small)
    params = api.init(jax.random.PRNGKey(0))
    gw = Gateway([PrefillEngine(cfg_small, params, max_seq=64)],
                 [DecodeEngine(cfg_small, params, max_slots=2, max_seq=64)],
                 orchestration=plan.orchestration, backend="ref")
    for i in range(16):
        gw.profiler.record(1024, 16, t=float(i))
    gw.profiler.set_baseline()
    for i in range(64):
        gw.profiler.record(1024, 140, t=float(16 + i))
    new_plan = gw.maybe_reschedule(cluster, cfg, plan, 2.0, SLO)
    assert new_plan is not None, "shift must trigger rescheduling"
    # conversation-ward shift: decode share must not shrink
    assert len(new_plan.decode_replicas) >= len(plan.decode_replicas)
    assert any("lightweight" in e for e in gw.events)


def test_straggler_routing_reweight():
    cfg = get_reduced("llama-30b")
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    import numpy as np
    from repro.core.orchestrator import Orchestration
    o = Orchestration(X=np.array([1.0]), Y=np.array([[0.5, 0.5]]),
                      Z=np.array([[0.5, 0.5]]), D=np.ones((1, 2)),
                      attainment=1.0, served_frac=1.0)
    gw = Gateway([PrefillEngine(cfg, params, max_seq=64)],
                 [DecodeEngine(cfg, params, max_slots=2, max_seq=64),
                  DecodeEngine(cfg, params, max_slots=2, max_seq=64)],
                 orchestration=o, backend="ref")
    gw.dec[0].ema_latency = 0.01   # fast
    gw.dec[1].ema_latency = 0.10   # straggler
    gw.refresh_routing_from_latency()
    assert o.Y[0, 0] > o.Y[0, 1], "traffic must shift to the fast replica"
