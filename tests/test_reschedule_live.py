"""Live replica re-designation: plan epochs applied to a running Gateway.

Covers the §3.4 closing-the-loop refactor — `plan_diff`/`PlanDelta`,
`LowerLevelSolver.seed`, phase-switchable `Replica`s, and
`Gateway.apply_plan` epoch transitions: in-flight requests on a flipping
decode replica are requeued and complete with zero token loss, routing
dimensions always match the live replica lists, parameters are never
reloaded, node failure composes as drop_nodes -> delta -> apply_plan, and
the profiler-gated `maybe_reschedule` path (observed rate, empty-window
guard). Plus the shed-mass-preserving routing refresh regression."""
import jax
import numpy as np
import pytest

from conftest import admit_one

from repro.configs import get_config, get_reduced
from repro.core import parallel as par
from repro.core import scheduler, tabu
from repro.core.cluster import make_paper_cloud
from repro.core.orchestrator import Orchestration, SloSpec
from repro.core.workload import CONVERSATION
from repro.models import build
from repro.serving.engine import DecodeEngine, PrefillEngine, Replica
from repro.serving.gateway import (DECODING, DONE, QUEUED, Gateway,
                                   ServeRequest, gateway_from_plan)
from repro.serving.transport import SimNetworkTransport

CFG_FULL = get_config("llama-30b")
SLO = SloSpec(ttft_s=2.0, tpot_s=0.15, e2e_s=30.0)

# four groups on the paper cloud, each big enough to hold LLaMA-30B:
# 2x A6000 nodes, the paired A5000 nodes, the A40 node
GROUPS = ((0, 1, 2, 3), (4, 5, 6, 7), tuple(range(8, 16)),
          tuple(range(16, 24)))


@pytest.fixture(scope="module")
def cluster():
    return make_paper_cloud()


@pytest.fixture(scope="module")
def solver(cluster):
    return scheduler.LowerLevelSolver(cluster, CFG_FULL, CONVERSATION, 2.0,
                                      SLO)


def _mk_plan(solver, phases):
    sol = tabu.Solution(GROUPS, tuple(phases))
    score, replicas, o = solver.solve(sol)
    assert replicas, "manual solution must deduce"
    return scheduler.DeploymentPlan(solution=sol, replicas=replicas,
                                    orchestration=o, score=score)


@pytest.fixture(scope="module")
def plan_a(solver):
    return _mk_plan(solver, ("prefill", "prefill", "decode", "decode"))


@pytest.fixture(scope="module")
def plan_b(solver):
    # both decodes flip to prefill and vice versa: every in-flight decode
    # request must cross the requeue path
    return _mk_plan(solver, ("decode", "decode", "prefill", "prefill"))


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced("llama-30b")
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, params


def _prompt(cfg, n=12, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, n).astype(np.int32)


def _routing_dims_match(gw):
    if gw.o is None:
        return True       # uniform alive routing has no dimensions to break
    return (gw.o.X.shape[0] == len(gw.pre)
            and gw.o.Y.shape == (len(gw.pre), len(gw.dec)))


# -- scheduler layer ----------------------------------------------------------


def test_plan_diff_flips_kept_dropped_added(solver, plan_a, plan_b):
    delta = scheduler.plan_diff(plan_a, plan_b)
    assert len(delta.flips) == 4 and not delta.kept
    assert not delta.dropped and not delta.added and not delta.is_noop
    flips = {g: (a, b) for g, a, b in delta.flips}
    assert flips[GROUPS[0]] == ("prefill", "decode")
    assert flips[GROUPS[2]] == ("decode", "prefill")
    # identical plans diff to a no-op
    assert scheduler.plan_diff(plan_a, plan_a).is_noop
    # a shrunk plan reports the missing group as dropped
    shrunk_sol = tabu.Solution(GROUPS[:3], ("prefill", "prefill", "decode"))
    score, reps, o = solver.solve(shrunk_sol)
    shrunk = scheduler.DeploymentPlan(solution=shrunk_sol, replicas=reps,
                                      orchestration=o, score=score)
    d2 = scheduler.plan_diff(plan_a, shrunk)
    assert d2.dropped == [(GROUPS[3], "decode")]
    assert scheduler.plan_diff(shrunk, plan_a).added == \
        [(GROUPS[3], "decode")]


def test_solver_seed_freezes_deductions(cluster, plan_a, monkeypatch):
    s = scheduler.LowerLevelSolver(cluster, CFG_FULL, CONVERSATION, 2.0,
                                   SLO)
    s.seed(plan_a)

    def boom(*a, **kw):
        raise AssertionError("seeded groups must never re-deduce")

    monkeypatch.setattr(par, "deduce", boom)
    for r in plan_a.replicas:
        for ph in ("prefill", "decode"):
            got = s.deduce(tuple(r.devices), ph)
            assert got is not None and got[0] is r.pc


def test_drop_nodes_drops_groups_outright(solver, plan_a, cluster):
    """Regression pin for the intended semantics: a group losing ANY
    device leaves the solution entirely — its surviving devices are NOT
    folded into other groups (that would re-shard resident params)."""
    dead = [GROUPS[2][0]]          # one device of the third group
    shrunk = scheduler.drop_nodes(cluster, plan_a, dead)
    assert shrunk.groups == (GROUPS[0], GROUPS[1], GROUPS[3])
    assert shrunk.phases == ("prefill", "prefill", "decode")
    survivors = set(GROUPS[2]) - set(dead)
    for g in shrunk.groups:
        assert not (set(g) & survivors), \
            "survivors of a dropped group must not be re-absorbed"


# -- engine layer -------------------------------------------------------------


def test_replica_switch_phase_keeps_param_buffers(small_model):
    cfg, params = small_model
    rep = Replica(cfg, params, phase="prefill", max_seq=64,
                  decode_kw=dict(max_slots=2, chunk_size=2))
    leaves_before = [id(x) for x in jax.tree_util.tree_leaves(params)]
    assert isinstance(rep.engine, PrefillEngine)
    rep.switch_phase()
    assert isinstance(rep.engine, DecodeEngine) and rep.phase == "decode"
    assert rep.engine.params is params, "flip must re-use resident params"
    assert [id(x) for x in jax.tree_util.tree_leaves(rep.engine.params)] \
        == leaves_before, "no parameter buffer may be copied or reloaded"
    # flipping back re-enters the cached (warm) engine
    pre = rep._engines["prefill"]
    rep.switch_phase("prefill")
    assert rep.engine is pre and rep.switches == 2


def test_replica_refuses_undrained_flip(small_model):
    cfg, params = small_model
    rep = Replica(cfg, params, phase="decode", max_seq=64,
                  decode_kw=dict(max_slots=2, chunk_size=2))
    from repro.serving.engine import GenRequest
    pre = PrefillEngine(cfg, params, max_seq=64)
    req = GenRequest(0, _prompt(cfg), max_new_tokens=8)
    for r, w, f in pre.run([req], compress=True, backend="ref"):
        assert admit_one(rep.engine, r, f, wire=w, backend="ref")
    assert not rep.drained
    with pytest.raises(RuntimeError, match="undrained"):
        rep.switch_phase()
    rep.engine.release(0)
    assert rep.drained
    rep.switch_phase()          # drained: flip succeeds
    assert rep.phase == "prefill"


# -- gateway layer: epoch transitions ----------------------------------------


def test_apply_plan_epoch_transition_no_token_loss(small_model, plan_a,
                                                   plan_b):
    """The acceptance scenario in miniature: a full phase swap applied to
    a running gateway — in-flight decode requests requeue and complete
    (QUEUED -> ... -> DONE, exact token counts, no duplicate streaming),
    routing dimensions match the live lists at every point, and no
    parameter buffer is reloaded."""
    cfg, params = small_model
    gw = gateway_from_plan(plan_a, cfg, params, max_seq=64, max_slots=4,
                           chunk_size=2, backend="ref")
    assert _routing_dims_match(gw) and gw.epoch == 0
    streamed = {}

    def count(h, tok):
        streamed[h.request.rid] = streamed.get(h.request.rid, 0) + 1

    hs = [gw.submit(ServeRequest(i, _prompt(cfg, 8 + 2 * (i % 3), seed=i),
                                 max_new_tokens=16), on_token=count)
          for i in range(6)]
    while not any(h.state == DECODING for h in hs):
        gw.pump()
    in_flight = [h for h in hs if h.state == DECODING]
    assert in_flight, "need live decode traffic to flip under"
    leaf_ids = {id(x) for x in jax.tree_util.tree_leaves(params)}

    delta = scheduler.plan_diff(plan_a, plan_b)
    n_requeued = gw.apply_plan(delta)

    assert gw.epoch == 1
    assert n_requeued == len(in_flight)
    for h in in_flight:
        assert h.state == QUEUED and h.restarts == 1
        assert [s for _, s in h.history[-2:]] == [DECODING, QUEUED]
    # dimensions + designation reflect the new plan immediately
    assert _routing_dims_match(gw) and gw.o is not None
    assert {h.group for h in gw.pre} == {GROUPS[2], GROUPS[3]}
    assert {h.group for h in gw.dec} == {GROUPS[0], GROUPS[1]}
    for h in gw.pre + gw.dec:
        assert h.client.phase == h.phase
    # the no-reload invariant: every engine still points at the SAME
    # parameter buffers it was constructed with
    for h in gw.pre + gw.dec:
        assert {id(x) for x in
                jax.tree_util.tree_leaves(h.engine.params)} == leaf_ids

    gw.run_until_drained()
    assert all(h.state == DONE and len(h.tokens) == 16 for h in hs), \
        [h.state for h in hs]
    # zero token loss AND zero duplicates through the requeue
    assert streamed == {i: 16 for i in range(6)}
    assert _routing_dims_match(gw)
    assert any(e.startswith("epoch 1:") for e in gw.events)


def test_apply_plan_rejects_added_groups_and_untagged(small_model, plan_a,
                                                      plan_b, solver):
    cfg, params = small_model
    gw = gateway_from_plan(plan_a, cfg, params, max_seq=64, max_slots=2,
                           chunk_size=2, backend="ref")
    grown_sol = tabu.Solution(GROUPS + (tuple(range(24, 32)),),
                              plan_a.solution.phases + ("decode",))
    score, reps, o = solver.solve(grown_sol)
    grown = scheduler.DeploymentPlan(solution=grown_sol, replicas=reps,
                                     orchestration=o, score=score)
    with pytest.raises(ValueError, match="cannot materialize"):
        gw.apply_plan(scheduler.plan_diff(plan_a, grown))
    # plan-less gateways cannot take epochs at all
    pre = PrefillEngine(cfg, params, max_seq=64)
    dec = DecodeEngine(cfg, params, max_slots=2, max_seq=64)
    bare = Gateway([pre], [dec], backend="ref")
    with pytest.raises(ValueError, match="group-tagged"):
        bare.apply_plan(scheduler.plan_diff(plan_a, plan_b))


def test_apply_plan_rebinds_sim_transport(small_model, plan_a, plan_b,
                                          cluster):
    cfg, params = small_model
    tr = SimNetworkTransport.from_plan(cluster, plan_a)
    gw = gateway_from_plan(plan_a, cfg, params, transport=tr, max_seq=64,
                           max_slots=2, chunk_size=2, backend="ref")
    tr.link(0, 0)
    assert tr.pre_devices == [list(r.devices)
                              for r in plan_a.prefill_replicas]
    gw.apply_plan(scheduler.plan_diff(plan_a, plan_b))
    assert tr.pre_devices == [list(r.devices)
                              for r in plan_b.prefill_replicas]
    assert tr.dec_devices == [list(r.devices)
                              for r in plan_b.decode_replicas]
    # the cached alpha-beta entries were dropped with the old epoch's
    # indices; the lazily rebuilt (0,0) link crosses the NEW groups
    assert not tr._links
    _, bw = tr.link(0, 0)
    assert bw == pytest.approx(cluster.min_bw_between(
        plan_b.prefill_replicas[0].devices,
        plan_b.decode_replicas[0].devices))
    gw.run_until_drained()


def test_node_failure_reschedule_mid_trace(small_model, plan_a, cluster,
                                           solver):
    """drop_nodes -> lightweight reschedule -> plan_diff -> apply_plan on
    a gateway with traffic in flight: the dead group's requests requeue,
    the trace finishes on the survivors."""
    cfg, params = small_model
    gw = gateway_from_plan(plan_a, cfg, params, max_seq=64, max_slots=4,
                           chunk_size=2, backend="ref")
    hs = [gw.submit(ServeRequest(i, _prompt(cfg, 10, seed=i),
                                 max_new_tokens=12)) for i in range(5)]
    while not any(h.state == DECODING for h in hs):
        gw.pump()
    dead_group = gw.dec[0].group
    shrunk = scheduler.drop_nodes(cluster, plan_a, list(dead_group))
    new_plan = scheduler.reschedule_lightweight(
        cluster, CFG_FULL, plan_a, CONVERSATION, 2.0, SLO,
        init_solution=shrunk)
    delta = scheduler.plan_diff(plan_a, new_plan)
    assert any(g == dead_group for g, _ in delta.dropped)
    gw.apply_plan(delta)
    assert gw.epoch == 1 and _routing_dims_match(gw)
    assert all(h.group != dead_group for h in gw.pre + gw.dec)
    assert len(gw.pre) + len(gw.dec) == 3
    gw.run_until_drained()
    assert all(h.state == DONE and len(h.tokens) == 12 for h in hs), \
        [h.state for h in hs]


def test_live_workload_shift_end_to_end(small_model, plan_a, plan_b,
                                        cluster):
    """Acceptance: a workload-shift trace through the real Gateway
    triggers shift detection; `maybe_reschedule` applies a phase-flip
    plan to the running service with no restart, no parameter reload,
    and zero dropped in-flight requests."""
    cfg, params = small_model
    gw = gateway_from_plan(plan_a, cfg, params, max_seq=64, max_slots=4,
                           chunk_size=2, backend="ref")
    leaf_ids = {id(x) for x in jax.tree_util.tree_leaves(params)}
    # phase 1: short-output traffic -> baseline
    first = [gw.submit(ServeRequest(i, _prompt(cfg, 10, seed=i),
                                    max_new_tokens=3)) for i in range(10)]
    gw.run_until_drained()
    assert all(h.state == DONE for h in first)
    gw.profiler.set_baseline()
    assert not gw.profiler.shift_detected()
    # phase 2: long-output traffic; keep some of it in flight
    second = [gw.submit(ServeRequest(100 + i, _prompt(cfg, 10, seed=i),
                                     max_new_tokens=12)) for i in range(8)]
    while sum(h.state == DONE for h in second) < 4:
        gw.pump()
    third = [gw.submit(ServeRequest(200 + i, _prompt(cfg, 10, seed=i),
                                    max_new_tokens=12)) for i in range(4)]
    assert gw.profiler.shift_detected(), "mean_out 3 -> 12 must register"

    new_plan = gw.maybe_reschedule(
        cluster, CFG_FULL, rate=2.0, slo=SLO,
        search_fn=lambda *a, **kw: plan_b)
    assert new_plan is plan_b and gw.epoch == 1
    assert _routing_dims_match(gw)
    assert {h.group for h in gw.dec} == {GROUPS[0], GROUPS[1]}
    for h in gw.pre + gw.dec:       # no reload, no restart
        assert {id(x) for x in
                jax.tree_util.tree_leaves(h.engine.params)} == leaf_ids
    gw.run_until_drained()
    done = [h for h in first + second + third]
    assert all(h.state == DONE for h in done), [h.state for h in done]
    assert all(len(h.tokens) == 12 for h in second + third)
    assert any("lightweight rescheduling" in e for e in gw.events)


# -- satellite regressions ----------------------------------------------------


def test_maybe_reschedule_survives_empty_profiler(small_model, plan_a,
                                                  cluster):
    """A shift signal with fewer than 8 window records must be a no-op,
    not a crash (`as_workload()` returns None)."""
    cfg, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    dec = DecodeEngine(cfg, params, max_slots=2, max_seq=64)
    gw = Gateway([pre], [dec], backend="ref")
    gw.profiler.shift_detected = lambda: True          # force the signal
    assert gw.profiler.as_workload() is None
    out = gw.maybe_reschedule(cluster, CFG_FULL, plan_a, 2.0, SLO)
    assert out is None and gw.epoch == 0


def test_maybe_reschedule_uses_observed_rate(small_model, plan_a, cluster):
    cfg, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    dec = DecodeEngine(cfg, params, max_slots=2, max_seq=64)
    gw = Gateway([pre], [dec], backend="ref")
    for i in range(10):
        gw.profiler.record(1024, 16, t=float(i))
    gw.profiler.set_baseline()
    for i in range(30):
        gw.profiler.record(1024, 140, t=float(10 + i))
    seen = {}

    def capture(cluster_, cfg_, plan_, wl_, rate_, slo_):
        seen["rate"], seen["wl"] = rate_, wl_
        return plan_

    stale_rate = 99.0
    out = gw.maybe_reschedule(cluster, CFG_FULL, plan_a, stale_rate, SLO,
                              search_fn=capture)
    assert out is plan_a
    observed = 40 / 39.0            # 40 records over 39 seconds
    assert seen["rate"] == pytest.approx(observed, rel=0.01)
    assert seen["rate"] != stale_rate
    assert seen["wl"].mean_out > 100        # the observed window, not stale


def test_refresh_routing_preserves_shed_mass(small_model):
    """When the TSTP shed mass (X.sum() < 1), the latency reweight must
    NOT renormalize the unserved mass back onto saturated replicas."""
    cfg, params = small_model

    class _Dummy:                   # routing-only: never pumped
        pass

    o = Orchestration(X=np.array([0.45, 0.15]),
                      Y=np.array([[0.7, 0.3], [0.5, 0.5]]),
                      Z=np.zeros((2, 2)), D=np.ones((2, 2)),
                      attainment=0.6, served_frac=0.6)
    gw = Gateway([_Dummy(), _Dummy()], [_Dummy(), _Dummy()],
                 orchestration=o, backend="ref")
    gw.pre[0].ema_latency = 0.01
    gw.pre[1].ema_latency = 0.10    # straggler
    gw.dec[0].ema_latency = 0.02
    gw.dec[1].ema_latency = 0.20    # straggler
    gw.refresh_routing_from_latency()
    assert o.X.sum() == pytest.approx(0.60), \
        "shed mass must stay shed after the reweight"
    assert o.X[0] > o.X[1], "traffic must shift toward the fast prefill"
    for i in range(2):
        assert o.Y[i].sum() == pytest.approx(1.0)
    assert o.Y[0, 0] > 0.7, "decode mass must shift toward the fast replica"


def test_gateway_from_plan_binds_groups(small_model, plan_a):
    cfg, params = small_model
    gw = gateway_from_plan(plan_a, cfg, params, max_seq=64, max_slots=2,
                           chunk_size=2, backend="ref")
    assert [h.group for h in gw.pre] == [GROUPS[0], GROUPS[1]]
    assert [h.group for h in gw.dec] == [GROUPS[2], GROUPS[3]]
    assert all(h.switchable for h in gw.pre + gw.dec)
    assert gw.plan is plan_a and gw.o is plan_a.orchestration
