"""Event-simulator sanity: SLO-scale monotonicity, load degradation,
compression benefit, colocation interference."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import scheduler
from repro.core.cluster import make_paper_cloud
from repro.core.orchestrator import SloSpec
from repro.core.simulator import min_slo_scale_for, simulate
from repro.core.workload import CODING, CONVERSATION, generate

CFG = get_config("llama-30b")
CLUSTER = make_paper_cloud()
SLO = SloSpec(ttft_s=2.0, tpot_s=0.15, e2e_s=30.0)


@pytest.fixture(scope="module")
def plan():
    return scheduler.schedule(CLUSTER, CFG, CONVERSATION, 2.0, SLO,
                              n_step=10, seed=0, patience=8)


def test_slo_scale_monotone(plan):
    reqs = generate(CONVERSATION, rate=2.0, duration=40, seed=0)
    prev = -1.0
    for scale in (0.25, 1.0, 4.0):
        res = simulate(CLUSTER, CFG, plan.replicas, plan.orchestration,
                       reqs, SLO.scaled(scale))
        assert res.e2e_attain >= prev - 1e-9
        prev = res.e2e_attain


def test_every_request_finishes(plan):
    reqs = generate(CONVERSATION, rate=2.0, duration=30, seed=1)
    res = simulate(CLUSTER, CFG, plan.replicas, plan.orchestration, reqs,
                   SLO)
    assert len(res.requests) == len(reqs)
    assert all(r.t_done >= r.t_first_token >= r.t_arrive - 1e-9
               for r in res.requests)


def test_overload_degrades_latency(plan):
    lo = generate(CONVERSATION, rate=1.0, duration=40, seed=2)
    hi = generate(CONVERSATION, rate=16.0, duration=40, seed=2)
    r_lo = simulate(CLUSTER, CFG, plan.replicas, plan.orchestration, lo, SLO)
    r_hi = simulate(CLUSTER, CFG, plan.replicas, plan.orchestration, hi, SLO)
    assert r_hi.p99_e2e >= r_lo.p99_e2e


def test_compression_reduces_kv_time_fraction(plan):
    reqs = generate(CONVERSATION, rate=2.0, duration=40, seed=3)
    r_raw = simulate(CLUSTER, CFG, plan.replicas, plan.orchestration, reqs,
                     SLO, compress=False)
    r_c = simulate(CLUSTER, CFG, plan.replicas, plan.orchestration, reqs,
                   SLO, compress=True)
    assert r_c.kv_comm_frac < r_raw.kv_comm_frac


def test_min_slo_scale_finite(plan):
    reqs = generate(CONVERSATION, rate=1.0, duration=30, seed=4)
    s = min_slo_scale_for(CLUSTER, CFG, plan.replicas, plan.orchestration,
                          reqs, SLO, target=0.5)
    assert s < float("inf")
