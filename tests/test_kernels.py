"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


FLASH_CASES = [
    # (BH, BHkv, Sq, Sk, hd, causal, window, dtype)
    (4, 4, 128, 128, 64, True, 0, jnp.float32),
    (8, 2, 256, 256, 64, True, 0, jnp.float32),     # GQA group=4
    (8, 1, 128, 128, 32, True, 0, jnp.bfloat16),    # MQA
    (4, 4, 128, 256, 64, False, 0, jnp.float32),    # cross-ish, non-causal
    (4, 2, 256, 256, 128, True, 64, jnp.float32),   # sliding window
    (2, 2, 384, 384, 64, True, 128, jnp.bfloat16),  # SWA bf16
]


@pytest.mark.parametrize("BH,BHkv,Sq,Sk,hd,causal,win,dtype", FLASH_CASES)
def test_flash_attention_matches_ref(BH, BHkv, Sq, Sk, hd, causal, win,
                                     dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (BH, Sq, hd), dtype)
    k = _rand(k2, (BHkv, Sk, hd), dtype)
    v = _rand(k3, (BHkv, Sk, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=win,
                              backend="interpret", block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=win)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,Hkv,g,S,hd,dtype", [
    (4, 2, 4, 512, 64, jnp.float32),
    (2, 1, 8, 256, 128, jnp.bfloat16),   # MQA-style decode
    (3, 4, 1, 384, 64, jnp.float32),     # MHA decode (g=1)
])
def test_decode_attention_matches_ref(B, Hkv, g, S, hd, dtype):
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    q = _rand(k1, (B, Hkv, g, hd), dtype)
    k = _rand(k2, (B, Hkv, S, hd), dtype)
    v = _rand(k3, (B, Hkv, S, hd), dtype)
    kv_len = jax.random.randint(k4, (B,), 1, S + 1)
    out = ops.decode_attention(q, k, v, kv_len, backend="interpret",
                               block_k=128)
    want = ref.decode_attention_ref(q, k, v, kv_len=kv_len)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("N,G,dtype", [
    (256, 128, jnp.bfloat16),
    (512, 64, jnp.float32),
    (1024, 256, jnp.bfloat16),
])
def test_kv_quant_roundtrip_error_bound(N, G, dtype):
    x = _rand(KEY, (N, G), dtype)
    packed, scale, zero = ops.kv_quant(x, backend="interpret")
    back = ops.kv_dequant(packed, scale, zero, backend="interpret",
                          out_dtype=jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(x, np.float32))
    # affine int4: |err| <= scale/2 per element (+ eps for bf16 rounding)
    bound = np.asarray(scale) / 2 + 1e-2
    assert (err <= bound).all(), (err.max(), bound.min())


def test_kv_quant_matches_ref_packing():
    x = _rand(KEY, (256, 128), jnp.bfloat16)
    p1, s1, z1 = ops.kv_quant(x, backend="interpret")
    p2, s2, z2 = ref.kv_quant_ref(x)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=1e-6)
    # packed nibbles may differ by 1 on exact rounding knife-edges (reduction
    # order); dequantized values must agree within one quantization step
    d1 = ref.kv_dequant_ref(p1, s1, z1, dtype=jnp.float32)
    d2 = ref.kv_dequant_ref(p2, s2, z2, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               atol=float(np.asarray(s1).max()) + 1e-6)


@pytest.mark.parametrize("N", [300, 17, 257])
def test_kv_quant_ragged_rows(N):
    """Row counts that do NOT divide the block: the ceil-div grid pads the
    tail block on load and clips it on store; results must match the
    reference exactly where it matters (per-row independence)."""
    x = _rand(KEY, (N, 64), jnp.bfloat16)
    p1, s1, z1 = ops.kv_quant(x, backend="interpret", block_n=128)
    p2, s2, z2 = ref.kv_quant_ref(x)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=1e-6)
    back = ops.kv_dequant(p1, s1, z1, backend="interpret",
                          out_dtype=jnp.float32, block_n=128)
    err = np.abs(np.asarray(back) - np.asarray(x, np.float32))
    assert (err <= np.asarray(s1) / 2 + 1e-2).all()


def test_kv_quant_compression_ratio():
    x = _rand(KEY, (512, 128), jnp.bfloat16)
    packed, scale, zero = ops.kv_quant(x, backend="interpret")
    wire = packed.nbytes + scale.nbytes + zero.nbytes
    orig = x.size * 2
    assert wire / orig < 0.30, wire / orig  # paper: ~4x shrink


@pytest.mark.parametrize("N,d", [(256, 128), (512, 512)])
def test_rmsnorm_matches_ref(N, d):
    x = _rand(KEY, (N, d), jnp.bfloat16)
    s = jax.random.normal(KEY, (d,), jnp.float32) * 0.2
    out = ops.rmsnorm(x, s, backend="interpret")
    want = ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=2e-2)
