import os
import sys

# NOTE: deliberately NO XLA_FLAGS here — tests must see the real (1-device)
# CPU topology; only launch/dryrun.py forces 512 host devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
