import os
import sys

# NOTE: deliberately NO XLA_FLAGS here — tests must see the real (1-device)
# CPU topology; only launch/dryrun.py forces 512 host devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def admit_one(eng, req, token, *, wire=None, pages=None, source=None,
              backend="auto"):
    """Admit a single request through the unified ``admit(AdmissionBatch)``
    entry point; True when it was placed (the rejected tail is empty)."""
    from repro.serving.engine import (ADMIT_FRESH, AdmissionBatch,
                                      AdmissionItem)
    item = AdmissionItem(req, token, source or ADMIT_FRESH, wire=wire,
                         pages=pages)
    return not eng.admit(AdmissionBatch([item]), backend=backend)


def admit_many(eng, triples, *, backend="auto"):
    """Admit ``(req, wire, first_token)`` triples (a prefill result list)
    as one FIFO batch; returns the rejected tail ``AdmissionBatch``."""
    from repro.serving.engine import AdmissionBatch, AdmissionItem
    return eng.admit(AdmissionBatch(
        [AdmissionItem(r, f, wire=w) for r, w, f in triples]),
        backend=backend)
