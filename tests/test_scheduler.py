"""Scheduler unit + property tests: tabu moves preserve the device
partition, search improves over init, TSTP respects simplex/capacity
constraints, lightweight rescheduling freezes groups & parallel configs."""
import random

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import costmodel as cm
from repro.core import orchestrator as orch
from repro.core import parallel as par
from repro.core import scheduler, tabu
from repro.core.cluster import make_paper_cloud, make_tpu_fleet
from repro.core.orchestrator import SloSpec
from repro.core.workload import CODING, CONVERSATION

CFG = get_config("llama-30b")
CLUSTER = make_paper_cloud()
SLO = SloSpec(ttft_s=2.0, tpot_s=0.15, e2e_s=30.0)


def _check_partition(sol, n):
    devs = [i for g in sol.groups for i in g]
    assert sorted(devs) == list(range(n)), "groups must partition devices"


def test_initial_solution_feasible_partition():
    sol = tabu.initial_solution(CLUSTER, CFG, random.Random(0))
    _check_partition(sol, CLUSTER.n)
    assert tabu.feasible(CLUSTER, CFG, sol) or len(sol.groups) == 1


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_neighbor_moves_preserve_partition(seed):
    rng = random.Random(seed)
    sol = tabu.initial_solution(CLUSTER, CFG, rng)
    for _ in range(30):
        nbrs = tabu.neighbors(CLUSTER, CFG, sol, 5, rng)
        for nb in nbrs:
            _check_partition(nb, CLUSTER.n)
        if nbrs:
            sol = nbrs[0]


def test_group_memory_feasibility_pruning():
    # a single 3090Ti (24 GB) cannot hold LLaMA-30B (~65 GB bf16)
    small = tabu.Solution(((24,), tuple(range(24)) + tuple(range(25, 32))),
                          ("prefill", "decode"))
    assert not tabu.feasible(CLUSTER, CFG, small)


def test_tabu_improves_over_init():
    solver = scheduler.LowerLevelSolver(CLUSTER, CFG, CODING, 2.0, SLO)
    rng = random.Random(0)
    init = tabu.initial_solution(CLUSTER, CFG, rng)
    init_score = solver.score(init)
    res = tabu.tabu_search(CLUSTER, CFG, solver.score, n_step=15, seed=0,
                           patience=50)
    assert res.best_score >= init_score
    assert res.history == sorted(res.history), "best score is monotone"


def test_parallel_config_covers_layers_and_memory():
    group = list(range(8, 16))  # the 8xA40 node
    for pc in par.enumerate_configs(CLUSTER, CFG, group):
        assert sum(pc.layer_partition) == CFG.num_layers
        assert len(pc.stages) == pc.pp
        assert all(len(s) == pc.tp for s in pc.stages)
        flat = sorted(i for s in pc.stages for i in s)
        assert flat == sorted(group)


def test_tp_never_spans_nodes():
    group = [0, 1, 2, 3, 8, 9, 10, 11]  # A6000 node + half the A40 node
    for pc in par.enumerate_configs(CLUSTER, CFG, group):
        for stage in pc.stages:
            nodes = {CLUSTER.devices[i].node for i in stage}
            assert len(nodes) == 1, "TP must stay within one node"


def test_stage_routing_dp_maximizes_bottleneck():
    group = list(range(0, 8))  # two nodes
    got = par.deduce(CLUSTER, CFG, group, "prefill")
    assert got is not None
    pc, rc = got
    assert rc.prefill_latency_1k > 0


def test_tstp_simplex_and_capacity():
    D = np.array([[0.9, 0.5], [0.4, 0.8]])
    cap_p = np.array([1.0, 1.0])
    cap_d = np.array([0.6, 0.6])
    o = orch.solve_tstp(D, cap_p, cap_d, rate=1.0)
    assert o.Z.sum() <= 1.0 + 1e-6
    assert (o.Z >= -1e-9).all()
    assert (o.Z.sum(axis=1) <= cap_p + 1e-6).all()
    assert (o.Z.sum(axis=0) <= cap_d + 1e-6).all()
    # prefers the high-attainment pairs
    assert o.attainment >= 0.8 * min(1.0, cap_d.sum())
    # Y rows are distributions where X > 0
    for i in range(2):
        if o.X[i] > 1e-9:
            assert abs(o.Y[i].sum() - 1.0) < 1e-6


def test_schedule_end_to_end_scores_positive():
    plan = scheduler.schedule(CLUSTER, CFG, CODING, 2.0, SLO, n_step=8,
                              seed=0, patience=8)
    assert plan.score > 0
    assert plan.prefill_replicas and plan.decode_replicas
    _check_partition(plan.solution, CLUSTER.n)


def test_lightweight_rescheduling_freezes_groups():
    plan = scheduler.schedule(CLUSTER, CFG, CODING, 2.0, SLO, n_step=8,
                              seed=0, patience=8)
    plan2 = scheduler.reschedule_lightweight(CLUSTER, CFG, plan,
                                             CONVERSATION, 2.0, SLO)
    assert sorted(map(tuple, plan2.solution.groups)) == \
        sorted(map(tuple, plan.solution.groups)), \
        "lightweight rescheduling must not change group construction"
    # parallel configs frozen per group
    pc_by_group = {tuple(r.devices): r.pc.describe() for r in plan.replicas}
    for r in plan2.replicas:
        assert pc_by_group[tuple(r.devices)] == r.pc.describe()


def test_drop_nodes_removes_affected_groups():
    plan = scheduler.schedule(CLUSTER, CFG, CODING, 2.0, SLO, n_step=8,
                              seed=0, patience=8)
    dead = [d.idx for d in CLUSTER.devices if d.node == 0]
    shrunk = scheduler.drop_nodes(CLUSTER, plan, dead)
    for g in shrunk.groups:
        assert not (set(g) & set(dead))


def test_scheduler_works_on_tpu_fleet():
    cluster = make_tpu_fleet()
    plan = scheduler.schedule(cluster, CFG, CONVERSATION, 2.0, SLO,
                              n_step=8, seed=0, patience=8)
    assert plan.score > 0


def test_coding_prefers_more_prefill_than_conversation():
    """Paper Fig. 6/Table 3: coding (short outputs) gets a higher
    prefill:decode ratio than conversation (long outputs)."""
    p_cod = scheduler.schedule(CLUSTER, CFG, CODING, 2.0, SLO, n_step=20,
                               seed=0)
    p_con = scheduler.schedule(CLUSTER, CFG, CONVERSATION, 2.0, SLO,
                               n_step=20, seed=0)
    r_cod = len(p_cod.prefill_replicas) / max(len(p_cod.decode_replicas), 1)
    r_con = len(p_con.prefill_replicas) / max(len(p_con.decode_replicas), 1)
    assert r_cod >= r_con
