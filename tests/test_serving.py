"""Serving runtime integration: KV-transfer roundtrip, continuous batching
invariants, gateway end-to-end with failure injection, profiler shifts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import admit_one

from repro.configs import get_reduced
from repro.models import build, transformer
from repro.serving import kv_transfer
from repro.serving.engine import DecodeEngine, GenRequest, PrefillEngine
from repro.serving.gateway import Gateway
from repro.serving.profiler import WorkloadProfiler

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced("llama-30b")
    api = build(cfg)
    params = api.init(KEY)
    return cfg, api, params


def test_kv_wire_roundtrip_error_bounded(small_model):
    cfg, api, params = small_model
    tokens = jax.random.randint(KEY, (2, 24), 0, cfg.vocab_size)
    _, cache = api.prefill(params, {"tokens": tokens}, max_seq=32)
    wire = kv_transfer.extract(cache, 0, 24, compress=True, backend="ref")
    dec_cache = transformer.init_cache(cfg, 4, 32)
    dec_cache = kv_transfer.insert(dec_cache, wire, 2, backend="ref")
    k_src = np.asarray(cache["slot0"]["k"][:, 0, :24], np.float32)
    k_dst = np.asarray(dec_cache["slot0"]["k"][:, 2, :24], np.float32)
    # int4 groupwise: error bounded by step size ~ range/15
    rng = np.abs(k_src).max()
    assert np.abs(k_src - k_dst).max() <= rng / 15 * 1.1 + 1e-3
    assert int(dec_cache["lengths"][2]) == 24


def test_kv_wire_compression_ratio(small_model):
    cfg, api, params = small_model
    tokens = jax.random.randint(KEY, (1, 32), 0, cfg.vocab_size)
    _, cache = api.prefill(params, {"tokens": tokens}, max_seq=40)
    wire = kv_transfer.extract(cache, 0, 32, compress=True, backend="ref")
    ratio = wire.nbytes() / kv_transfer.wire_bytes_uncompressed(wire)
    assert ratio < 0.35, ratio  # ~4x shrink (paper §4)


def test_recurrent_state_transfer_roundtrip():
    """Beyond-paper: SSM/hybrid archs transfer recurrent state snapshots."""
    cfg = get_reduced("xlstm-125m")
    api = build(cfg)
    params = api.init(KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    _, cache = api.prefill(params, {"tokens": tokens}, max_seq=32)
    wire = kv_transfer.extract(cache, 1, 16, compress=True, backend="ref")
    dec_cache = transformer.init_cache(cfg, 2, 32)
    dec_cache = kv_transfer.insert(dec_cache, wire, 0, backend="ref")
    c_src = np.asarray(cache["slot0"]["C"][:, 1], np.float32)
    c_dst = np.asarray(dec_cache["slot0"]["C"][:, 0], np.float32)
    np.testing.assert_allclose(c_src, c_dst, rtol=1e-6)


def test_continuous_batching_slots(small_model):
    cfg, api, params = small_model
    eng = DecodeEngine(cfg, params, max_slots=2, max_seq=64)
    pre = PrefillEngine(cfg, params, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [GenRequest(i, rng.integers(1, cfg.vocab_size, 8).astype(np.int32),
                       max_new_tokens=4) for i in range(3)]
    results = pre.run(reqs, backend="ref")
    admitted = 0
    for r, w, f in results:
        if admit_one(eng, r, f, wire=w, backend="ref"):
            admitted += 1
    assert admitted == 2, "third request must wait for a free slot"
    done = []
    while eng.active:
        done += eng.step()
    assert len(done) == 2
    # now the third fits
    r, w, f = results[2]
    assert admit_one(eng, r, f, wire=w, backend="ref")


def test_gateway_failure_injection_finishes_all(small_model):
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    decs = [DecodeEngine(cfg, params, max_slots=4, max_seq=64)
            for _ in range(2)]
    gw = Gateway([pre], decs, backend="ref")
    rng = np.random.default_rng(1)
    for rid in range(6):
        gw.submit(GenRequest(
            rid, rng.integers(1, cfg.vocab_size, 12).astype(np.int32),
            max_new_tokens=4))
    gw.pump()
    gw.kill_replica("decode", 1)  # mid-flight failure
    done = gw.run_until_drained(max_iters=300)
    assert len(done) == 6, "all requests must finish despite the failure"
    assert any("killed" in e for e in gw.events)


def test_profiler_shift_detection():
    prof = WorkloadProfiler(window=64, shift_threshold=0.4)
    for i in range(32):
        prof.record(1024, 16, t=float(i))
    prof.set_baseline()
    assert not prof.shift_detected()
    for i in range(64):
        prof.record(1024, 129, t=float(32 + i))
    assert prof.shift_detected()
    wl = prof.as_workload()
    assert wl.mean_out > 100
