"""HLO cost walker: trip-count multiplication validated against XLA's own
cost_analysis on equivalent scanned vs unrolled modules, plus collective
parsing on explicit psum programs."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.roofline import hlo_cost


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_trip_count_multiplies_flops():
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = lax.scan(body, x, None, length=12)
        return y

    def unrolled(x, w):
        for _ in range(12):
            x = jnp.tanh(x @ w)
        return x

    cs = hlo_cost.analyze_text(_compile(scanned, x, w).as_text())
    cu = hlo_cost.analyze_text(_compile(unrolled, x, w).as_text())
    assert cs.flops > 0
    np.testing.assert_allclose(cs.flops, cu.flops, rtol=0.05)
    # 12 matmuls of 2*8*64*64
    np.testing.assert_allclose(cs.flops, 12 * 2 * 8 * 64 * 64, rtol=0.05)


def test_flops_match_xla_on_unrolled():
    a = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 96), jnp.float32)

    def f(a, b):
        return jax.nn.relu(a @ b) @ b.T

    compiled = _compile(f, a, b)
    c = hlo_cost.analyze_text(compiled.as_text())
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    np.testing.assert_allclose(c.flops, float(ca["flops"]), rtol=0.1)


def test_nested_scan_trip_counts_compose():
    x = jax.ShapeDtypeStruct((4, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = lax.scan(inner, c, None, length=5)
            return ci, None
        y, _ = lax.scan(outer, x, None, length=3)
        return y

    c = hlo_cost.analyze_text(_compile(f, x, w).as_text())
    np.testing.assert_allclose(c.flops, 15 * 2 * 4 * 32 * 32, rtol=0.05)


def test_collective_parse_psum():
    import os
    import subprocess
    import sys
    # needs >1 device: run in a subprocess with forced host devices
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import sys
sys.path.insert(0, "src")
from repro.roofline import hlo_cost

mesh = jax.make_mesh((4,), ("d",))
x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
xs = NamedSharding(mesh, P(None, "d"))
ws = NamedSharding(mesh, P("d", None))
def f(x, w):
    return x @ w  # contracting dim sharded -> all-reduce
compiled = jax.jit(f, in_shardings=(xs, ws)).lower(x, w).compile()
c = hlo_cost.analyze_text(compiled.as_text(), default_group=4)
assert c.coll_counts.get("all-reduce", 0) >= 1, c.coll_counts
assert c.wire > 0
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "OK" in out.stdout, out.stderr[-2000:]


def test_dus_counted_in_place():
    """dynamic-update-slice must not charge the full target buffer."""
    big = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    small = jax.ShapeDtypeStruct((1, 1024), jnp.float32)

    def f(big, small):
        return lax.dynamic_update_slice(big, small, (3, 0))

    compiled = jax.jit(f, donate_argnums=(0,)).lower(big, small).compile()
    c = hlo_cost.analyze_text(compiled.as_text())
    # in-place: ~2x the update (read+write), far below the 4MB buffer
    assert c.bytes < 1024 * 1024 * 4 * 0.5, c.bytes
