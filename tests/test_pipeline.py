"""shard_map pipeline parallelism: pipelined == sequential stage apply."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ("pod",))
S, d, B = 4, 16, 8
keys = jax.random.split(jax.random.PRNGKey(0), S)
W = jnp.stack([jax.random.normal(k, (d, d)) / jnp.sqrt(d) for k in keys])
b = jnp.stack([jax.random.normal(k, (d,)) * 0.1 for k in keys])
params = {"w": W, "b": b}
x = jax.random.normal(jax.random.PRNGKey(9), (B, d))

def stage(p, t):
    return jnp.tanh(t @ p["w"] + p["b"])

y_pipe = pipeline_apply(stage, params, x, mesh=mesh, axis="pod")

# sequential reference: microbatch groups pass through all 4 stages, and the
# loop-pipeline leaves group g's output on rank (g + S) % S = g -> order kept
y_ref = x
for s in range(S):
    p = jax.tree.map(lambda a: a[s], params)
    y_ref = stage(p, y_ref)
print("shape", y_pipe.shape)
np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref),
                           atol=2e-5, rtol=2e-5)
print("PIPE OK")
"""


def test_pipeline_matches_sequential():
    out = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                         text=True, cwd=ROOT, timeout=600)
    assert "PIPE OK" in out.stdout, (out.stdout[-800:], out.stderr[-2500:])
