"""Fault injection + recovery (ISSUE 6 / DESIGN.md §8).

Covers the chaos layer end to end: seeded reproducible schedules, the
virtual clock, bounded retry with backoff falling back to
requeue-through-prefill, the status-based failure detector (suspected
vs confirmed-dead, heartbeat and latency sources, probe recovery),
page-exact accounting across every requeue path, spot-preemption drains
that migrate decode KV page-granular with ZERO token loss (bit-identical
to an uninterrupted run), the FAILED/REJECTED reason contract from
every non-terminal state, `Gateway.stats()`, and death composing into a
failover reschedule that excludes the dead node's devices.
"""
import time
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.configs import get_config, get_reduced
from repro.core import scheduler, tabu
from repro.core.cluster import make_paper_cloud
from repro.core.orchestrator import SloSpec
from repro.core.workload import CONVERSATION
from repro.models import build
from repro.serving.engine import DecodeEngine, GenRequest, PrefillEngine
from repro.serving.faults import (CRASH, PREEMPT, STRAGGLER, TRANSIENT,
                                  ChaosClient, ChaosTransport, FaultEvent,
                                  FaultSchedule, ReplicaCrashError,
                                  RetryPolicy, TransientTransportError,
                                  VirtualClock, install_chaos)
from repro.serving.gateway import (DECODING, DONE, FAILED, PREFILLING, QUEUED,
                                   REJECTED, TRANSFERRING, Gateway,
                                   RequestHandle, ServeRequest,
                                   gateway_from_plan, warmup_engines)
from repro.serving.transport import InProcessTransport

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced("llama-30b")
    api = build(cfg)
    params = api.init(KEY)
    return cfg, params


def _prompt(cfg, n=12, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, n).astype(np.int32)


def _gw(cfg, params, *, n_dec=2, max_slots=4, chunk_size=4, paged=False,
        transport=None, **gw_kw):
    pre = PrefillEngine(cfg, params, max_seq=64)
    dkw = dict(max_slots=max_slots, chunk_size=chunk_size, max_seq=64)
    if paged:
        dkw.update(paged=True, page_size=8)
    decs = [DecodeEngine(cfg, params, **dkw) for _ in range(n_dec)]
    return Gateway([pre], decs, transport=transport, backend="ref", **gw_kw)


def _reqs(cfg, n, *, max_new=16, plen=12, **kw):
    return [ServeRequest(i, _prompt(cfg, plen, seed=i), max_new, **kw)
            for i in range(n)]


# -- schedule / policy / clock (no model) -------------------------------------


def test_fault_schedule_reproducible():
    a = FaultSchedule.random(seed=7, horizon_s=10.0, n_events=4,
                             kinds=(CRASH, TRANSIENT, STRAGGLER, PREEMPT))
    b = FaultSchedule.random(seed=7, horizon_s=10.0, n_events=4,
                             kinds=(CRASH, TRANSIENT, STRAGGLER, PREEMPT))
    key = lambda e: (e.t, e.kind, e.phase, e.idx, e.duration_s, e.grace_s,
                     e.slow_s)
    assert [key(e) for e in a.events] == [key(e) for e in b.events]
    c = FaultSchedule.random(seed=8, horizon_s=10.0, n_events=4)
    assert [key(e) for e in a.events] != [key(e) for e in c.events]
    # events are sorted by fire time, and due() only surfaces point events
    assert all(x.t <= y.t for x, y in zip(a.events, a.events[1:]))
    a.arm(100.0)
    due = a.due(100.0 + 11.0)
    assert all(e.kind in (CRASH, PREEMPT) for e in due)


def test_fault_schedule_windows():
    s = FaultSchedule([FaultEvent(t=1.0, kind=TRANSIENT, duration_s=0.5),
                       FaultEvent(t=2.0, kind=STRAGGLER, phase="decode",
                                  idx=1, duration_s=0.5, slow_s=0.07)])
    s.arm(10.0)
    assert not s.transport_faulty(10.9)
    assert s.transport_faulty(11.0) and s.transport_faulty(11.49)
    assert not s.transport_faulty(11.5)
    assert s.straggle_s("decode", 1, 12.2) == pytest.approx(0.07)
    assert s.straggle_s("decode", 0, 12.2) == 0.0
    assert s.straggle_s("decode", 1, 12.6) == 0.0


def test_retry_policy_backoff():
    p = RetryPolicy(max_retries=4, base_s=0.02, multiplier=2.0, jitter=0.5,
                    max_s=0.1)
    import random
    rng = random.Random(0)
    for attempt, nominal in enumerate([0.02, 0.04, 0.08, 0.1, 0.1]):
        d = p.delay_s(attempt, rng)
        assert 0.5 * nominal <= d <= 1.5 * nominal
    # same seed -> same jittered delays
    a = [p.delay_s(i, random.Random(3)) for i in range(3)]
    b = [p.delay_s(i, random.Random(3)) for i in range(3)]
    assert a == b


def test_virtual_clock():
    clk = VirtualClock(5.0)
    assert clk() == 5.0
    clk.advance(0.25)
    clk.sleep(0.25)
    assert clk() == pytest.approx(5.5)


def test_chaos_transport_unit():
    sent = []
    inner = SimpleNamespace(
        send=lambda w, s, d, *, now=None: sent.append(("pd", s, d)) or "tkt",
        send_decode=lambda w, s, d, *, now=None:
            sent.append(("dd", s, d)) or "tkt",
        transfers=0)
    clk = VirtualClock()
    s = FaultSchedule([FaultEvent(t=1.0, kind=TRANSIENT, duration_s=0.5)])
    s.arm(clk())
    ct = ChaosTransport(inner, s, clk)
    assert ct.send(None, 0, 1) == "tkt"          # before the window
    clk.advance(1.2)
    with pytest.raises(TransientTransportError):
        ct.send(None, 0, 1)
    with pytest.raises(TransientTransportError):
        ct.send_decode(None, 0, 1)
    assert ct.faults_raised == 2
    clk.advance(0.5)                             # window over
    assert ct.send_decode(None, 0, 1) == "tkt"
    assert ct.transfers == 0                     # attribute forwarding


def test_chaos_client_unit():
    inner = SimpleNamespace(step=lambda: ["stepped"],
                            resident=lambda: ["r0"],
                            release=lambda r: True,
                            n_free=lambda: 3)
    clk = VirtualClock()
    s = FaultSchedule([FaultEvent(t=0.0, kind=STRAGGLER, phase="decode",
                                  idx=0, duration_s=1.0, slow_s=0.2)])
    s.arm(clk())
    c = ChaosClient(inner, s, "decode", 0, clk)
    t0 = clk()
    assert c.step() == ["stepped"]
    assert clk() - t0 == pytest.approx(0.2)      # straggler stall, virtual
    s.mark_crashed(c.cid)
    assert c.crashed
    with pytest.raises(ReplicaCrashError):
        c.step()
    assert c.n_free() == 0                       # routing steers away
    assert c.resident() == ["r0"]                # recovery calls still work
    assert c.release("r0") is True


def test_failed_rejected_require_reason():
    gw = SimpleNamespace(clock=lambda: 0.0)

    def fresh(path):
        h = RequestHandle(ServeRequest(0, np.ones(4, np.int32), 4),
                          GenRequest(0, np.ones(4, np.int32), 4), gw)
        for st in path:
            h._transition(st)
        return h

    paths = [(), (PREFILLING,), (PREFILLING, TRANSFERRING),
             (PREFILLING, TRANSFERRING, DECODING)]
    for path in paths:
        h = fresh(path)
        with pytest.raises(ValueError):
            h._transition(FAILED)                # no reason -> refused
        assert h.state == (path[-1] if path else QUEUED)
        h._transition(FAILED, reason="boom")
        assert h.state == FAILED and h.reason == "boom"
    h = fresh(())
    with pytest.raises(ValueError):
        h._transition(REJECTED)
    h._transition(REJECTED, reason="deadline")
    assert h.reason == "deadline"


def test_virtual_clock_deadline_shed():
    """Deadline shedding is deterministic under an injected clock: no
    sleeping, no wall time — advance past the TTFT deadline and pump."""
    clk = VirtualClock()
    pre = SimpleNamespace(synchronous=True, prefill=lambda *a, **k: [])
    dec = SimpleNamespace(synchronous=True, step=lambda: [], n_free=lambda: 1,
                          active=0, resident=lambda: [],
                          release=lambda r: False)
    gw = Gateway([pre], [dec], clock=clk)
    h = gw.submit(ServeRequest(0, np.ones(4, np.int32), 4,
                               ttft_deadline_s=0.5))
    clk.advance(1.0)
    gw.pump()
    assert h.state == REJECTED
    assert h.reason is not None and "deadline" in h.reason
    assert h.history[-1][0] == pytest.approx(1.0)   # virtual timestamp


def test_async_heartbeat_suspect_beat_then_dead():
    """Asynchronous clients go alive -> suspected (half the timeout) ->
    dead; a beat while merely suspected restores them to routing."""
    clk = VirtualClock()
    pre = SimpleNamespace(synchronous=True, prefill=lambda *a, **k: [])
    fake = SimpleNamespace(synchronous=False, step=lambda: [],
                           n_free=lambda: 0, active=0, resident=lambda: [],
                           release=lambda r: False)
    sync = SimpleNamespace(synchronous=True, step=lambda: [],
                           n_free=lambda: 1, active=0, resident=lambda: [],
                           release=lambda r: False)
    gw = Gateway([pre], [fake, sync], clock=clk, heartbeat_timeout=1.0)
    gw.pump()
    assert gw.dec[0].status == "alive"
    clk.advance(0.6)                      # past suspect_timeout (0.5)
    gw.pump()
    assert gw.dec[0].status == "suspected"
    assert gw.dec[0].suspect_why == "heartbeat"
    assert any("suspected" in e for e in gw.events)
    gw.dec[0].beat(clk())                 # a fresh beat refutes suspicion
    assert gw.dec[0].status == "alive"
    clk.advance(1.1)                      # past heartbeat_timeout
    gw.pump()
    assert gw.dec[0].status == "dead"
    assert any("timed out" in e for e in gw.events)
    assert any("confirmed dead" in e for e in gw.events)
    assert gw.dec[1].status == "alive"    # sync client auto-beats
    st = gw.stats()
    assert st["replicas"][1]["status"] == "dead"


# -- transient transport faults: retry -> requeue -----------------------------


def test_transient_fault_retries_then_delivers(small_model):
    cfg, params = small_model
    clk = VirtualClock()
    sched = FaultSchedule([FaultEvent(t=0.0, kind=TRANSIENT,
                                      duration_s=0.02)])
    sched.arm(clk())
    tr = ChaosTransport(InProcessTransport(), sched, clk)
    gw = _gw(cfg, params, n_dec=1, transport=tr, clock=clk,
             retry=RetryPolicy(max_retries=4, base_s=0.03, jitter=0.0))
    h = gw.submit(ServeRequest(0, _prompt(cfg), 8))
    gw.run_until_drained()
    # first send fails inside the window; the 30ms backoff lands after it
    assert h.state == DONE and len(h.tokens) == 8
    assert h.restarts == 0                      # retried, never requeued
    assert gw.n_retries == 1
    assert tr.faults_raised == 1
    assert any("transfer retry 1" in e for e in gw.events)


def test_retry_exhaustion_requeues_through_prefill(small_model):
    cfg, params = small_model
    clk = VirtualClock()
    sched = FaultSchedule([FaultEvent(t=0.0, kind=TRANSIENT,
                                      duration_s=0.06)])
    sched.arm(clk())
    tr = ChaosTransport(InProcessTransport(), sched, clk)
    gw = _gw(cfg, params, n_dec=2, transport=tr, clock=clk,
             retry=RetryPolicy(max_retries=1, base_s=0.04, jitter=0.0))
    h = gw.submit(ServeRequest(0, _prompt(cfg), 8))
    gw.run_until_drained()
    # attempt@0 fails, retry@0.04 fails -> exhausted -> requeue; the second
    # prefill's retry lands at ~0.08, outside the window -> delivery
    assert h.state == DONE and len(h.tokens) == 8
    assert h.restarts >= 1
    assert any("transfer retries exhausted" in e for e in gw.events)
    assert any("re-queued" in e for e in gw.events)
    states = [s for _, s in h.history]
    assert QUEUED in states[states.index(TRANSFERRING):]  # TRANSFERRING->QUEUED
    assert gw.stats()["counters"]["requeues"] >= 1


def test_retry_exhaustion_fails_at_max_restarts(small_model):
    cfg, params = small_model
    clk = VirtualClock()
    sched = FaultSchedule([FaultEvent(t=0.0, kind=TRANSIENT,
                                      duration_s=1e9)])
    sched.arm(clk())
    tr = ChaosTransport(InProcessTransport(), sched, clk)
    gw = _gw(cfg, params, n_dec=2, paged=True, transport=tr, clock=clk,
             retry=RetryPolicy(max_retries=0), max_restarts=0)
    h = gw.submit(ServeRequest(0, _prompt(cfg), 8))
    gw.pump()
    assert h.state == FAILED
    assert h.reason is not None and "gave up" in h.reason
    assert gw.n_failed == 1 and h in gw.done
    assert gw.stats()["counters"]["failed"] == 1
    # a TRANSFERRING-state failure never touched decode pages: none were
    # allocated, none freed — nothing to leak (satellite: page accounting)
    for d in gw.dec:
        st = d.engine.page_stats()
        assert st["allocs"] == 0 and st["frees"] == 0 and st["in_use"] == 0


# -- decode death: requeue + page accounting ----------------------------------


def test_decode_death_frees_pages_exactly_once(small_model):
    cfg, params = small_model
    gw = _gw(cfg, params, n_dec=2, paged=True, chunk_size=2)
    hs = [gw.submit(r) for r in _reqs(cfg, 3, max_new=12)]
    for _ in range(60):
        gw.pump()
        if all(h.state == DECODING for h in hs):
            break
    assert all(h.state == DECODING for h in hs)
    vic = max(range(2), key=lambda j: len(gw.dec[j].client.resident()))
    eng = gw.dec[vic].engine
    before = eng.page_stats()
    assert before["in_use"] > 0
    gw.kill_replica("decode", vic)
    after = eng.page_stats()
    # every resident page freed exactly once (a double free raises inside
    # PagePool.free), and no new allocations on the dead engine
    assert after["in_use"] == 0
    assert after["allocs"] == before["allocs"]
    assert after["frees"] == before["frees"] + before["in_use"]
    gw.run_until_drained()
    assert all(h.state == DONE and len(h.tokens) == 12 for h in hs)
    for d in gw.dec:
        st = d.engine.page_stats()
        assert st["in_use"] == 0 and st["allocs"] == st["frees"]
    assert gw.stats()["page_pool"]["alloc_failures"] >= 0


# -- chaos end-to-end: injected crash mid-trace -------------------------------


def test_chaos_crash_recovers_all_requests(small_model):
    cfg, params = small_model
    clk = VirtualClock()
    gw = _gw(cfg, params, n_dec=2, chunk_size=2, clock=clk)
    sched = FaultSchedule([FaultEvent(t=0.0, kind=CRASH, phase="decode",
                                      idx=-1, require_busy=True)])
    ctl = install_chaos(gw, sched, clock=clk)
    hs = [gw.submit(r) for r in _reqs(cfg, 4, max_new=10)]
    gw.run_until_drained()
    assert [f["kind"] for f in ctl.fired] == [CRASH]
    assert sum(d.status == "dead" for d in gw.dec) == 1
    assert any("confirmed dead" in e for e in gw.events)
    # zero accepted requests lost: every stream completes in full, the
    # victims' restarted attempts dedup their regenerated prefix
    assert all(h.state == DONE and len(h.tokens) == 10 for h in hs)
    assert gw.stats()["counters"]["requeues"] >= 1


def test_straggler_suspected_then_recovers(small_model):
    cfg, params = small_model
    gw = _gw(cfg, params, n_dec=3, max_slots=2, chunk_size=4,
             suspect_probe_s=0.25)
    warmup_engines([gw.pre[0].engine], [d.engine for d in gw.dec],
                   cfg.vocab_size, prompt_lens=(12,), max_new=2,
                   backend="ref")
    # the window must outlive the first (slow, freshly-batched) prefill
    # call, or the stall never lands on a decode step and the test only
    # "passes" via an incidental first-step compile spike
    sched = FaultSchedule([FaultEvent(t=0.0, kind=STRAGGLER, phase="decode",
                                      idx=0, duration_s=1.5, slow_s=0.3)])
    install_chaos(gw, sched)
    hs = [gw.submit(r) for r in _reqs(cfg, 8, max_new=24)]
    suspected = False
    for _ in range(4000):
        gw.pump()
        suspected = suspected or gw.dec[0].status == "suspected"
        if all(h.is_terminal for h in hs):
            break
    assert all(h.state == DONE for h in hs)
    assert suspected, "the stalled replica never left the routing tables"
    assert any("suspected (latency" in e for e in gw.events)
    # recovery: a healthy sample or the probe re-admission path
    if gw.dec[0].status != "alive":
        time.sleep(0.3)
        gw.pump()
    assert gw.dec[0].status == "alive"
    assert any(("recovered" in e) or ("probe" in e) for e in gw.events)


# -- spot preemption: page-granular migration ---------------------------------


def test_preemption_migrates_kv_zero_loss(small_model):
    """The tentpole acceptance test: a preemption drain mid-stream moves
    resident decode KV page-granular to the survivor and every stream
    finishes BIT-IDENTICAL to an uninterrupted run — zero token loss,
    zero restarts, zero re-quantization."""
    cfg, params = small_model
    reqs = _reqs(cfg, 3, max_new=20)
    base = _gw(cfg, params, n_dec=2, paged=True, chunk_size=2)
    bh = [base.submit(r) for r in reqs]
    base.run_until_drained()
    want = {h.request.rid: list(h.tokens) for h in bh}
    assert all(h.state == DONE for h in bh)

    gw = _gw(cfg, params, n_dec=2, paged=True, chunk_size=2)
    hs = [gw.submit(r) for r in reqs]
    for _ in range(60):
        gw.pump()
        if (all(h.state == DECODING for h in hs)
                and min(len(h.tokens) for h in hs) >= 2):
            break
    assert all(h.state == DECODING for h in hs)
    mid = {h.request.rid: len(h.tokens) for h in hs}
    vic = max(range(2), key=lambda j: len(gw.dec[j].client.resident()))
    n_vic = len(gw.dec[vic].client.resident())
    assert n_vic >= 1
    zc0 = sum(d.engine.page_stats()["zero_copy_inserts"] for d in gw.dec)
    re0 = sum(d.engine.page_stats()["reencoded_inserts"] for d in gw.dec)

    rep = gw.handle_preemption("decode", vic, grace_s=60.0)
    assert rep["migrated"] == n_vic and rep["requeued"] == 0
    assert rep["tokens_migrated"] >= n_vic * 13   # 12 prompt + >=1 decoded
    assert gw.dec[vic].status == "dead"
    assert gw.dec[vic].engine.page_stats()["in_use"] == 0   # source drained
    gw.run_until_drained()

    assert all(h.state == DONE for h in hs)
    assert all(h.restarts == 0 for h in hs)       # migrated, not restarted
    got = {h.request.rid: list(h.tokens) for h in hs}
    assert got == want                            # bit-identical streams
    # the migrated handles crossed DECODING -> TRANSFERRING -> DECODING
    n_mig_handles = 0
    for h in hs:
        states = [s for _, s in h.history]
        if TRANSFERRING in states[states.index(DECODING):]:
            n_mig_handles += 1
    assert n_mig_handles == rep["migrated"]
    # page-gathered wires scatter zero-copy into the survivor's pool: no
    # dequant/requant round-trip anywhere on the migration path
    zc1 = sum(d.engine.page_stats()["zero_copy_inserts"] for d in gw.dec)
    re1 = sum(d.engine.page_stats()["reencoded_inserts"] for d in gw.dec)
    per_wire = zc0 // len(hs)       # tensors per wire (k/v x layer groups)
    assert per_wire > 0 and zc0 == per_wire * len(hs)
    assert zc1 - zc0 == rep["migrated"] * per_wire
    assert re1 - re0 == 0
    st = gw.stats()
    assert st["counters"]["migrations"] == rep["migrated"]
    assert st["counters"]["migrated_tokens"] == rep["tokens_migrated"]
    assert st["counters"]["preemptions"] == 1


def test_preemption_zero_grace_requeues(small_model):
    """No grace budget -> nothing can migrate; every resident falls back
    to requeue-through-prefill and still completes (zero loss)."""
    cfg, params = small_model
    gw = _gw(cfg, params, n_dec=2, chunk_size=2)
    hs = [gw.submit(r) for r in _reqs(cfg, 2, max_new=10)]
    for _ in range(60):
        gw.pump()
        if all(h.state == DECODING for h in hs):
            break
    vic = max(range(2), key=lambda j: len(gw.dec[j].client.resident()))
    n_vic = len(gw.dec[vic].client.resident())
    rep = gw.handle_preemption("decode", vic, grace_s=0.0)
    assert rep["migrated"] == 0 and rep["requeued"] == n_vic
    assert any("preemption drain" in e for e in gw.events)
    gw.run_until_drained()
    assert all(h.state == DONE and len(h.tokens) == 10 for h in hs)
    assert sum(h.restarts for h in hs) >= n_vic


# -- death -> failover reschedule (plan epoch excluding the dead node) --------


GROUPS = ((0, 1, 2, 3), (4, 5, 6, 7), tuple(range(8, 16)),
          tuple(range(16, 24)))
CFG_FULL = get_config("llama-30b")
SLO = SloSpec(ttft_s=2.0, tpot_s=0.15, e2e_s=30.0)


def test_dead_replica_triggers_failover_reschedule(small_model):
    cfg, params = small_model
    cluster = make_paper_cloud()
    solver = scheduler.LowerLevelSolver(cluster, CFG_FULL, CONVERSATION, 2.0,
                                        SLO)
    sol = tabu.Solution(GROUPS, ("prefill", "prefill", "decode", "decode"))
    score, replicas, o = solver.solve(sol)
    assert replicas
    plan = scheduler.DeploymentPlan(solution=sol, replicas=replicas,
                                    orchestration=o, score=score)

    calls = []

    def pinned(cluster_, cfg_, plan_, wl, rate, slo, *, init_solution=None,
               **kw):
        calls.append(init_solution)
        sc, reps, orch = solver.solve(init_solution)
        return scheduler.DeploymentPlan(solution=init_solution,
                                        replicas=reps, orchestration=orch,
                                        score=sc)

    gw = gateway_from_plan(plan, cfg, params, max_seq=64, max_slots=4,
                           chunk_size=4, backend="ref")
    gw.set_failover(cluster, CFG_FULL, SLO, workload=CONVERSATION, rate=2.0,
                    search_fn=pinned)
    hs = [gw.submit(r) for r in _reqs(cfg, 3, max_new=8)]
    for _ in range(30):
        gw.pump()
        if any(h.state == DECODING for h in hs):
            break
    dead_group = gw.dec[1].group
    gw.kill_replica("decode", 1)
    gw.pump()                       # picks up the pending failover
    assert calls, "failover search never ran"
    assert gw.epoch == 1
    live_groups = {h.group for h in gw.pre + gw.dec if h.alive}
    assert dead_group not in live_groups
    assert set(calls[0].groups) == live_groups
    assert any(e.startswith("failover reschedule:") for e in gw.events)
    gw.run_until_drained()
    assert all(h.state == DONE and len(h.tokens) == 8 for h in hs)
