"""Checkpoint save/restore roundtrip (incl. bf16), async writer, recovery."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              load_checkpoint, save_checkpoint)


def _tree():
    k = jax.random.PRNGKey(0)
    return {
        "a": jax.random.normal(k, (4, 8), jnp.float32),
        "nested": {"b": jax.random.normal(k, (3,), jnp.bfloat16),
                   "c": (jnp.arange(5), jnp.ones((2, 2), jnp.bfloat16))},
        "step": jnp.int32(7),
    }


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, t, 120, extra={"note": "hi"})
    got, step, extra = load_checkpoint(tmp_path, t)
    assert step == 120 and extra["note"] == "hi"
    _assert_tree_equal(t, got)


def test_latest_step_and_multiple(tmp_path):
    t = _tree()
    for s in (10, 20, 30):
        save_checkpoint(tmp_path, t, s)
    assert latest_step(tmp_path) == 30
    _, step, _ = load_checkpoint(tmp_path, t, step=20)
    assert step == 20


def test_async_checkpointer(tmp_path):
    t = _tree()
    ck = AsyncCheckpointer(tmp_path)
    for s in (5, 6):
        ck.save(t, s)
    ck.wait()
    got, step, _ = load_checkpoint(tmp_path, t)
    assert step == 6
    _assert_tree_equal(t, got)


def test_restart_resumes_training(tmp_path):
    """Checkpoint/restart: a restarted run continues bit-identically."""
    from repro.configs import get_reduced
    from repro.models import build
    from repro.training import optimizer as opt
    from repro.training.data import DataConfig, PackedLM

    cfg = get_reduced("llama-30b")
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    ostate = opt.adamw_init(params)
    step_fn = jax.jit(opt.make_train_step(api, ocfg))
    data = PackedLM(DataConfig(cfg.vocab_size, 16, 2))

    losses_a = []
    for i, batch in enumerate(data):
        if i >= 6:
            break
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        params, ostate, st = step_fn(params, ostate, jb)
        losses_a.append(float(st["loss"]))
        if i == 2:
            save_checkpoint(tmp_path, {"p": params, "o": ostate}, i,
                            extra={"data": data.state()})

    # restart from step 2 and replay
    got, _, extra = load_checkpoint(
        tmp_path, {"p": params, "o": ostate})
    params2, ostate2 = got["p"], got["o"]
    data2 = PackedLM(DataConfig(cfg.vocab_size, 16, 2))
    data2.restore(extra["data"])
    losses_b = []
    for i, batch in enumerate(data2):
        if i >= 3:
            break
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        params2, ostate2, st = step_fn(params2, ostate2, jb)
        losses_b.append(float(st["loss"]))
    np.testing.assert_allclose(losses_a[3:], losses_b, rtol=1e-5)
