"""Per-architecture smoke + prefill/decode consistency.

The consistency test is the strong one: running `prefill(prompt)` then
feeding the next tokens one-by-one through `decode` must reproduce the
logits of a single full `forward` over the whole sequence (same params,
same tokens) — this exercises KV caches, recurrent states, ring buffers,
and position handling across every family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_reduced
from repro.models import build
from repro.models import transformer

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B, S, key):
    k1, k2 = jax.random.split(key)
    batch = {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
             "targets": jax.random.randint(k2, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frame_embeds"] = jax.random.normal(
            k1, (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            k1, (B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step, output shapes, no NaNs."""
    from repro.training import optimizer as opt
    cfg = get_reduced(arch)
    api = build(cfg)
    params = api.init(KEY)
    batch = _batch_for(cfg, 2, 32, jax.random.PRNGKey(1))
    loss = api.loss(params, batch)
    assert np.isfinite(float(loss)), arch
    step = opt.make_train_step(api, opt.AdamWConfig(lr=1e-3))
    p2, o2, stats = step(params, opt.adamw_init(params), batch)
    assert np.isfinite(float(stats["loss"]))
    assert np.isfinite(float(stats["grad_norm"]))
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert l0.shape == l1.shape
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """Greedy-context equivalence: forward logits == prefill+decode logits."""
    cfg = get_reduced(arch)
    api = build(cfg)
    params = api.init(KEY)
    B, S_prompt, S_total = 2, 12, 16
    batch = _batch_for(cfg, B, S_total, jax.random.PRNGKey(2))
    tokens = batch["tokens"]

    # full forward logits at every position
    if cfg.family == "audio":
        from repro.models import whisper
        x = whisper.forward(cfg, params, tokens, batch["frame_embeds"])
        w = params["unembed"]
    else:
        x, _, _ = transformer.forward(
            cfg, params, tokens, patch_embeds=batch.get("patch_embeds"))
        w = transformer.unembed_matrix(cfg, params)
    full_logits = np.asarray((x @ w).astype(jnp.float32))
    if cfg.family == "vlm":
        full_logits = full_logits[:, cfg.num_patches:]

    # prefill on the prompt, then step through the remaining tokens
    pf = {"tokens": tokens[:, :S_prompt]}
    for key in ("frame_embeds", "patch_embeds"):
        if key in batch:
            pf[key] = batch[key]
    logits, cache = api.prefill(params, pf,
                                max_seq=S_total + cfg.num_patches + 4)
    got = [np.asarray(logits[:, -1])]
    for t in range(S_prompt, S_total):
        logits, cache = api.decode(params, cache,
                                   {"tokens": tokens[:, t:t + 1]})
        got.append(np.asarray(logits[:, -1]))
    got = np.stack(got, axis=1)  # (B, S_total-S_prompt+1, V)
    want = full_logits[:, S_prompt - 1:S_total]
    # bf16 models: compare top-1 agreement + moderate numeric tolerance
    top_got = got.argmax(-1)
    top_want = want.argmax(-1)
    agree = (top_got == top_want).mean()
    assert agree >= 0.95, (arch, agree)
    np.testing.assert_allclose(got, want, atol=0.25, rtol=0.05)


def test_sliding_window_ring_buffer_consistency():
    """SWA decode with a ring cache == full-cache attention w/ window mask."""
    cfg = get_reduced("h2o-danube-3-4b").replace(sliding_window=8)
    api = build(cfg)
    params = api.init(KEY)
    B, S_prompt, S_total = 1, 12, 18
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S_total), 0,
                                cfg.vocab_size)
    x, _, _ = transformer.forward(cfg, params, tokens)
    w = transformer.unembed_matrix(cfg, params)
    full_logits = np.asarray((x @ w).astype(jnp.float32))

    logits, cache = api.prefill(params, {"tokens": tokens[:, :S_prompt]},
                                max_seq=S_total)
    # cache layout (n_super, B, S_cache, Hkv, hd): ring size == window
    assert cache["slot0"]["k"].shape[2] == cfg.sliding_window
    got = [np.asarray(logits[:, -1])]
    for t in range(S_prompt, S_total):
        logits, cache = api.decode(params, cache,
                                   {"tokens": tokens[:, t:t + 1]})
        got.append(np.asarray(logits[:, -1]))
    got = np.stack(got, axis=1)
    want = full_logits[:, S_prompt - 1:S_total]
    agree = (got.argmax(-1) == want.argmax(-1)).mean()
    assert agree >= 0.95, agree
    np.testing.assert_allclose(got, want, atol=0.25, rtol=0.05)


def test_vlm_patch_prefix_changes_logits():
    cfg = get_reduced("llava-next-mistral-7b")
    api = build(cfg)
    params = api.init(KEY)
    tokens = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    pe1 = jnp.zeros((1, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    pe2 = jnp.ones((1, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    l1, _ = api.prefill(params, {"tokens": tokens, "patch_embeds": pe1},
                        max_seq=32)
    l2, _ = api.prefill(params, {"tokens": tokens, "patch_embeds": pe2},
                        max_seq=32)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_moe_router_load_balance_loss_positive():
    from repro.models import moe
    cfg = get_reduced("qwen3-moe-235b-a22b")
    p = moe.moe_init(cfg, KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.bfloat16)
    y, aux = moe.moe_apply(cfg, p, x)
    assert y.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz, ==1 if balanced


@pytest.mark.parametrize("arch", ["command-r-35b", "jamba-v0.1-52b",
                                  "h2o-danube-3-4b"])
def test_inplace_decode_matches_scan_decode(arch):
    """§Perf iteration A1: the fori_loop in-place decode must be
    numerically equivalent to the scan-based decode."""
    cfg = get_reduced(arch)
    api = build(cfg)
    params = api.init(KEY)
    B, Sp, St = 2, 10, 14
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, St), 0,
                                cfg.vocab_size)
    _, c0 = api.prefill(params, {"tokens": tokens[:, :Sp]}, max_seq=St + 2)
    c1 = jax.tree.map(lambda a: a, c0)
    for t in range(Sp, St):
        l0, c0 = transformer.decode_step(cfg, params, c0,
                                         tokens[:, t:t + 1])
        l1, c1 = transformer.decode_step_inplace(cfg, params, c1,
                                                 tokens[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   atol=0.02, rtol=0.02)


def test_hkv_layout_decode_matches_default():
    """§Perf iteration A2: flash-decode cache layout equivalence."""
    cfg0 = get_reduced("command-r-35b")
    cfg1 = cfg0.replace(decode_cache_layout="hkv_s")
    api0, api1 = build(cfg0), build(cfg1)
    params = api0.init(KEY)
    B, Sp, St = 2, 10, 13
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, St), 0,
                                cfg0.vocab_size)
    _, c0 = api0.prefill(params, {"tokens": tokens[:, :Sp]}, max_seq=St + 2)
    _, c1 = api1.prefill(params, {"tokens": tokens[:, :Sp]}, max_seq=St + 2)
    for t in range(Sp, St):
        l0, c0 = api0.decode(params, c0, {"tokens": tokens[:, t:t + 1]})
        l1, c1 = api1.decode(params, c1, {"tokens": tokens[:, t:t + 1]})
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   atol=0.02, rtol=0.02)
