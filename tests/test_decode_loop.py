"""Device-resident decode loop + batched KV wire fast path.

Covers: token-identical output of the chunked device loop vs the per-step
reference, steps-per-host-sync accounting, bucketed prefill equivalence and
bounded jit cache, quantize->dequantize roundtrips across backends/dtypes,
batched insert equivalence, wire dtype preservation, and the gateway's
all-decode-replicas-dead guard."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import admit_one

from repro.configs import get_reduced
from repro.models import build, transformer
from repro.serving import kv_transfer
from repro.serving.engine import DecodeEngine, GenRequest, PrefillEngine
from repro.serving.gateway import Gateway

KEY = jax.random.PRNGKey(0)
LENS = [8, 12, 17, 24, 9, 31]


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced("llama-30b")
    api = build(cfg)
    params = api.init(KEY)
    return cfg, api, params


def _reqs(cfg, lens=LENS, max_new=12):
    rng = np.random.default_rng(0)
    return [GenRequest(i, rng.integers(1, cfg.vocab_size,
                                       int(l)).astype(np.int32),
                       max_new_tokens=max_new)
            for i, l in enumerate(lens)]


# -- device loop vs per-step reference --------------------------------------


def test_device_loop_token_identical(small_model):
    """The jitted multi-token scan must reproduce the seed per-step path
    token for token (same wires in, same tokens out)."""
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64, bucket=False)
    res_a = pre.run(_reqs(cfg), backend="ref")
    res_b = pre.run(_reqs(cfg), backend="ref")
    chunked = DecodeEngine(cfg, params, max_slots=len(LENS), max_seq=64,
                           chunk_size=8)
    ref = DecodeEngine(cfg, params, max_slots=len(LENS), max_seq=64)
    for r, w, f in res_a:
        assert admit_one(chunked, r, f, wire=w, backend="ref")
    for r, w, f in res_b:
        assert admit_one(ref, r, f, wire=w, backend="ref")
    done_c, done_r = [], []
    while chunked.active:
        done_c += chunked.step()
    while ref.active:
        done_r += ref.step_reference()
    toks_c = {r.rid: r.out_tokens for r in done_c}
    toks_r = {r.rid: r.out_tokens for r in done_r}
    assert toks_c == toks_r
    assert all(len(t) == 12 for t in toks_c.values())


def test_steps_per_host_sync(small_model):
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    eng = DecodeEngine(cfg, params, max_slots=len(LENS), max_seq=64,
                       chunk_size=8)
    for r, w, f in pre.run(_reqs(cfg, max_new=16), backend="ref"):
        admit_one(eng, r, f, wire=w, backend="ref")
    while eng.active:
        eng.step()
    assert eng.steps_run / eng.host_syncs >= 8


def test_chunk_respects_max_new_and_eos(small_model):
    """Done-flags live on device: max_new_tokens is never overshot even
    when the chunk is longer than the remaining budget."""
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    eng = DecodeEngine(cfg, params, max_slots=4, max_seq=64, chunk_size=16)
    reqs = _reqs(cfg, lens=[8, 12], max_new=3)
    for r, w, f in pre.run(reqs, backend="ref"):
        admit_one(eng, r, f, wire=w, backend="ref")
    done = []
    while eng.active:
        done += eng.step()
    assert sorted(len(r.out_tokens) for r in done) == [3, 3]


# -- bucketed prefill -------------------------------------------------------


def test_bucketed_prefill_matches_exact(small_model):
    """Right-padded power-of-two buckets must produce the same first token
    and the same (raw) KV as exact-length prefill — causal attention makes
    the padding invisible."""
    cfg, api, params = small_model
    pre_b = PrefillEngine(cfg, params, max_seq=64, bucket=True)
    pre_e = PrefillEngine(cfg, params, max_seq=64, bucket=False)
    res_b = {r.rid: (w, f) for r, w, f in
             pre_b.run(_reqs(cfg), compress=False, backend="ref")}
    res_e = {r.rid: (w, f) for r, w, f in
             pre_e.run(_reqs(cfg), compress=False, backend="ref")}
    for rid, (w_b, f_b) in res_b.items():
        w_e, f_e = res_e[rid]
        assert f_b == f_e
        assert w_b.request_len == w_e.request_len
        for name in w_b.slots:
            for key, t_b in w_b.slots[name].items():
                t_e = w_e.slots[name][key]
                assert t_b.dtype == t_e.dtype
                np.testing.assert_array_equal(
                    np.asarray(t_b.payload["x"], np.float32),
                    np.asarray(t_e.payload["x"], np.float32))


def test_bucketed_prefill_jit_cache_bounded(small_model):
    """One compile per power-of-two bucket: the jit cache stays <=
    log2(max_seq) no matter how many distinct prompt lengths arrive."""
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    assert pre.bucketed
    for lens in ([3, 5, 7], [11, 13], [19, 23, 29], [37, 41], [53, 61]):
        pre.run(_reqs(cfg, lens=lens), backend="ref")
    assert pre.jit_cache_size <= int(math.log2(64))


def test_bucketed_prefill_disabled_for_recurrent():
    """Recurrent-state archs must keep exact-length prefill: padded junk
    tokens would pollute the state snapshot."""
    cfg = get_reduced("xlstm-125m")
    params = build(cfg).init(KEY)
    pre = PrefillEngine(cfg, params, max_seq=64)
    assert not pre.bucketed
    out = pre.run(_reqs(cfg, lens=[8, 8]), backend="ref")
    assert len(out) == 2


# -- KV wire ----------------------------------------------------------------


def _toy_cache(dtype, L=2, B=3, S=32, Hkv=4, hd=16, key=KEY):
    k1, k2 = jax.random.split(key)
    return {
        "slot0": {"k": jax.random.normal(k1, (L, B, S, Hkv, hd), dtype),
                  "v": jax.random.normal(k2, (L, B, S, Hkv, hd), dtype)},
        "lengths": jnp.full((B,), S, jnp.int32),
    }


@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_kv_roundtrip_backends_dtypes(backend, dtype):
    cache = _toy_cache(dtype)
    wire = kv_transfer.extract(cache, 1, 24, compress=True, backend=backend)
    dec = _toy_cache(dtype, key=jax.random.PRNGKey(9))
    dec = kv_transfer.insert(dec, wire, 0, backend=backend)
    src = np.asarray(cache["slot0"]["k"][:, 1, :24], np.float32)
    dst = np.asarray(dec["slot0"]["k"][:, 0, :24], np.float32)
    rng = np.abs(src).max()
    assert np.abs(src - dst).max() <= rng / 15 * 1.1 + 1e-3
    assert int(dec["lengths"][0]) == 24
    assert wire.slots["slot0"]["k"].dtype == str(jnp.dtype(dtype))


def test_raw_wire_preserves_dtype():
    """Satellite fix: the raw (compress=False) path used to hard-code
    bfloat16 regardless of the source tensor."""
    for dtype, want in ((jnp.float32, "float32"), (jnp.bfloat16, "bfloat16")):
        cache = _toy_cache(dtype)
        wire = kv_transfer.extract(cache, 0, 16, compress=False)
        for key in ("k", "v"):
            assert wire.slots["slot0"][key].kind == "raw"
            assert wire.slots["slot0"][key].dtype == want


def test_insert_batch_matches_sequential():
    cache = _toy_cache(jnp.bfloat16)
    wires = kv_transfer.extract_batch(cache, [(0, 16), (2, 24)],
                                      backend="ref")
    seq = _toy_cache(jnp.bfloat16, key=jax.random.PRNGKey(1))
    seq = kv_transfer.insert(seq, wires[0], 1, backend="ref")
    seq = kv_transfer.insert(seq, wires[1], 2, backend="ref")
    bat = _toy_cache(jnp.bfloat16, key=jax.random.PRNGKey(1))
    bat = kv_transfer.insert_batch(bat, [(wires[0], 1), (wires[1], 2)],
                                   backend="ref")
    for key in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(seq["slot0"][key], np.float32),
            np.asarray(bat["slot0"][key], np.float32))
    np.testing.assert_array_equal(np.asarray(seq["lengths"]),
                                  np.asarray(bat["lengths"]))


def test_wire_materialize_single_host_hop():
    cache = _toy_cache(jnp.bfloat16)
    wire = kv_transfer.extract(cache, 0, 16, backend="ref")
    n0 = wire.nbytes()
    assert any(isinstance(t.payload[k], jax.Array)
               for s in wire.slots.values() for t in s.values()
               for k in t.payload)
    wire.materialize()
    for s in wire.slots.values():
        for t in s.values():
            for a in t.payload.values():
                assert isinstance(a, np.ndarray)
    assert wire.nbytes() == n0


def test_padded_extract_matches_exact_quantization():
    """extract_batch(pad_to=...) trims packed rows to the true length; the
    result must dequantize identically to an exact-length extract with the
    same group width."""
    cache = _toy_cache(jnp.bfloat16)
    padded, = kv_transfer.extract_batch(cache, [(1, 17)], backend="ref",
                                        pad_to=32)
    dec_a = _toy_cache(jnp.bfloat16, key=jax.random.PRNGKey(3))
    dec_a = kv_transfer.insert(dec_a, padded, 0, backend="ref")
    assert padded.slots["slot0"]["k"].orig_shape[1] == 17
    src = np.asarray(cache["slot0"]["k"][:, 1, :17], np.float32)
    dst = np.asarray(dec_a["slot0"]["k"][:, 0, :17], np.float32)
    rng = np.abs(src).max()
    assert np.abs(src - dst).max() <= rng / 15 * 1.1 + 1e-3


def test_bucketed_prefill_rejects_overlong_prompt(small_model):
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=32)
    with pytest.raises(ValueError, match="max_seq"):
        pre.run(_reqs(cfg, lens=[40]), backend="ref")


def test_whisper_kv_transfer_roundtrip():
    """Encoder-decoder caches are flat arrays: self_* KV must transfer
    trimmed to the request, cross_* KV whole."""
    from repro.models import whisper

    cfg = get_reduced("whisper-base")
    api = build(cfg)
    params = api.init(KEY)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 12)), jnp.int32)
    frames = jnp.asarray(rng.standard_normal(
        (2, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16)
    _, cache = api.prefill(params, {"tokens": tokens, "frame_embeds": frames},
                           max_seq=32)
    wire = kv_transfer.extract(cache, 1, 12, compress=True, backend="ref")
    assert wire.nbytes() > 0
    dec = whisper.init_cache(cfg, 3, 32)
    dec = kv_transfer.insert(dec, wire, 0, backend="ref")
    for name, ln in (("self_k", 12), ("cross_k", cfg.encoder_seq)):
        src = np.asarray(cache[name][:, 1, :ln], np.float32)
        dst = np.asarray(dec[name][:, 0, :ln], np.float32)
        rng_ = np.abs(src).max()
        assert np.abs(src - dst).max() <= rng_ / 15 * 1.1 + 1e-3, name
    assert int(dec["lengths"][0]) == 12


# -- slot recycling ---------------------------------------------------------


def test_chunked_step_releases_lengths_on_finish(small_model):
    """Regression: the scan path froze a finished slot's cache length
    instead of zeroing it like step_reference does; a recycled slot must
    serve its new request with unpolluted attention."""
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64, bucket=False)
    eng = DecodeEngine(cfg, params, max_slots=1, max_seq=64, chunk_size=4)
    req_a, req_b = _reqs(cfg, lens=[24, 9], max_new=6)
    (a, wa, fa), = pre.run([req_a], backend="ref")
    assert admit_one(eng, a, fa, wire=wa, backend="ref")
    while eng.active:
        eng.step()
    assert int(eng.cache["lengths"][0]) == 0, \
        "finished slot must release its cache length (step_reference parity)"
    # recycle the slot: admit -> finish -> admit; tokens must match a
    # fresh engine decoding the same request
    (b, wb, fb), = pre.run([req_b], backend="ref")
    assert admit_one(eng, b, fb, wire=wb, backend="ref")
    while eng.active:
        eng.step()
    fresh = DecodeEngine(cfg, params, max_slots=1, max_seq=64, chunk_size=4)
    req_b2 = GenRequest(99, req_b.tokens, max_new_tokens=6)
    (b2, wb2, fb2), = pre.run([req_b2], backend="ref")
    assert admit_one(fresh, b2, fb2, wire=wb2, backend="ref")
    while fresh.active:
        fresh.step()
    assert b.out_tokens == b2.out_tokens, \
        "recycled slot's attention polluted by the previous occupant"


def test_release_frees_slot_and_length(small_model):
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    eng = DecodeEngine(cfg, params, max_slots=2, max_seq=64)
    (r, w, f), = pre.run(_reqs(cfg, lens=[12], max_new=8), backend="ref")
    assert admit_one(eng, r, f, wire=w, backend="ref")
    assert int(eng.cache["lengths"][0]) == 12
    assert eng.release(0) is r
    assert eng.slots[0] is None and int(eng.cache["lengths"][0]) == 0


# -- gateway guard -----------------------------------------------------------


def test_all_decode_dead_surfaces_event(small_model):
    cfg, api, params = small_model
    gw = Gateway([PrefillEngine(cfg, params, max_seq=64)],
                 [DecodeEngine(cfg, params, max_slots=2, max_seq=64)],
                 backend="ref")
    for r in _reqs(cfg, lens=[8, 8], max_new=4):
        gw.submit(r)
    gw.kill_replica("decode", 0)
    gw.pump()
    gw.pump()
    outage = [e for e in gw.events if "all decode replicas dead" in e]
    assert len(outage) == 1          # surfaced once, not spammed
    assert gw.transfer_queue         # wires wait instead of spinning


def test_gateway_drains_all_prefill_replicas(small_model):
    cfg, api, params = small_model
    pres = [PrefillEngine(cfg, params, max_seq=64) for _ in range(2)]
    decs = [DecodeEngine(cfg, params, max_slots=4, max_seq=64)
            for _ in range(2)]
    gw = Gateway(pres, decs, backend="ref")
    for r in _reqs(cfg, lens=[8] * 8, max_new=4):
        gw.submit(r)
    gw.pump()
    # with 8 queued and max_prefill_batch=4, one pump must feed BOTH
    # replicas (the seed path fed one random replica per pump)
    assert not gw.queue
    done = gw.run_until_drained(max_iters=200)
    assert len(done) == 8
