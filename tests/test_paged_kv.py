"""Paged int4-resident decode KV cache (DESIGN.md §7).

Covers: interpret-mode parity of the fused-dequant ``paged_decode_attention``
kernel vs the dense oracle (ragged lengths, page-boundary-straddling
sequences, int4 vs bf16 residency), ``PagePool`` alloc/free invariants
under hypothesis, the engine-level page lifecycle (release mid-stream
returns every page; finish returns every page; admission is page-budget
gated), zero-dequant wire insertion, phase-flip pool ownership, and the
cost model's page-budget capacity term.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import admit_many, admit_one

from repro.configs import get_reduced
from repro.kernels import ops, ref
from repro.models import build, paged
from repro.serving import page_pool
from repro.serving.engine import DecodeEngine, GenRequest, PrefillEngine, \
    Replica
from repro.serving.page_pool import PagePool, pages_needed

KEY = jax.random.PRNGKey(0)


# -- kernel parity -----------------------------------------------------------


def _paged_fixture(B, Hkv, gq, hd, page_size, kv_len, *, seed=0,
                   resident="int4"):
    """Random dense K/V scattered into a shuffled page pool; returns
    (q, k_pages, v_pages, page_table, dense_k, dense_v) where dense_* are
    the values attention should see (dequantized for int4 residency)."""
    rng = np.random.default_rng(seed)
    B_ = len(kv_len)
    assert B_ == B
    W = max(-(-int(l) // page_size) for l in kv_len)
    g = next(gg for gg in (128, 64, 32, 16, 8, 4, 2)
             if (Hkv * hd) % gg == 0)
    ppr = (Hkv * hd) // g
    P = 1 + sum(-(-int(l) // page_size) for l in kv_len)
    pt = np.zeros((B, W), np.int32)
    perm = rng.permutation(np.arange(1, P))
    n = 0
    for b in range(B):
        k = -(-int(kv_len[b]) // page_size)
        pt[b, :k] = perm[n:n + k]
        n += k
    S = W * page_size
    K = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    V = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)

    def to_pages(X):
        if resident == "bf16":
            buf = np.zeros((P, page_size, Hkv, hd), np.float32)
            for b in range(B):
                for t in range(int(kv_len[b])):
                    buf[pt[b, t // page_size], t % page_size] = X[b, t]
            pages = jnp.asarray(buf, jnp.bfloat16)
            dense = np.zeros_like(X)
            for b in range(B):
                for t in range(int(kv_len[b])):
                    dense[b, t] = np.asarray(
                        pages[pt[b, t // page_size], t % page_size],
                        np.float32)
            return pages, dense
        kp = np.zeros((P, page_size * ppr, g // 2), np.uint8)
        ks = np.zeros((P, page_size * ppr, 1), np.float32)
        kz = np.zeros((P, page_size * ppr, 1), np.float32)
        dense = np.zeros_like(X)
        for b in range(B):
            for t in range(int(kv_len[b])):
                rows = X[b, t].reshape(ppr, g)
                p_, s_, z_ = ref.kv_quant_ref(jnp.asarray(rows))
                page, r0 = pt[b, t // page_size], (t % page_size) * ppr
                kp[page, r0:r0 + ppr] = np.asarray(p_)
                ks[page, r0:r0 + ppr] = np.asarray(s_)
                kz[page, r0:r0 + ppr] = np.asarray(z_)
                dense[b, t] = np.asarray(ref.kv_dequant_ref(
                    p_, s_, z_, dtype=jnp.float32)).reshape(Hkv, hd)
        return (jnp.asarray(kp), jnp.asarray(ks), jnp.asarray(kz)), dense

    k_pages, dense_k = to_pages(K)
    v_pages, dense_v = to_pages(V)
    q = jnp.asarray(rng.standard_normal((B, Hkv, gq, hd)), jnp.float32)
    return q, k_pages, v_pages, jnp.asarray(pt), dense_k, dense_v


# ragged + page-straddling: lengths deliberately off page boundaries, one
# exactly on a boundary, one inside the first page
KERNEL_CASES = [
    # (B, Hkv, gq, hd, page_size, kv_lens)
    (3, 2, 4, 16, 8, [5, 16, 23]),
    (2, 1, 8, 32, 16, [17, 48]),          # MQA, boundary-exact second seq
    (4, 4, 1, 16, 8, [1, 9, 24, 31]),     # MHA decode (gq=1)
]


@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("B,Hkv,gq,hd,ps,lens", KERNEL_CASES)
@pytest.mark.parametrize("resident", ["int4", "bf16"])
def test_paged_attention_matches_dense_ref(backend, B, Hkv, gq, hd, ps,
                                           lens, resident):
    """The fused-dequant paged kernel must equal the dense oracle run over
    the SAME (dequantized) values — bf16-level tolerance."""
    kv_len = jnp.asarray(lens, jnp.int32)
    q, kpg, vpg, pt, dk, dv = _paged_fixture(B, Hkv, gq, hd, ps, lens,
                                             resident=resident)
    out = ops.paged_decode_attention(q, kpg, vpg, pt, kv_len,
                                     page_size=ps, backend=backend)
    want = ref.decode_attention_ref(
        q, jnp.asarray(dk.transpose(0, 2, 1, 3)),
        jnp.asarray(dv.transpose(0, 2, 1, 3)), kv_len=kv_len)
    tol = 2e-2 if resident == "bf16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_paged_attention_ignores_trash_and_masked_tail():
    """Positions beyond kv_len (the tail of the last page) and table
    entries pointing at the trash page must not leak into the output."""
    lens = [5, 16, 23]
    kv_len = jnp.asarray(lens, jnp.int32)
    q, kpg, vpg, pt, dk, dv = _paged_fixture(3, 2, 4, 16, 8, lens, seed=4)
    out1 = ops.paged_decode_attention(q, kpg, vpg, pt, kv_len,
                                      page_size=8, backend="ref")
    # poison the trash page + every masked tail cell, rerun
    kp, ks, kz = kpg
    kp2 = kp.at[0].set(255)
    ks2 = ks.at[0].set(1e6)
    out2 = ops.paged_decode_attention(q, (kp2, ks2, kz), vpg, pt, kv_len,
                                      page_size=8, backend="ref")
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


# -- PagePool invariants ------------------------------------------------------


def test_page_pool_basics():
    pool = PagePool(9, 8)
    assert pool.capacity == 8 and pool.n_free == 8
    a = pool.alloc(3, owner=0)
    b = pool.alloc(5, owner=1)
    assert a is not None and b is not None
    assert 0 not in a + b, "trash page must never be handed out"
    assert set(a).isdisjoint(b)
    assert pool.alloc(1, owner=2) is None and pool.alloc_failures == 1
    pool.free(a)
    assert pool.n_free == 3
    with pytest.raises(ValueError):
        pool.free(a)            # double free
    assert pool.owned_by(1) == sorted(b)


def test_page_pool_invariants_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.tuples(st.booleans(), st.integers(0, 6)),
                    min_size=1, max_size=60))
    def scenario(script):
        pool = PagePool(17, 4)
        live = {}
        for i, (is_alloc, n) in enumerate(script):
            if is_alloc or not live:
                got = pool.alloc(n, owner=i)
                if n == 0:
                    assert got == []
                elif got is None:
                    assert n > pool.n_free or pool.n_free == 0 \
                        or n > 16 - sum(map(len, live.values()))
                else:
                    assert len(got) == n and 0 not in got
                    for other in live.values():
                        assert set(got).isdisjoint(other)
                    if got:
                        live[i] = got
            else:
                key = next(iter(live))
                pool.free(live.pop(key))
            in_use = sum(len(v) for v in live.values())
            assert pool.n_in_use == in_use
            assert pool.n_free == pool.capacity - in_use
            assert pool.allocs - pool.frees == in_use

    scenario()


def test_pages_needed():
    assert pages_needed(0, 8) == 1      # a slot always owns >= 1 page
    assert pages_needed(8, 8) == 1
    assert pages_needed(9, 8) == 2
    assert pages_needed(64, 16) == 4


# -- engine lifecycle ---------------------------------------------------------


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced("llama-30b")
    api = build(cfg)
    params = api.init(KEY)
    return cfg, api, params


def _reqs(cfg, lens, max_new=8, seed=0):
    rng = np.random.default_rng(seed)
    return [GenRequest(i, rng.integers(1, cfg.vocab_size,
                                       int(l)).astype(np.int32),
                       max_new_tokens=max_new)
            for i, l in enumerate(lens)]


def test_paged_chunked_matches_paged_reference(small_model):
    """The jitted multi-token scan over the pool must reproduce the
    per-step paged path token for token (same wires in, same tokens out)
    — the ``step_reference`` parity oracle for the paged engine."""
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    lens = [8, 12, 17, 24]
    kw = dict(max_slots=4, max_seq=64, paged=True, page_size=8)
    a = DecodeEngine(cfg, params, chunk_size=8, **kw)
    b = DecodeEngine(cfg, params, **kw)
    for r, w, f in pre.run(_reqs(cfg, lens, 12), backend="ref"):
        assert admit_one(a, r, f, wire=w, backend="ref")
    for r, w, f in pre.run(_reqs(cfg, lens, 12), backend="ref"):
        assert admit_one(b, r, f, wire=w, backend="ref")
    done_a, done_b = [], []
    while a.active:
        done_a += a.step()
    while b.active:
        done_b += b.step_reference()
    toks_a = {r.rid: r.out_tokens for r in done_a}
    toks_b = {r.rid: r.out_tokens for r in done_b}
    assert toks_a == toks_b
    assert all(len(t) == 12 for t in toks_a.values())


def test_paged_finish_returns_all_pages(small_model):
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    eng = DecodeEngine(cfg, params, max_slots=4, max_seq=64, chunk_size=4,
                       paged=True, page_size=8)
    total = eng.pool.n_free
    for r, w, f in pre.run(_reqs(cfg, [8, 17, 24], 6), backend="ref"):
        assert admit_one(eng, r, f, wire=w, backend="ref")
    assert eng.pool.n_in_use > 0
    while eng.active:
        eng.step()
    assert eng.pool.n_free == total and eng.pool.n_in_use == 0
    assert np.all(np.asarray(eng.cache["page_table"]) == 0)
    assert np.all(np.asarray(eng.cache["lengths"]) == 0)


def test_paged_release_mid_stream_returns_every_page(small_model):
    """A cancellation mid-decode must return the slot's ENTIRE page list
    to the pool and point its table row back at the trash page."""
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    eng = DecodeEngine(cfg, params, max_slots=2, max_seq=64, chunk_size=2,
                       paged=True, page_size=8)
    total = eng.pool.n_free
    (r, w, f), = pre.run(_reqs(cfg, [20], max_new=30), backend="ref")
    assert admit_one(eng, r, f, wire=w, backend="ref")
    held = len(eng.pool.owned_by(0))
    assert held == pages_needed(20 + 30, 8)
    eng.step()                              # mid-stream
    assert eng.release(0) is r
    assert eng.pool.n_free == total and eng.pool.n_in_use == 0
    assert np.all(np.asarray(eng.cache["page_table"][0]) == 0)
    # the freed budget is immediately re-admissible
    (r2, w2, f2), = pre.run(_reqs(cfg, [40], max_new=16, seed=3),
                            backend="ref")
    assert admit_one(eng, r2, f2, wire=w2, backend="ref")


def test_paged_admission_is_page_budget_gated(small_model):
    """With plenty of slots but a tiny pool, admission rejects the tail
    on pages, and free_slots() (what the gateway's dispatch reads) is
    truncated by the page budget."""
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    eng = DecodeEngine(cfg, params, max_slots=8, max_seq=64, paged=True,
                       page_size=8, num_pages=7)      # 6 usable pages
    wires = pre.run(_reqs(cfg, [20, 20, 20], max_new=12), backend="ref")
    rejected = admit_many(eng, wires, backend="ref")
    # each request needs ceil(32/8) = 4 pages; only one fits in 6
    assert len(rejected) == 2
    assert eng.active == 1
    assert eng.pool.alloc_failures >= 1
    assert len(eng.free_slots()) == 0, \
        "page budget exhausted: no admissible slot despite 7 free slots"
    while eng.active:
        eng.step()
    assert len(eng.free_slots()) >= 1


def test_paged_zero_dequant_inserts_from_bucketed_wire(small_model):
    """Bucketed-prefill wires carry position-aligned int4 groups: they
    must scatter into pages with NO dequant round-trip."""
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)        # bucketed
    assert pre.bucketed
    eng = DecodeEngine(cfg, params, max_slots=4, max_seq=64, paged=True,
                       page_size=8)
    for r, w, f in pre.run(_reqs(cfg, [9, 17], 4), backend="ref"):
        assert admit_one(eng, r, f, wire=w, backend="ref")
    assert eng.zero_copy_inserts > 0
    assert eng.reencoded_inserts == 0
    # raw (uncompressed) wires take the re-encode path instead
    eng2 = DecodeEngine(cfg, params, max_slots=4, max_seq=64, paged=True,
                        page_size=8)
    for r, w, f in pre.run(_reqs(cfg, [9], 4), compress=False,
                           backend="ref"):
        assert admit_one(eng2, r, f, wire=w, backend="ref")
    assert eng2.reencoded_inserts > 0 and eng2.zero_copy_inserts == 0


def test_paged_bf16_resident_decodes(small_model):
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    eng = DecodeEngine(cfg, params, max_slots=2, max_seq=64, chunk_size=4,
                       paged=True, page_size=8, kv_resident="bf16")
    done = []
    for r, w, f in pre.run(_reqs(cfg, [8, 12], 6), backend="ref"):
        assert admit_one(eng, r, f, wire=w, backend="ref")
    while eng.active:
        done += eng.step()
    assert sorted(len(r.out_tokens) for r in done) == [6, 6]
    assert eng.pool.n_in_use == 0


def test_paged_unsupported_arch_falls_back():
    cfg = get_reduced("xlstm-125m")
    params = build(cfg).init(KEY)
    eng = DecodeEngine(cfg, params, max_slots=2, max_seq=64, paged=True)
    assert not eng.paged and eng.paged_fallback
    pre = PrefillEngine(cfg, params, max_seq=64)
    (r, w, f), = pre.run(_reqs(cfg, [8], 4), backend="ref")
    assert admit_one(eng, r, f, wire=w, backend="ref")
    while eng.active:
        eng.step()
    assert len(r.out_tokens) == 4


def test_paged_pool_survives_phase_flip(small_model):
    """The pool is the DECODE-phase-owned buffer: a drained flip leaves it
    all-free, and a warm re-flip re-enters the same pool (no realloc)."""
    cfg, api, params = small_model
    rep = Replica(cfg, params, phase="decode", max_seq=64,
                  decode_kw={"max_slots": 2, "paged": True, "page_size": 8})
    pre = PrefillEngine(cfg, params, max_seq=64)
    eng = rep.engine
    pool = eng.pool
    for r, w, f in pre.run(_reqs(cfg, [8], 4), backend="ref"):
        assert admit_one(eng, r, f, wire=w, backend="ref")
    with pytest.raises(RuntimeError, match="undrained"):
        rep.switch_phase("prefill")
    while eng.active:
        eng.step()
    rep.switch_phase("prefill")
    rep.switch_phase("decode")
    assert rep.engine is eng and rep.engine.pool is pool
    assert pool.n_in_use == 0 and pool.n_free == pool.capacity


# -- cost model ---------------------------------------------------------------


def test_costmodel_page_budget_credits_compression():
    from repro.configs import get_config
    from repro.core import costmodel as cm
    from repro.core.cluster import make_paper_cloud

    cfg = get_config("llama-30b")
    cluster = make_paper_cloud()
    pc = cm.ParallelConfig(tp=8, pp=1, stages=[list(range(8, 16))],
                           layer_partition=[cfg.num_layers])
    assert cm.paged_kv_supported(cfg)
    paged_cap = cm.max_decode_batch(cluster, cfg, pc, 2048)
    # dense bf16 arithmetic for comparison
    per_seq = 2048 * cm.kv_bytes_per_token(cfg)
    budget = cm.decode_page_budget(cluster, cfg, pc)
    assert budget > 0
    dense_cap = int(budget * cm.PAGE_SIZE
                    * cm.kv_bytes_per_token(cfg, resident="int4")
                    / per_seq)
    assert paged_cap >= 1.5 * max(dense_cap, 1), (paged_cap, dense_cap)
    # int4 residency really is ~7x smaller per token
    ratio = cm.kv_bytes_per_token(cfg) / cm.kv_bytes_per_token(
        cfg, resident="int4")
    assert 3 < ratio < 8
    # recurrent archs keep dense arithmetic
    assert not cm.paged_kv_supported(get_config("jamba-v0.1-52b"))
