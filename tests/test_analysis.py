"""repro.analysis: the R001-R005 invariant linter and the REPRO_SANITIZE
runtime sanitizers (ISSUE 7).

Lint rules are exercised on synthetic source snippets through the
``lint_sources`` core (each rule fires on a bad snippet and stays quiet on
the fixed version, plus the pragma escape hatch), and the REAL tree must
lint clean under ``--strict``. Sanitizer tests plant actual faults — a
leak, a double free, a free under the wrong owner, an illegal transition,
a misaligned migration wire — and assert each is caught with a message
that names the offending site.
"""
import math
from pathlib import Path
from types import SimpleNamespace

import numpy as np
import pytest

from repro.analysis.lint import Finding, lint_sources, run_lint
from repro.analysis.sanitizers import (RetraceMonitor, SanitizerError,
                                       TransitionAuditor,
                                       check_wire_alignment,
                                       make_sanitized_pool)

REPO = Path(__file__).resolve().parents[1]


def rules_of(files, **kw):
    return [f.rule for f in lint_sources(files, **kw)]


# -- R001: wall-clock reads in serving/ ---------------------------------------


def test_r001_fires_on_direct_call():
    src = ("import time\n"
           "def pump(self):\n"
           "    now = time.time()\n"
           "    return now\n")
    fs = {"src/repro/serving/foo.py": src}
    assert rules_of(fs) == ["R001"]
    # monotonic too
    fs = {"src/repro/serving/foo.py": src.replace("time.time()",
                                                  "time.monotonic()")}
    assert rules_of(fs) == ["R001"]


def test_r001_quiet_on_injected_clock():
    src = ("import time\n"
           "class G:\n"
           "    def __init__(self, clock=time.time):\n"   # reference: fine
           "        self.clock = clock\n"
           "    def pump(self):\n"
           "        return self.clock()\n")
    assert rules_of({"src/repro/serving/foo.py": src}) == []
    # same violation outside serving/ is out of scope
    bad = "import time\nnow = time.time()\n"
    assert rules_of({"src/repro/core/foo.py": bad}) == []


def test_r001_default_factory():
    src = ("import time\n"
           "from dataclasses import dataclass, field\n"
           "@dataclass\n"
           "class H:\n"
           "    last: float = field(default_factory=time.time)\n")
    assert rules_of({"src/repro/serving/foo.py": src}) == ["R001"]


# -- R002: host syncs in jit-reachable code -----------------------------------


def test_r002_item_in_scan_body():
    src = ("from jax import lax\n"
           "def outer(xs):\n"
           "    def body(c, x):\n"
           "        n = x.item()\n"
           "        return c + n, x\n"
           "    return lax.scan(body, 0, xs)\n")
    assert rules_of({"src/repro/kernels/foo.py": src}) == ["R002"]
    # the same .item() at the host boundary (not jit-reachable) is fine
    ok = ("def summarize(x):\n"
          "    return x.item()\n")
    assert rules_of({"src/repro/kernels/foo.py": ok}) == []


def test_r002_jit_decorated_and_called():
    src = ("import jax\n"
           "import numpy as np\n"
           "@jax.jit\n"
           "def f(x):\n"
           "    return helper(x)\n"
           "def helper(x):\n"
           "    return np.asarray(x)\n")     # reachable via local call graph
    assert rules_of({"src/repro/models/foo.py": src}) == ["R002"]


def test_r002_int_cast_static_vs_device():
    bad = ("from jax import lax\n"
           "def step(lengths):\n"
           "    def body(i, c):\n"
           "        return c + int(lengths[i])\n"
           "    return lax.fori_loop(0, 4, body, 0)\n")
    assert rules_of({"src/repro/models/foo.py": bad}) == ["R002"]
    ok = ("import jax, math\n"
          "@jax.jit\n"
          "def f(x, head_dim, pct):\n"
          "    half = int(head_dim * pct) // 2 * 2\n"     # static python int
          "    b = int(x.shape[0])\n"                     # shape: static
          "    return x[:b, :half]\n")
    assert rules_of({"src/repro/models/foo.py": ok}) == []


# -- R003: replica reach-through ----------------------------------------------


def test_r003_reach_through():
    bad = "def peek(gw):\n    return gw.dec[0].engine.params\n"
    assert rules_of({"benchmarks/bench_x.py": bad}) == ["R003"]
    assert rules_of({"src/repro/serving/gateway.py": bad}) == ["R003"]
    # defining sites are allowed: self.engine / self.replica.engine
    ok = ("class C:\n"
          "    @property\n"
          "    def engine(self):\n"
          "        return self.replica.engine\n"
          "    def use(self):\n"
          "        return self.engine\n")
    assert rules_of({"src/repro/serving/gateway.py": ok}) == []
    # out of scope: the engine module itself builds engines
    assert rules_of({"src/repro/serving/engine.py": bad}) == []


def test_r003_coordinator_stays_deleted():
    imp = "from repro.serving.coordinator import Coordinator\n"
    assert rules_of({"tests/test_old.py": imp}) == ["R003"]
    assert rules_of({"src/repro/launchers.py": imp}) == ["R003"]
    imp2 = "import repro.serving.coordinator as co\n"
    assert rules_of({"benchmarks/bench_x.py": imp2}) == ["R003"]
    cls = "class Coordinator:\n    pass\n"
    assert rules_of({"src/repro/serving/coordinator.py": cls}) == ["R003"]
    # a Coordinator class OUTSIDE serving/ is somebody else's business
    assert rules_of({"src/repro/core/foo.py": cls}) == []
    ok = "from repro.serving.gateway import Gateway\n"
    assert rules_of({"tests/test_new.py": ok}) == []


# -- R004: FAILED/REJECTED must carry a reason --------------------------------


def test_r004_reason_required():
    bad = ("def kill(h, now, FAILED='FAILED'):\n"
           "    h._transition(FAILED, now)\n")
    assert rules_of({"src/repro/serving/gateway.py": bad}) == ["R004"]
    ok = ("def kill(h, now, FAILED='FAILED'):\n"
          "    h._transition(FAILED, now, reason='replica died')\n")
    assert rules_of({"src/repro/serving/gateway.py": ok}) == []


def test_r004_direct_state_assign():
    bad = ("def hack(h, FAILED='FAILED'):\n"
           "    h.state = FAILED\n")
    assert rules_of({"src/repro/serving/gateway.py": bad}) == ["R004"]


# -- R005: layout lockstep ----------------------------------------------------


def test_r005_local_group_tuple():
    bad = "MY_GROUPS = (128, 64, 32, 16, 8, 4, 2)\n"
    assert rules_of({"src/repro/serving/new_wire.py": bad}) == ["R005"]
    # tuples of non-ints (device groups) are not the layout contract
    ok = "GROUPS = ((0, 1), (2, 3))\n"
    assert rules_of({"src/repro/serving/new_wire.py": ok}) == []


def test_r005_local_selection_and_nibbles():
    sel = ("from repro.kernels.kv_layout import GROUPS\n"
           "def pick(span):\n"
           "    return next((g for g in GROUPS if span % g == 0), 0)\n")
    assert rules_of({"src/repro/serving/new_wire.py": sel}) == ["R005"]
    nib = "def unpack(p):\n    return (p & 0xF), (p >> 4)\n"
    assert set(rules_of({"src/repro/models/new_paged.py": nib})) == {"R005"}
    # the layout module itself is exempt from the nibble rule (but must
    # keep defining GROUPS)
    layout = "GROUPS = (128, 64, 32, 16, 8, 4, 2)\n" + nib
    assert rules_of({"src/repro/kernels/kv_layout.py": layout}) == []


def test_r005_consumer_must_import_contract():
    # a kv_transfer.py that grew its own copy and dropped the import
    rogue = ("_GROUPS2 = [128, 64]\n"
             "def _pick_group(span):\n"
             "    return 128\n")
    found = lint_sources({"src/repro/serving/kv_transfer.py": rogue})
    assert any(f.rule == "R005" and "import" in f.message for f in found)


# -- R006: pool refcount internals --------------------------------------------


def test_r006_pool_internal_reach():
    bad = ("def steal(pool, p):\n"
           "    pool._owners.pop(p)\n"
           "    pool._free.append(p)\n")
    assert rules_of({"src/repro/serving/engine.py": bad}) == ["R006", "R006"]
    assert rules_of({"benchmarks/bench_x.py": bad}) == ["R006", "R006"]
    # the pool module itself is the defining site
    assert rules_of({"src/repro/serving/page_pool.py": bad}) == []
    # self._owners inside a PagePool subclass (sanitizer pool) is fine
    ok = ("class SanitizedPagePool:\n"
          "    def check_empty(self):\n"
          "        assert not self._owners\n")
    assert rules_of({"src/repro/analysis/sanitizers.py": ok}) == []
    # the public API never fires
    api = ("def audit(pool):\n"
           "    return pool.owned_by(0), pool.owners_of(1), pool.refcount(1)\n")
    assert rules_of({"src/repro/serving/engine.py": api}) == []


# -- pragmas ------------------------------------------------------------------


def test_pragma_suppresses_and_strict_flags_unused():
    bad = ("import time\n"
           "now = time.time()  # repro: ignore[R001]\n")
    assert rules_of({"src/repro/serving/foo.py": bad}) == []
    above = ("import time\n"
             "# repro: ignore[R001]\n"
             "now = time.time()\n")
    assert rules_of({"src/repro/serving/foo.py": above}) == []
    # wrong rule id does not suppress
    wrong = ("import time\n"
             "now = time.time()  # repro: ignore[R003]\n")
    assert rules_of({"src/repro/serving/foo.py": wrong},
                    strict=False) == ["R001"]
    # strict: the R003 pragma above suppressed nothing -> W001 (+ the R001)
    assert sorted(rules_of({"src/repro/serving/foo.py": wrong},
                           strict=True)) == ["R001", "W001"]


def test_finding_format_carries_hint():
    f = Finding("R001", "a.py", 3, 1, "boom", "fix it")
    assert "a.py:3:1: R001 boom" in f.format()
    assert "fix it" in f.format()


def test_real_tree_is_clean_strict():
    assert run_lint(REPO, strict=True) == []


# -- sanitizers: page pool ----------------------------------------------------


def test_sanitized_pool_double_free_names_both_sites():
    pool = make_sanitized_pool(8, 4)
    pages = pool.alloc(2, 0)
    pool.free(pages, owner=0)
    with pytest.raises(SanitizerError, match="already freed"):
        pool.free(pages, owner=0)


def test_sanitized_pool_wrong_owner():
    pool = make_sanitized_pool(8, 4)
    pages = pool.alloc(1, 0)
    with pytest.raises(SanitizerError, match="owned by slot 0"):
        pool.free(pages, owner=3)
    # the refused free must not have mutated anything
    assert pool.n_in_use == 1
    pool.free(pages, owner=0)
    assert pool.n_in_use == 0


def test_sanitized_pool_leak_report_has_alloc_site():
    pool = make_sanitized_pool(8, 4)
    pool.alloc(1, 5)
    with pytest.raises(SanitizerError, match="page leak"):
        pool.check_empty("teardown")


def test_plain_pool_double_free_is_atomic():
    """Satellite 2: even without sanitize mode, a bad free() raises and
    leaves the free list uncorrupted (no partial free)."""
    from repro.serving.page_pool import PagePool
    pool = PagePool(8, 4)
    a = pool.alloc(2, 0)
    b = pool.alloc(2, 1)
    pool.free(b)
    before_free, before_use = pool.n_free, pool.n_in_use
    with pytest.raises(ValueError, match="double free"):
        pool.free(a + b[:1])          # b[0] already free: whole call refused
    assert (pool.n_free, pool.n_in_use) == (before_free, before_use)
    with pytest.raises(ValueError, match="listed twice"):
        pool.free([a[0], a[0]])
    with pytest.raises(ValueError, match="owned by slot 0"):
        pool.free(a, owner=9)
    pool.free(a, owner=0)             # the good free still works
    assert pool.n_in_use == 0


# -- sanitizers: state machine ------------------------------------------------


def _fake_handle(chain, state=None, reason=None, rid=7):
    hist = [(float(i), s) for i, s in enumerate(chain)]
    return SimpleNamespace(request=SimpleNamespace(rid=rid), state=state
                           or chain[-1], history=hist, reason=reason)


def test_auditor_accepts_legal_lifecycles():
    aud = TransitionAuditor()
    aud.audit(_fake_handle(["QUEUED", "PREFILLING", "TRANSFERRING",
                            "DECODING", "DONE"]))
    # preemption migration + failure requeue edges are legal
    aud.audit(_fake_handle(["QUEUED", "PREFILLING", "TRANSFERRING",
                            "DECODING", "TRANSFERRING", "DECODING",
                            "QUEUED", "PREFILLING", "TRANSFERRING",
                            "DECODING", "DONE"]))
    aud.audit(_fake_handle(["QUEUED", "REJECTED"], reason="deadline"))
    assert aud.audited == 3 and aud.illegal == 0


def test_auditor_catches_illegal_edge():
    aud = TransitionAuditor()
    with pytest.raises(SanitizerError, match="illegal transition "
                                             "QUEUED -> DONE"):
        aud.audit(_fake_handle(["QUEUED", "DONE"]))


def test_auditor_catches_state_assigned_around_transition():
    aud = TransitionAuditor()
    h = _fake_handle(["QUEUED", "PREFILLING"], state="DONE")
    with pytest.raises(SanitizerError, match="without _transition"):
        aud.audit(h)


def test_auditor_requires_reason_on_failed():
    aud = TransitionAuditor()
    with pytest.raises(SanitizerError, match="no reason"):
        aud.audit(_fake_handle(["QUEUED", "FAILED"]))


# -- sanitizers: retrace monitor ----------------------------------------------


def test_retrace_monitor_flags_growth():
    sizes = {"n": 3}
    client = SimpleNamespace(jit_cache_size=lambda: sizes["n"])
    gw = SimpleNamespace(pre=[], dec=[SimpleNamespace(client=client)])
    mon = RetraceMonitor()
    mon.mark_steady(gw)
    mon.check(gw)                      # stable: fine
    sizes["n"] = 5
    with pytest.raises(SanitizerError, match="retrace"):
        mon.check(gw, context="steady state")


# -- sanitizers: wire alignment -----------------------------------------------


def test_misaligned_wire_caught():
    from repro.configs import get_reduced
    from repro.serving.kv_transfer import KVWire, WireTensor
    cfg = get_reduced("llama-30b")
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    span = Hkv * hd
    ln, L = 4, 1
    # aligned: group g | span, rows = L*ln*ppr
    from repro.kernels.kv_layout import pick_group
    g = pick_group(span)
    ppr = span // g
    mk = lambda rows, gg: WireTensor("int4", {
        "packed": np.zeros((rows, gg // 2), np.uint8),
        "scale": np.zeros((rows, 1), np.float32),
        "zero": np.zeros((rows, 1), np.float32)}, (L, ln, Hkv, hd))
    good = KVWire(request_len=ln,
                  slots={"slot0": {"k": mk(L * ln * ppr, g),
                                   "v": mk(L * ln * ppr, g)}})
    check_wire_alignment(good, cfg)    # no raise
    # wrong group width (half the page group): what an exact-length
    # extract with a flattened-size pick can produce
    bad = KVWire(request_len=ln,
                 slots={"slot0": {"k": mk(L * ln * ppr * 2, g // 2),
                                  "v": mk(L * ln * ppr * 2, g // 2)}})
    with pytest.raises(SanitizerError, match="misaligned migration wire"):
        check_wire_alignment(bad, cfg, context="test")


# -- end to end: sanitized gateway runs ---------------------------------------


@pytest.fixture(scope="module")
def small_model():
    import jax
    from repro.configs import get_reduced
    from repro.models import build
    cfg = get_reduced("llama-30b")
    api = build(cfg)
    return cfg, api.init(jax.random.PRNGKey(0))


def _mk_gw(cfg, params, *, paged, clock=None, n_dec=1):
    from repro.serving.engine import DecodeEngine, PrefillEngine
    from repro.serving.gateway import Gateway
    pre = PrefillEngine(cfg, params, max_seq=64)
    dkw = dict(max_slots=2, chunk_size=2, max_seq=64)
    if paged:
        dkw.update(paged=True, page_size=8)
    decs = [DecodeEngine(cfg, params, **dkw) for _ in range(n_dec)]
    kw = {"clock": clock} if clock is not None else {}
    return Gateway([pre], decs, backend="ref", **kw)


def _reqs(cfg, n, *, max_new=4, plen=12):
    from repro.serving.gateway import ServeRequest
    rng = np.random.default_rng(0)
    return [ServeRequest(i, rng.integers(1, cfg.vocab_size, plen)
                         .astype(np.int32), max_new) for i in range(n)]


def test_sanitized_gateway_clean_run_and_planted_leak(small_model,
                                                      monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    cfg, params = small_model
    gw = _mk_gw(cfg, params, paged=True)
    assert gw.sanitizer is not None
    for r in _reqs(cfg, 3):
        gw.submit(r)
    done = gw.run_until_drained()      # drain runs sanitize_check: clean
    assert len(done) == 3 and all(h.state == "DONE" for h in done)
    st = gw.stats()
    assert st["page_pool"]["leaked_pages"] == 0
    assert st["sanitizer"]["transitions_audited"] >= 3
    assert st["sanitizer"]["transition_violations"] == 0
    # plant a leak: pages the pool owns but no slot references
    eng = gw.dec[0].engine
    eng.pool.alloc(1, 0)
    assert gw.stats()["page_pool"]["leaked_pages"] == 1
    with pytest.raises(SanitizerError, match="leaked page"):
        gw.sanitize_check("test")


def test_sanitized_engine_double_free_caught(small_model, monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    cfg, params = small_model
    gw = _mk_gw(cfg, params, paged=True)
    for r in _reqs(cfg, 1):
        gw.submit(r)
    gw.run_until_drained()
    eng = gw.dec[0].engine
    pages = eng.pool.alloc(2, 0)
    eng.pool.free(pages, owner=0)
    with pytest.raises(SanitizerError, match="already freed at"):
        eng.pool.free(pages, owner=0)  # the exact double-free site named


def test_sanitized_gateway_catches_tampered_history(small_model,
                                                    monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    cfg, params = small_model
    gw = _mk_gw(cfg, params, paged=False)
    h = gw.submit(_reqs(cfg, 1)[0])
    gw.run_until_drained()
    # tamper: rewrite history to a QUEUED -> DONE jump (the class of bug
    # R004 + the auditor exist for: state bypassing the machine)
    h.history = [h.history[0], h.history[-1]]
    with pytest.raises(SanitizerError, match="illegal transition"):
        gw.sanitize_check("tampered")


def test_virtual_clock_run_touches_wall_clock_zero_times(small_model,
                                                         monkeypatch):
    """Satellite 1 regression: with an injected VirtualClock, a full
    submit -> prefill -> transfer -> decode -> drain cycle performs ZERO
    wall-clock reads anywhere in serving/ (time.time in each serving
    module's namespace is replaced by a tripwire)."""
    from repro.serving import faults as faults_mod
    from repro.serving import gateway as gateway_mod
    from repro.serving import profiler as profiler_mod
    from repro.serving import transport as transport_mod

    calls = {"n": 0}

    def tripwire(*a, **k):
        calls["n"] += 1
        raise AssertionError("wall clock touched during virtual-clock run")

    fake_time = SimpleNamespace(time=tripwire, monotonic=tripwire,
                                sleep=tripwire)
    for mod in (gateway_mod, transport_mod, profiler_mod, faults_mod):
        monkeypatch.setattr(mod, "time", fake_time)

    cfg, params = small_model
    clk = faults_mod.VirtualClock(1000.0)
    gw = _mk_gw(cfg, params, paged=True, clock=clk)
    for r in _reqs(cfg, 2):
        gw.submit(r)
    done = gw.run_until_drained()
    assert len(done) == 2 and all(h.state == "DONE" for h in done)
    assert calls["n"] == 0
    # every recorded timestamp sits on the virtual timeline
    for h in done:
        assert all(t >= 1000.0 for t, _ in h.history)
