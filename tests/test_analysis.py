"""repro.analysis: the R001-R008 invariant linter, the explicit-state
protocol model checker, and the REPRO_SANITIZE runtime sanitizers
(ISSUEs 7 and 10).

Lint rules are exercised on synthetic source snippets through the
``lint_sources`` core (each rule fires on a bad snippet and stays quiet on
the fixed version, plus the pragma escape hatch), and the REAL tree must
lint clean under ``--strict``. The model checker must explore all three
protocol models violation-free on the clean tree, catch every planted
mutation with a counterexample that replays on the real code, and flag
drift between ``protocol.TRANSITIONS`` and the sanitizer's independent
copy. Sanitizer tests plant actual faults — a leak, a double free
(including mid-chunk under chunked prefill), a free under the wrong
owner, an illegal transition, a misaligned migration wire — and assert
each is caught with a message that names the offending site.
"""
import math
from pathlib import Path
from types import SimpleNamespace

import numpy as np
import pytest

from repro.analysis.lint import Finding, lint_sources, run_lint
from repro.analysis.sanitizers import (RetraceMonitor, SanitizerError,
                                       TransitionAuditor,
                                       check_wire_alignment,
                                       make_sanitized_pool)

REPO = Path(__file__).resolve().parents[1]


def rules_of(files, **kw):
    return [f.rule for f in lint_sources(files, **kw)]


# -- R001: wall-clock reads in serving/ ---------------------------------------


def test_r001_fires_on_direct_call():
    src = ("import time\n"
           "def pump(self):\n"
           "    now = time.time()\n"
           "    return now\n")
    fs = {"src/repro/serving/foo.py": src}
    assert rules_of(fs) == ["R001"]
    # monotonic too
    fs = {"src/repro/serving/foo.py": src.replace("time.time()",
                                                  "time.monotonic()")}
    assert rules_of(fs) == ["R001"]


def test_r001_quiet_on_injected_clock():
    src = ("import time\n"
           "class G:\n"
           "    def __init__(self, clock=time.time):\n"   # reference: fine
           "        self.clock = clock\n"
           "    def pump(self):\n"
           "        return self.clock()\n")
    assert rules_of({"src/repro/serving/foo.py": src}) == []
    # same violation outside serving/ is out of scope
    bad = "import time\nnow = time.time()\n"
    assert rules_of({"src/repro/core/foo.py": bad}) == []


def test_r001_default_factory():
    src = ("import time\n"
           "from dataclasses import dataclass, field\n"
           "@dataclass\n"
           "class H:\n"
           "    last: float = field(default_factory=time.time)\n")
    assert rules_of({"src/repro/serving/foo.py": src}) == ["R001"]


# -- R002: host syncs in jit-reachable code -----------------------------------


def test_r002_item_in_scan_body():
    src = ("from jax import lax\n"
           "def outer(xs):\n"
           "    def body(c, x):\n"
           "        n = x.item()\n"
           "        return c + n, x\n"
           "    return lax.scan(body, 0, xs)\n")
    assert rules_of({"src/repro/kernels/foo.py": src}) == ["R002"]
    # the same .item() at the host boundary (not jit-reachable) is fine
    ok = ("def summarize(x):\n"
          "    return x.item()\n")
    assert rules_of({"src/repro/kernels/foo.py": ok}) == []


def test_r002_jit_decorated_and_called():
    src = ("import jax\n"
           "import numpy as np\n"
           "@jax.jit\n"
           "def f(x):\n"
           "    return helper(x)\n"
           "def helper(x):\n"
           "    return np.asarray(x)\n")     # reachable via local call graph
    assert rules_of({"src/repro/models/foo.py": src}) == ["R002"]


def test_r002_int_cast_static_vs_device():
    bad = ("from jax import lax\n"
           "def step(lengths):\n"
           "    def body(i, c):\n"
           "        return c + int(lengths[i])\n"
           "    return lax.fori_loop(0, 4, body, 0)\n")
    assert rules_of({"src/repro/models/foo.py": bad}) == ["R002"]
    ok = ("import jax, math\n"
          "@jax.jit\n"
          "def f(x, head_dim, pct):\n"
          "    half = int(head_dim * pct) // 2 * 2\n"     # static python int
          "    b = int(x.shape[0])\n"                     # shape: static
          "    return x[:b, :half]\n")
    assert rules_of({"src/repro/models/foo.py": ok}) == []


# -- R003: replica reach-through ----------------------------------------------


def test_r003_reach_through():
    bad = "def peek(gw):\n    return gw.dec[0].engine.params\n"
    assert rules_of({"benchmarks/bench_x.py": bad}) == ["R003"]
    assert rules_of({"src/repro/serving/gateway.py": bad}) == ["R003"]
    # defining sites are allowed: self.engine / self.replica.engine
    ok = ("class C:\n"
          "    @property\n"
          "    def engine(self):\n"
          "        return self.replica.engine\n"
          "    def use(self):\n"
          "        return self.engine\n")
    assert rules_of({"src/repro/serving/gateway.py": ok}) == []
    # out of scope: the engine module itself builds engines
    assert rules_of({"src/repro/serving/engine.py": bad}) == []


def test_r003_coordinator_stays_deleted():
    imp = "from repro.serving.coordinator import Coordinator\n"
    assert rules_of({"tests/test_old.py": imp}) == ["R003"]
    assert rules_of({"src/repro/launchers.py": imp}) == ["R003"]
    imp2 = "import repro.serving.coordinator as co\n"
    assert rules_of({"benchmarks/bench_x.py": imp2}) == ["R003"]
    cls = "class Coordinator:\n    pass\n"
    assert rules_of({"src/repro/serving/coordinator.py": cls}) == ["R003"]
    # a Coordinator class OUTSIDE serving/ is somebody else's business
    assert rules_of({"src/repro/core/foo.py": cls}) == []
    ok = "from repro.serving.gateway import Gateway\n"
    assert rules_of({"tests/test_new.py": ok}) == []


# -- R004: FAILED/REJECTED must carry a reason --------------------------------


def test_r004_reason_required():
    bad = ("def kill(h, now, FAILED='FAILED'):\n"
           "    h._transition(FAILED, now)\n")
    assert rules_of({"src/repro/serving/gateway.py": bad}) == ["R004"]
    ok = ("def kill(h, now, FAILED='FAILED'):\n"
          "    h._transition(FAILED, now, reason='replica died')\n")
    assert rules_of({"src/repro/serving/gateway.py": ok}) == []


def test_r004_direct_state_assign():
    bad = ("def hack(h, FAILED='FAILED'):\n"
           "    h.state = FAILED\n")
    assert rules_of({"src/repro/serving/gateway.py": bad}) == ["R004"]


# -- R005: layout lockstep ----------------------------------------------------


def test_r005_local_group_tuple():
    bad = "MY_GROUPS = (128, 64, 32, 16, 8, 4, 2)\n"
    assert rules_of({"src/repro/serving/new_wire.py": bad}) == ["R005"]
    # tuples of non-ints (device groups) are not the layout contract
    ok = "GROUPS = ((0, 1), (2, 3))\n"
    assert rules_of({"src/repro/serving/new_wire.py": ok}) == []


def test_r005_local_selection_and_nibbles():
    sel = ("from repro.kernels.kv_layout import GROUPS\n"
           "def pick(span):\n"
           "    return next((g for g in GROUPS if span % g == 0), 0)\n")
    assert rules_of({"src/repro/serving/new_wire.py": sel}) == ["R005"]
    nib = "def unpack(p):\n    return (p & 0xF), (p >> 4)\n"
    assert set(rules_of({"src/repro/models/new_paged.py": nib})) == {"R005"}
    # the layout module itself is exempt from the nibble rule (but must
    # keep defining GROUPS)
    layout = "GROUPS = (128, 64, 32, 16, 8, 4, 2)\n" + nib
    assert rules_of({"src/repro/kernels/kv_layout.py": layout}) == []


def test_r005_consumer_must_import_contract():
    # a kv_transfer.py that grew its own copy and dropped the import
    rogue = ("_GROUPS2 = [128, 64]\n"
             "def _pick_group(span):\n"
             "    return 128\n")
    found = lint_sources({"src/repro/serving/kv_transfer.py": rogue})
    assert any(f.rule == "R005" and "import" in f.message for f in found)


# -- R006: pool refcount internals --------------------------------------------


def test_r006_pool_internal_reach():
    bad = ("def steal(pool, p):\n"
           "    pool._owners.pop(p)\n"
           "    pool._free.append(p)\n")
    assert rules_of({"src/repro/serving/engine.py": bad}) == ["R006", "R006"]
    assert rules_of({"benchmarks/bench_x.py": bad}) == ["R006", "R006"]
    # the pool module itself is the defining site
    assert rules_of({"src/repro/serving/page_pool.py": bad}) == []
    # self._owners inside a PagePool subclass (sanitizer pool) is fine
    ok = ("class SanitizedPagePool:\n"
          "    def check_empty(self):\n"
          "        assert not self._owners\n")
    assert rules_of({"src/repro/analysis/sanitizers.py": ok}) == []
    # the public API never fires
    api = ("def audit(pool):\n"
           "    return pool.owned_by(0), pool.owners_of(1), pool.refcount(1)\n")
    assert rules_of({"src/repro/serving/engine.py": api}) == []


# -- pragmas ------------------------------------------------------------------


def test_pragma_suppresses_and_strict_flags_unused():
    bad = ("import time\n"
           "now = time.time()  # repro: " "ignore[R001]\n")
    assert rules_of({"src/repro/serving/foo.py": bad}) == []
    above = ("import time\n"
             "# repro: " "ignore[R001]\n"
             "now = time.time()\n")
    assert rules_of({"src/repro/serving/foo.py": above}) == []
    # wrong rule id does not suppress
    wrong = ("import time\n"
             "now = time.time()  # repro: " "ignore[R003]\n")
    assert rules_of({"src/repro/serving/foo.py": wrong},
                    strict=False) == ["R001"]
    # strict: the R003 pragma above suppressed nothing -> W001 (+ the R001)
    assert sorted(rules_of({"src/repro/serving/foo.py": wrong},
                           strict=True)) == ["R001", "W001"]


def test_finding_format_carries_hint():
    f = Finding("R001", "a.py", 3, 1, "boom", "fix it")
    assert "a.py:3:1: R001 boom" in f.format()
    assert "fix it" in f.format()


def test_real_tree_is_clean_strict():
    assert run_lint(REPO, strict=True) == []


# -- sanitizers: page pool ----------------------------------------------------


def test_sanitized_pool_double_free_names_both_sites():
    pool = make_sanitized_pool(8, 4)
    pages = pool.alloc(2, 0)
    pool.free(pages, owner=0)
    with pytest.raises(SanitizerError, match="already freed"):
        pool.free(pages, owner=0)


def test_sanitized_pool_wrong_owner():
    pool = make_sanitized_pool(8, 4)
    pages = pool.alloc(1, 0)
    with pytest.raises(SanitizerError, match="owned by slot 0"):
        pool.free(pages, owner=3)
    # the refused free must not have mutated anything
    assert pool.n_in_use == 1
    pool.free(pages, owner=0)
    assert pool.n_in_use == 0


def test_sanitized_pool_leak_report_has_alloc_site():
    pool = make_sanitized_pool(8, 4)
    pool.alloc(1, 5)
    with pytest.raises(SanitizerError, match="page leak"):
        pool.check_empty("teardown")


def test_plain_pool_double_free_is_atomic():
    """Satellite 2: even without sanitize mode, a bad free() raises and
    leaves the free list uncorrupted (no partial free)."""
    from repro.serving.page_pool import PagePool
    pool = PagePool(8, 4)
    a = pool.alloc(2, 0)
    b = pool.alloc(2, 1)
    pool.free(b)
    before_free, before_use = pool.n_free, pool.n_in_use
    with pytest.raises(ValueError, match="double free"):
        pool.free(a + b[:1])          # b[0] already free: whole call refused
    assert (pool.n_free, pool.n_in_use) == (before_free, before_use)
    with pytest.raises(ValueError, match="listed twice"):
        pool.free([a[0], a[0]])
    with pytest.raises(ValueError, match="owned by slot 0"):
        pool.free(a, owner=9)
    pool.free(a, owner=0)             # the good free still works
    assert pool.n_in_use == 0


# -- sanitizers: state machine ------------------------------------------------


def _fake_handle(chain, state=None, reason=None, rid=7):
    hist = [(float(i), s) for i, s in enumerate(chain)]
    return SimpleNamespace(request=SimpleNamespace(rid=rid), state=state
                           or chain[-1], history=hist, reason=reason)


def test_auditor_accepts_legal_lifecycles():
    aud = TransitionAuditor()
    aud.audit(_fake_handle(["QUEUED", "PREFILLING", "TRANSFERRING",
                            "DECODING", "DONE"]))
    # preemption migration + failure requeue edges are legal
    aud.audit(_fake_handle(["QUEUED", "PREFILLING", "TRANSFERRING",
                            "DECODING", "TRANSFERRING", "DECODING",
                            "QUEUED", "PREFILLING", "TRANSFERRING",
                            "DECODING", "DONE"]))
    aud.audit(_fake_handle(["QUEUED", "REJECTED"], reason="deadline"))
    assert aud.audited == 3 and aud.illegal == 0


def test_auditor_catches_illegal_edge():
    aud = TransitionAuditor()
    with pytest.raises(SanitizerError, match="illegal transition "
                                             "QUEUED -> DONE"):
        aud.audit(_fake_handle(["QUEUED", "DONE"]))


def test_auditor_catches_state_assigned_around_transition():
    aud = TransitionAuditor()
    h = _fake_handle(["QUEUED", "PREFILLING"], state="DONE")
    with pytest.raises(SanitizerError, match="without _transition"):
        aud.audit(h)


def test_auditor_requires_reason_on_failed():
    aud = TransitionAuditor()
    with pytest.raises(SanitizerError, match="no reason"):
        aud.audit(_fake_handle(["QUEUED", "FAILED"]))


# -- sanitizers: retrace monitor ----------------------------------------------


def test_retrace_monitor_flags_growth():
    sizes = {"n": 3}
    client = SimpleNamespace(jit_cache_size=lambda: sizes["n"])
    gw = SimpleNamespace(pre=[], dec=[SimpleNamespace(client=client)])
    mon = RetraceMonitor()
    mon.mark_steady(gw)
    mon.check(gw)                      # stable: fine
    sizes["n"] = 5
    with pytest.raises(SanitizerError, match="retrace"):
        mon.check(gw, context="steady state")


# -- sanitizers: wire alignment -----------------------------------------------


def test_misaligned_wire_caught():
    from repro.configs import get_reduced
    from repro.serving.kv_transfer import KVWire, WireTensor
    cfg = get_reduced("llama-30b")
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    span = Hkv * hd
    ln, L = 4, 1
    # aligned: group g | span, rows = L*ln*ppr
    from repro.kernels.kv_layout import pick_group
    g = pick_group(span)
    ppr = span // g
    mk = lambda rows, gg: WireTensor("int4", {
        "packed": np.zeros((rows, gg // 2), np.uint8),
        "scale": np.zeros((rows, 1), np.float32),
        "zero": np.zeros((rows, 1), np.float32)}, (L, ln, Hkv, hd))
    good = KVWire(request_len=ln,
                  slots={"slot0": {"k": mk(L * ln * ppr, g),
                                   "v": mk(L * ln * ppr, g)}})
    check_wire_alignment(good, cfg)    # no raise
    # wrong group width (half the page group): what an exact-length
    # extract with a flattened-size pick can produce
    bad = KVWire(request_len=ln,
                 slots={"slot0": {"k": mk(L * ln * ppr * 2, g // 2),
                                  "v": mk(L * ln * ppr * 2, g // 2)}})
    with pytest.raises(SanitizerError, match="misaligned migration wire"):
        check_wire_alignment(bad, cfg, context="test")


# -- end to end: sanitized gateway runs ---------------------------------------


@pytest.fixture(scope="module")
def small_model():
    import jax
    from repro.configs import get_reduced
    from repro.models import build
    cfg = get_reduced("llama-30b")
    api = build(cfg)
    return cfg, api.init(jax.random.PRNGKey(0))


def _mk_gw(cfg, params, *, paged, clock=None, n_dec=1):
    from repro.serving.engine import DecodeEngine, PrefillEngine
    from repro.serving.gateway import Gateway
    pre = PrefillEngine(cfg, params, max_seq=64)
    dkw = dict(max_slots=2, chunk_size=2, max_seq=64)
    if paged:
        dkw.update(paged=True, page_size=8)
    decs = [DecodeEngine(cfg, params, **dkw) for _ in range(n_dec)]
    kw = {"clock": clock} if clock is not None else {}
    return Gateway([pre], decs, backend="ref", **kw)


def _reqs(cfg, n, *, max_new=4, plen=12):
    from repro.serving.gateway import ServeRequest
    rng = np.random.default_rng(0)
    return [ServeRequest(i, rng.integers(1, cfg.vocab_size, plen)
                         .astype(np.int32), max_new) for i in range(n)]


def test_sanitized_gateway_clean_run_and_planted_leak(small_model,
                                                      monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    cfg, params = small_model
    gw = _mk_gw(cfg, params, paged=True)
    assert gw.sanitizer is not None
    for r in _reqs(cfg, 3):
        gw.submit(r)
    done = gw.run_until_drained()      # drain runs sanitize_check: clean
    assert len(done) == 3 and all(h.state == "DONE" for h in done)
    st = gw.stats()
    assert st["page_pool"]["leaked_pages"] == 0
    assert st["sanitizer"]["transitions_audited"] >= 3
    assert st["sanitizer"]["transition_violations"] == 0
    # plant a leak: pages the pool owns but no slot references
    eng = gw.dec[0].engine
    eng.pool.alloc(1, 0)
    assert gw.stats()["page_pool"]["leaked_pages"] == 1
    with pytest.raises(SanitizerError, match="leaked page"):
        gw.sanitize_check("test")


def test_sanitized_engine_double_free_caught(small_model, monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    cfg, params = small_model
    gw = _mk_gw(cfg, params, paged=True)
    for r in _reqs(cfg, 1):
        gw.submit(r)
    gw.run_until_drained()
    eng = gw.dec[0].engine
    pages = eng.pool.alloc(2, 0)
    eng.pool.free(pages, owner=0)
    with pytest.raises(SanitizerError, match="already freed at"):
        eng.pool.free(pages, owner=0)  # the exact double-free site named


def test_sanitized_gateway_catches_tampered_history(small_model,
                                                    monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    cfg, params = small_model
    gw = _mk_gw(cfg, params, paged=False)
    h = gw.submit(_reqs(cfg, 1)[0])
    gw.run_until_drained()
    # tamper: rewrite history to a QUEUED -> DONE jump (the class of bug
    # R004 + the auditor exist for: state bypassing the machine)
    h.history = [h.history[0], h.history[-1]]
    with pytest.raises(SanitizerError, match="illegal transition"):
        gw.sanitize_check("tampered")


def test_virtual_clock_run_touches_wall_clock_zero_times(small_model,
                                                         monkeypatch):
    """Satellite 1 regression: with an injected VirtualClock, a full
    submit -> prefill -> transfer -> decode -> drain cycle performs ZERO
    wall-clock reads anywhere in serving/ (time.time in each serving
    module's namespace is replaced by a tripwire)."""
    from repro.serving import faults as faults_mod
    from repro.serving import gateway as gateway_mod
    from repro.serving import profiler as profiler_mod
    from repro.serving import transport as transport_mod

    calls = {"n": 0}

    def tripwire(*a, **k):
        calls["n"] += 1
        raise AssertionError("wall clock touched during virtual-clock run")

    fake_time = SimpleNamespace(time=tripwire, monotonic=tripwire,
                                sleep=tripwire)
    for mod in (gateway_mod, transport_mod, profiler_mod, faults_mod):
        monkeypatch.setattr(mod, "time", fake_time)

    cfg, params = small_model
    clk = faults_mod.VirtualClock(1000.0)
    gw = _mk_gw(cfg, params, paged=True, clock=clk)
    for r in _reqs(cfg, 2):
        gw.submit(r)
    done = gw.run_until_drained()
    assert len(done) == 2 and all(h.state == "DONE" for h in done)
    assert calls["n"] == 0
    # every recorded timestamp sits on the virtual timeline
    for h in done:
        assert all(t >= 1000.0 for t, _ in h.history)


# -- R007: quantize once, over the spliced whole ------------------------------


def test_r007_double_quantization():
    bad = ("from repro.serving.kv_transfer import compress_wire\n"
           "def f(x):\n"
           "    w = compress_wire(x)\n"
           "    return compress_wire(w)\n")
    assert rules_of({"src/repro/serving/foo.py": bad}) == ["R007"]
    ok = ("from repro.serving.kv_transfer import compress_wire\n"
          "def f(x):\n"
          "    return compress_wire(x)\n")
    assert rules_of({"src/repro/serving/foo.py": ok}) == []


def test_r007_quantized_run_output_requantized():
    # pre.run() defaults to compress=True: its wires are already quantized
    bad = ("def f(pre, reqs):\n"
           "    for r, w, first in pre.run(reqs, backend='ref'):\n"
           "        yield compress_wire(w)\n")
    assert rules_of({"src/repro/serving/foo.py": bad}) == ["R007"]
    # compress=False marks the wire raw: quantizing it once is the point
    ok = ("def f(pre, reqs):\n"
          "    for r, w, first in pre.run(reqs, compress=False):\n"
          "        yield compress_wire(w)\n")
    assert rules_of({"src/repro/serving/foo.py": ok}) == []


def test_r007_per_chunk_quantization():
    # quantizing chunk-by-chunk breaks the resumable raw prefix AND pays
    # a second quantization at splice time — the exact bug the
    # chunk-per-chunk-quant mutation plants
    bad = ("def f(job):\n"
           "    out = []\n"
           "    for chunk in job.wires:\n"
           "        out.append(compress_wire(chunk))\n"
           "    return out\n")
    fs = lint_sources({"src/repro/serving/foo.py": bad})
    assert [f.rule for f in fs] == ["R007"]
    assert "concat_wires" in (fs[0].hint or "")
    # the sanctioned shape: splice the raw chunks, quantize the whole once
    ok = ("from repro.serving.kv_transfer import (compress_wire,\n"
          "                                       concat_wires)\n"
          "def f(job):\n"
          "    whole = concat_wires(job.wires)\n"
          "    return compress_wire(whole)\n")
    assert rules_of({"src/repro/serving/foo.py": ok}) == []


def test_r007_quantized_chunk_appended_to_wire_list():
    bad = ("def f(job, x):\n"
           "    job.wires.append(compress_wire(x))\n")
    assert rules_of({"src/repro/serving/foo.py": bad}) == ["R007"]
    ok = ("def f(job, x):\n"
          "    job.wires.append(extract_kv(x, compress=False))\n")
    assert rules_of({"src/repro/serving/foo.py": ok}) == []


def test_r007_protocol_hook_marks_raw():
    # the engine's real idiom: compression gated on the PROTOCOL hook, so
    # the model checker and the dataflow rule see the same policy
    ok = ("from repro.serving import protocol\n"
          "def f(pre, job, x):\n"
          "    w = extract_kv(x, compress=protocol.chunk_extract_compress())\n"
          "    job.wires.append(w)\n")
    assert rules_of({"src/repro/serving/engine2.py": ok}) == []


# -- R008: wire-layout arithmetic only in the layout modules ------------------


def test_r008_wire_construction_outside_layout_modules():
    bad = ("from repro.serving.kv_transfer import KVWire\n"
           "def f(ln, slots):\n"
           "    return KVWire(request_len=ln, slots=slots)\n")
    assert rules_of({"src/repro/serving/gateway2.py": bad}) == ["R008"]
    # the layout modules themselves are blessed (R005's separate
    # import-the-contract obligation still applies there, hence `not in`)
    assert "R008" not in rules_of({"src/repro/serving/kv_transfer.py": bad})
    assert "R008" not in rules_of({"src/repro/models/paged.py": bad})


def test_r008_row_math_and_payload_splices():
    row_math = ("def f(L, ln, ppr):\n"
                "    return L * ln * ppr\n")
    assert rules_of({"src/repro/serving/foo.py": row_math}) == ["R008"]
    splice = ("import numpy as np\n"
              "def f(tensors):\n"
              "    return np.concatenate([t.payload for t in tensors])\n")
    assert rules_of({"src/repro/serving/foo.py": splice}) == ["R008"]
    gpt = ("def f(cfg):\n"
           "    return groups_per_token(cfg)\n")
    assert rules_of({"src/repro/serving/foo.py": gpt}) == ["R008"]
    # kernels/ own the layout definition: out of scope by design
    assert rules_of({"src/repro/kernels/foo.py": row_math}) == []
    # ordinary arithmetic that never touches layout names is fine
    ok = ("def f(a, b):\n"
          "    return a * b + len([a])\n")
    assert rules_of({"src/repro/serving/foo.py": ok}) == []


# -- R003 extension: deleted admission shims stay deleted ---------------------


def test_r003_admit_shims_banned():
    for shim in ("admit_batch", "admit_prefix", "admit_migrated"):
        call = f"def f(eng, x):\n    return eng.{shim}(x)\n"
        fs = lint_sources({"benchmarks/bench_x.py": call})
        assert [f.rule for f in fs] == ["R003"], shim
        assert "AdmissionBatch" in (fs[0].hint or "")
        redef = f"class E:\n    def {shim}(self, x):\n        pass\n"
        assert rules_of(
            {"src/repro/serving/engine2.py": redef}) == ["R003"], shim
    # the unified entry point itself is fine everywhere
    ok = ("def f(eng, batch):\n"
          "    return eng.admit(batch, backend='ref')\n")
    assert rules_of({"benchmarks/bench_x.py": ok}) == []
    assert rules_of({"src/repro/serving/engine2.py": ok}) == []


# -- CLI: --format json / github ----------------------------------------------


def _violating_repo(tmp_path):
    d = tmp_path / "src" / "repro" / "serving"
    d.mkdir(parents=True)
    (d / "foo.py").write_text("import time\nnow = time.time()\n")
    return tmp_path


def test_cli_format_json(tmp_path, capsys):
    import json
    from repro.analysis.__main__ import main
    root = _violating_repo(tmp_path)
    rc = main(["lint", "--root", str(root), "--format", "json"])
    assert rc == 1
    out = json.loads(capsys.readouterr().out)
    assert len(out) == 1
    f = out[0]
    assert f["rule"] == "R001" and f["line"] == 2
    assert f["path"].endswith("src/repro/serving/foo.py")
    assert set(f) == {"rule", "path", "line", "col", "message", "hint"}


def test_cli_format_github(tmp_path, capsys):
    from repro.analysis.__main__ import main
    root = _violating_repo(tmp_path)
    rc = main(["lint", "--root", str(root), "--format", "github"])
    assert rc == 1
    lines = capsys.readouterr().out.strip().splitlines()
    assert len(lines) == 1
    assert lines[0].startswith("::error file=")
    assert "line=2" in lines[0] and "title=R001" in lines[0]


def test_cli_format_json_clean_is_empty_list(tmp_path, capsys):
    import json
    from repro.analysis.__main__ import main
    (tmp_path / "src" / "repro").mkdir(parents=True)
    rc = main(["lint", "--root", str(tmp_path), "--format", "json"])
    assert rc == 0
    assert json.loads(capsys.readouterr().out) == []


# -- model checker: clean tree, mutations, drift, replay ----------------------


def test_modelcheck_clean_tree_explores_all_models():
    from repro.analysis import modelcheck as mc
    results = mc.run_check(quick=True)
    assert sorted(r.model for r in results) == \
        ["chunkedprefill", "lifecycle", "pagepool"]
    for r in results:
        assert r.ok, f"{r.model}: {[v.message for v in r.violations]}"
        assert r.states > 1 and r.transitions > 0


def test_modelcheck_mutation_harness_catches_every_planted_bug():
    from repro.analysis import modelcheck as mc
    muts = mc.run_mutations(depth=10)
    assert len(muts) >= 6
    for m in muts:
        assert m.caught, f"checker missed planted bug {m.name}"
        assert m.message, m.name
        # every counterexample must replay: pool/chunk traces re-execute
        # against the real PagePool / protocol hooks (stdlib); lifecycle
        # replay is the jax-gated opt-in exercised separately
        if m.model in ("pagepool", "chunkedprefill"):
            assert m.replayed is True, \
                f"{m.name}: counterexample did not reproduce on real code"
            assert m.trace, m.name
        else:
            assert m.replayed is None
    # the mutated models must still be restored: the tree checks clean
    assert all(r.ok for r in mc.run_check(quick=True))


def test_modelcheck_lifecycle_replay_through_real_gateway():
    """Lifecycle counterexamples replay through the REAL RequestHandle
    under a VirtualClock with REPRO_SANITIZE on — the missing-edge traces
    found by the model must raise in gateway._transition too."""
    pytest.importorskip("jax")
    from repro.analysis import modelcheck as mc
    muts = mc.run_mutations(depth=8, lifecycle_replay=True)
    life = [m for m in muts if m.model == "lifecycle"]
    assert life, "no lifecycle mutations planted"
    for m in life:
        assert m.caught and m.replayed is True, \
            f"{m.name}: trace {m.trace} did not reproduce on RequestHandle"


def test_modelcheck_clean_traces_do_not_reproduce():
    # replay is a real check, not a rubber stamp: on the UNMUTATED tree a
    # legal trace replays silently (returns None, no violation)
    from repro.analysis import modelcheck as mc
    assert mc.replay_trace(
        "pagepool", ["admit_fresh_mid:0", "retire:0"]) is None
    assert mc.replay_trace(
        "chunkedprefill", ["advance", "advance", "advance"]) is None


def test_modelcheck_transition_table_drift(monkeypatch):
    from repro.analysis import modelcheck as mc
    from repro.analysis import sanitizers as san
    assert mc.check_table_drift() == []
    # the sanitizer's independent copy loses an edge -> drift flagged
    pruned = dict(san._LEGAL)
    pruned["DECODING"] = tuple(
        s for s in pruned["DECODING"] if s != "TRANSFERRING")
    monkeypatch.setattr(san, "_LEGAL", pruned)
    viols = mc.check_table_drift()
    assert len(viols) == 1 and "DECODING" in viols[0].message


def test_modelcheck_explore_reports_counterexample_trace():
    # a minimal inline model with a planted unreachable-event bug: the
    # trace in the violation must be the exact event path to the failure
    from repro.analysis import modelcheck as mc

    class Toy:
        name = "toy"

        def initial(self):
            return 0

        def events(self, s):
            return ("inc",) if s < 3 else ()

        def apply(self, s, ev):
            return s + 1

        def invariants(self, s):
            return ["hit three"] if s == 3 else []

    res = mc.explore(Toy(), depth=5)
    assert not res.ok
    assert res.violations[0].trace == ("inc", "inc", "inc")


# -- sanitizers under chunked prefill (satellite 4) ---------------------------


def _mk_chunked_gw(cfg, params, *, chunk_tokens=8):
    from repro.serving.engine import DecodeEngine, PrefillEngine
    from repro.serving.gateway import Gateway, SchedulerConfig
    pre = PrefillEngine(cfg, params, max_seq=64)
    dec = DecodeEngine(cfg, params, max_slots=2, chunk_size=2, max_seq=64,
                       paged=True, page_size=8)
    return Gateway([pre], [dec], backend="ref",
                   scheduler=SchedulerConfig(
                       prefill_chunk_tokens=chunk_tokens))


def test_retrace_monitor_quiet_across_chunk_boundaries(small_model,
                                                       monkeypatch):
    """Chunked prefill must not churn decode jit caches: prompts long
    enough to cross several chunk boundaries drain with the retrace
    monitor armed, and the steady-state check stays silent."""
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    from repro.serving.gateway import warmup_gateway
    cfg, params = small_model
    gw = _mk_chunked_gw(cfg, params, chunk_tokens=8)
    warmup_gateway(gw, cfg.vocab_size, prompt_lens=(24,))
    for r in _reqs(cfg, 3, plen=24):          # 24 tokens / 8 = 3 chunks
        gw.submit(r)
    done = gw.run_until_drained()             # drain runs sanitize_check
    assert len(done) == 3 and all(h.state == "DONE" for h in done)
    assert gw.stats()["counters"]["chunked_prefills"] >= 3
    gw.sanitizer.retrace.check(gw, context="post-chunked-drain")  # no raise


def test_mid_chunk_double_free_caught_by_sanitized_pool(small_model,
                                                        monkeypatch):
    """A double free planted WHILE a chunked prefill is mid-flight (the
    job paused between chunks, decode pool live) is caught by the
    sanitized pool with both sites named — chunking must not open a
    window where pool bookkeeping goes unaudited."""
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    from repro.serving.engine import (DecodeEngine, GenRequest,
                                      PartialPrefill, PrefillEngine)
    cfg, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    eng = DecodeEngine(cfg, params, max_slots=2, max_seq=64,
                       paged=True, page_size=8)
    rng = np.random.default_rng(0)
    job = PartialPrefill(GenRequest(0, rng.integers(
        1, cfg.vocab_size, 24).astype(np.int32), 4))
    pre.prefill_chunk([job], 8, backend="ref")
    assert not job.done                        # mid-chunk: 8/24 prefilled
    pages = eng.pool.alloc(2, 0)
    eng.pool.free(pages, owner=0)
    with pytest.raises(SanitizerError, match="already freed"):
        eng.pool.free(pages, owner=0)
    # the job is still resumable after the refused free
    while not job.done:
        pre.prefill_chunk([job], 8, backend="ref")
    assert job.wire() is not None
