"""Serving API v2: request lifecycle through the gateway — streaming
before drain, cancellation freeing decode slots, deadline-based admission
control, decode-replica failure re-queueing handles (DECODING -> QUEUED),
transport-delayed TTFT, priority dispatch, and the legacy GenRequest
submit path."""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build
from repro.serving.engine import DecodeEngine, GenRequest, PrefillEngine
from repro.serving.gateway import (CANCELLED, DECODING, DONE, QUEUED,
                                   REJECTED, TRANSFERRING, Gateway,
                                   RequestHandle, SchedulerConfig,
                                   ServeRequest)
from repro.serving.transport import InProcessTransport, SimNetworkTransport

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced("llama-30b")
    api = build(cfg)
    params = api.init(KEY)
    return cfg, api, params


def _prompt(cfg, n=12, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, n).astype(np.int32)


def _gw(cfg, params, *, n_dec=2, max_slots=4, chunk_size=4, transport=None):
    pre = PrefillEngine(cfg, params, max_seq=64)
    decs = [DecodeEngine(cfg, params, max_slots=max_slots, max_seq=64,
                         chunk_size=chunk_size) for _ in range(n_dec)]
    return Gateway([pre], decs, transport=transport, backend="ref")


# -- streaming ----------------------------------------------------------------


def test_tokens_stream_before_drained(small_model):
    """First tokens must be observable (callback + handle.tokens) while
    the run is still in flight — long before run_until_drained returns."""
    cfg, api, params = small_model
    gw = _gw(cfg, params)
    got = []
    hs = [gw.submit(ServeRequest(i, _prompt(cfg, 8 + 4 * i, seed=i),
                                 max_new_tokens=12),
                    on_token=lambda h, t: got.append((h.request.rid, t)))
          for i in range(3)]
    for _ in range(3):
        gw.pump()
        if any(h.tokens for h in hs):
            break
    streaming = [h for h in hs if h.tokens]
    assert streaming, "no tokens streamed while requests in flight"
    assert any(not h.is_terminal for h in hs), \
        "tokens must arrive before the system drains"
    assert got, "on_token callback must fire as chunks complete"
    n_seen = {h.request.rid: len(h.tokens) for h in hs}
    done = gw.run_until_drained()
    assert len(done) == 3 and all(h.state == DONE for h in hs)
    for h in hs:
        # the stream accumulated (not replaced) and matches the engine
        assert len(h.tokens) == 12 >= n_seen[h.request.rid]
        assert h.tokens == h.req.out_tokens
        assert [t for r, t in got if r == h.request.rid] == h.tokens


def test_handle_metrics_and_history(small_model):
    cfg, api, params = small_model
    gw = _gw(cfg, params)
    h = gw.submit(ServeRequest(0, _prompt(cfg), max_new_tokens=6))
    gw.run_until_drained()
    assert h.state == DONE
    # TTFT/TPOT/E2E are reported by the handle (engines stamp nothing)
    assert h.t_done >= h.t_first >= h.t_submit
    assert h.e2e >= h.ttft >= 0 and h.tpot >= 0
    m = h.metrics()
    assert m["state"] == DONE and m["n_tokens"] == 6
    assert m["ttft_met"] and m["e2e_met"]
    # canonical lifecycle order
    states = [s for _, s in h.history]
    assert states == [QUEUED, "PREFILLING", TRANSFERRING, DECODING, DONE]


def test_stream_iterator_drives_gateway(small_model):
    cfg, api, params = small_model
    gw = _gw(cfg, params)
    h = gw.submit(ServeRequest(0, _prompt(cfg), max_new_tokens=5))
    assert list(h.stream()) == h.tokens and len(h.tokens) == 5
    assert h.state == DONE


def test_illegal_transition_raises(small_model):
    cfg, api, params = small_model
    gw = _gw(cfg, params)
    h = gw.submit(ServeRequest(0, _prompt(cfg), max_new_tokens=2))
    with pytest.raises(RuntimeError, match="illegal transition"):
        h._transition(DONE)


# -- cancellation -------------------------------------------------------------


def test_cancel_queued_request(small_model):
    cfg, api, params = small_model
    gw = _gw(cfg, params)
    h = gw.submit(ServeRequest(0, _prompt(cfg), max_new_tokens=4))
    assert h.cancel()
    assert h.state == CANCELLED and not gw.queue
    assert not h.cancel(), "terminal handles cannot be re-cancelled"
    assert gw.run_until_drained() == [h]


def test_cancel_mid_decode_frees_slot(small_model):
    """Cancelling a DECODING request must release its slot (and zero the
    slot's cache length) so a waiting request can take it."""
    cfg, api, params = small_model
    gw = _gw(cfg, params, n_dec=1, max_slots=1, chunk_size=2)
    h1 = gw.submit(ServeRequest(0, _prompt(cfg), max_new_tokens=64))
    h2 = gw.submit(ServeRequest(1, _prompt(cfg, seed=3), max_new_tokens=4))
    while h1.state != DECODING:
        gw.pump()
    eng = gw.dec[0].engine
    assert eng.slots[0] is h1.req and h2.state != DECODING
    assert h1.cancel()
    assert h1.state == CANCELLED
    assert eng.slots[0] is None, "cancel must free the decode slot"
    assert int(eng.cache["lengths"][0]) == 0, \
        "cancel must zero the released slot's cache length"
    n1 = len(h1.tokens)
    gw.run_until_drained()
    assert h2.state == DONE and len(h2.tokens) == 4
    assert len(h1.tokens) == n1, "cancelled stream must not keep growing"


# -- deadline admission control ----------------------------------------------


def test_deadline_rejection_emits_reason(small_model):
    cfg, api, params = small_model
    gw = _gw(cfg, params)
    h = gw.submit(ServeRequest(0, _prompt(cfg), max_new_tokens=4,
                               ttft_deadline_s=0.005))
    ok = gw.submit(ServeRequest(1, _prompt(cfg, seed=2), max_new_tokens=4))
    time.sleep(0.02)        # the deadline passes while still queued
    gw.pump()
    assert h.state == REJECTED
    assert h.reason and "deadline" in h.reason
    assert h in gw.done and not h.tokens
    assert any("rejected" in e for e in gw.events)
    gw.run_until_drained()
    assert ok.state == DONE, "undeadlined request must be unaffected"


# -- failure recovery ---------------------------------------------------------


def test_decode_failure_requeues_handles(small_model):
    """Replica death mid-decode: handles transition DECODING -> QUEUED
    (visible in history / restarts — not a silent restart) and finish on
    the survivor."""
    cfg, api, params = small_model
    gw = _gw(cfg, params, n_dec=2, max_slots=4, chunk_size=2)
    streamed = {i: 0 for i in range(4)}

    def count(h, tok):
        streamed[h.request.rid] += 1

    hs = [gw.submit(ServeRequest(i, _prompt(cfg, 8 + 2 * i, seed=i),
                                 max_new_tokens=24), on_token=count)
          for i in range(4)]
    while not any(h.state == DECODING for h in hs):
        gw.pump()
    victim = next(d for d in gw.dec if d.client.active)
    resident = set(map(id, victim.client.resident()))
    gw.kill_replica("decode", victim.idx)
    hit = [h for h in hs if id(h.req) in resident]
    assert hit, "killed replica held no requests"
    for h in hit:
        assert h.state == QUEUED and h.restarts == 1
        states = [s for _, s in h.history]
        assert states[-2:] == [DECODING, QUEUED]
    assert any("re-queued" in e for e in gw.events)
    gw.run_until_drained()
    assert all(h.state == DONE and len(h.tokens) == 24 for h in hs)
    # the restarted attempt regenerates the delivered prefix: streaming
    # consumers must NOT see duplicate tokens
    assert streamed == {i: 24 for i in range(4)}


def test_open_loop_idle_gap_is_not_replica_death(small_model):
    """A traffic gap longer than the heartbeat timeout must not read as
    fleet death: the driver keeps replicas beating while it sleeps."""
    from repro.serving.gateway import drive_open_loop

    cfg, api, params = small_model
    gw = _gw(cfg, params, n_dec=1)
    gw.heartbeat_timeout = 0.25
    arrivals = [(0.0, ServeRequest(0, _prompt(cfg), max_new_tokens=4)),
                (0.6, ServeRequest(1, _prompt(cfg, seed=1),
                                   max_new_tokens=4))]
    handles = drive_open_loop(gw, arrivals)
    assert [h.state for h in handles] == [DONE, DONE]
    assert not any("timed out" in e for e in gw.events)


# -- transport ----------------------------------------------------------------


def test_sim_transport_delays_first_token(small_model):
    """A simulated network hop gates decode admission: the wire is not
    consumable before its alpha-beta arrival time, so TTFT includes the
    hop (and the payload crossed the explicit host boundary)."""
    cfg, api, params = small_model
    alpha = 0.15
    tr = SimNetworkTransport(alpha=alpha, bandwidth=1e12)
    gw = _gw(cfg, params, n_dec=1, transport=tr)
    h = gw.submit(ServeRequest(0, _prompt(cfg), max_new_tokens=4))
    gw.pump()
    assert h.state == TRANSFERRING and not h.tokens, \
        "wire must not arrive before the simulated hop completes"
    assert len(gw.transfer_queue) == 1
    wire = gw.transfer_queue[0].ticket.wire
    for s in wire.slots.values():
        for t in s.values():
            for a in t.payload.values():
                assert isinstance(a, np.ndarray), \
                    "sim transport must materialize payloads to the host"
    gw.run_until_drained()
    assert h.state == DONE
    assert h.ttft >= alpha, f"TTFT {h.ttft:.3f}s must include the hop"
    assert tr.transfers == 1 and tr.mean_delay_s >= alpha


def test_inproc_transport_keeps_device_arrays(small_model):
    """In-process hop: immediately ready, payloads stay device arrays, and
    one pump carries a request all the way into decode (no gating)."""
    import jax as _jax

    from repro.serving import kv_transfer

    cfg, api, params = small_model
    tokens = _jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    _, cache = api.prefill(params, {"tokens": tokens}, max_seq=32)
    wire = kv_transfer.extract(cache, 0, 16, backend="ref")
    ticket = InProcessTransport().send(wire, 0, 0)
    assert ticket.ready() and ticket.delay_s == 0.0
    assert any(isinstance(a, _jax.Array)
               for s in ticket.wire.slots.values() for t in s.values()
               for a in t.payload.values()), \
        "in-process transport must not pull payloads to the host"
    gw = _gw(cfg, params, transport=InProcessTransport())
    h = gw.submit(ServeRequest(0, _prompt(cfg), max_new_tokens=8))
    gw.pump()
    assert h.state in (DECODING, DONE) and h.tokens, \
        "in-process wire must be consumable within the same pump"


# -- priority -----------------------------------------------------------------


def test_priority_dispatches_first(small_model):
    cfg, api, params = small_model
    gw = _gw(cfg, params)
    lo = gw.submit(ServeRequest(0, _prompt(cfg), max_new_tokens=4,
                                priority=0))
    hi = gw.submit(ServeRequest(1, _prompt(cfg, seed=5), max_new_tokens=4,
                                priority=5))
    gw.pump(max_prefill_batch=1)
    assert lo.state == QUEUED, "low priority must wait"
    assert hi.state in (DECODING, DONE) and hi.tokens
    gw.run_until_drained()
    assert lo.state == DONE and hi.state == DONE


# -- legacy GenRequest submit + scheduler config ------------------------------


def test_genrequest_submit_path_and_scheduler_config(small_model):
    """Bare GenRequest submit still works (no deadlines/priority) and the
    SchedulerConfig defaults reproduce the legacy one-shot behavior."""
    cfg, api, params = small_model
    gw = Gateway([PrefillEngine(cfg, params, max_seq=64)],
                 [DecodeEngine(cfg, params, max_slots=2, max_seq=64)],
                 backend="ref")
    assert gw.scheduler == SchedulerConfig()
    assert gw.scheduler.prefill_chunk_tokens == 0   # one-shot prefill
    req = GenRequest(0, _prompt(cfg), max_new_tokens=4)
    h = gw.submit(req)
    done = gw.run_until_drained()
    assert done == [h] and h.req is req
    assert len(req.out_tokens) == 4
    assert h.t_done >= h.t_first >= h.t_submit > 0
    assert gw.stats()["counters"]["chunked_prefills"] == 0
