"""Prefix-sharing KV cache (DESIGN.md §10, ISSUE 8).

Covers: the radix index (page-aligned matching, the generated-token trust
rule for full hits, LRU eviction that respects outside refcounts), the
refcounted ``PagePool`` (a shared page never returns to the free list
while any owner holds it; interleaved alloc/share/free/unshare conserves
pages — both as a seeded deterministic sweep and under hypothesis when
available), and the engine-level warm paths: a full prefix hit decodes
BIT-IDENTICAL tokens to a cold run with copy-on-write never mutating the
shared pages, the partial-hit suffix prefill splices onto the shared
chain, and a preempted COW slot migrates to another replica without
perturbing the stream.
"""
import random

import jax
import numpy as np
import pytest

from conftest import admit_one

from repro.configs import get_reduced
from repro.models import build
from repro.serving.engine import (ADMIT_MIGRATED, ADMIT_PREFIX_HIT,
                                  AdmissionBatch, AdmissionItem,
                                  DecodeEngine, GenRequest, PrefillEngine)
from repro.serving.page_pool import PagePool, pages_needed
from repro.serving.prefix_cache import PREFIX_OWNER, PrefixCache

KEY = jax.random.PRNGKey(0)
PS = 8


# -- radix index --------------------------------------------------------------


def _donate(cache, pool, tokens, kv_len, gen_from, owner=0):
    """Alloc a chain under ``owner``, donate it, release the donor ref —
    exactly the engine's _retire_slot ordering."""
    pages = pool.alloc(pages_needed(kv_len, PS), owner)
    cache.insert(tokens, kv_len, pages, gen_from, pool)
    pool.free(pages, owner=owner)
    return pages


def test_radix_full_hit_needs_generated_continuation():
    pool = PagePool(32, PS)
    cache = PrefixCache(PS)
    prompt = list(range(100, 116))            # 16 tokens = 2 pages exactly
    out = [7, 8, 9, 10]                       # generated
    pages = _donate(cache, pool, prompt + out, 19, gen_from=16)

    # full prompt: continuation is the donor's first GENERATED token
    m = cache.match(prompt)
    assert m is not None and m.full
    assert m.length == 16 and m.next_token == 7
    assert m.pages == pages[:2]

    # a prompt ending mid-page inside the donor's PROMPT region: those
    # tokens are arbitrary user text, never a trusted continuation —
    # only a page-aligned partial hit
    m = cache.match(prompt[:12])
    assert m is not None and not m.full
    assert m.length == 8 and m.pages == pages[:1]

    # a prompt ending inside the GENERATED region: full hit mid-page
    m = cache.match(prompt + out[:2])
    assert m is not None and m.full and m.next_token == 9
    assert m.pages == pages[:3]

    # diverging first page: miss
    assert cache.match([1, 2, 3] + prompt) is None
    # a one-page prompt with no trusted continuation: partial needs k>=1
    # AND at least one suffix token, so an exact-page prompt with no
    # generated child collapses to a miss rather than length==prompt
    assert cache.match(prompt[:8]) is None or not cache.match(prompt[:8]).full


def test_radix_donations_dedupe_and_merge_gen_flags():
    pool = PagePool(32, PS)
    cache = PrefixCache(PS)
    prompt = list(range(16))
    _donate(cache, pool, prompt + [77], 16, gen_from=16, owner=0)
    before = pool.n_in_use
    # a second donor with the same prompt adds no pages (duplicates are
    # not adopted; its own refs were released by the donor)
    _donate(cache, pool, prompt + [77], 16, gen_from=16, owner=1)
    assert pool.n_in_use == before
    assert cache.stats()["entries"] == 2
    # the trailing emitted token past the resident KV is a trusted
    # continuation: the full prompt now scores a FULL hit
    m = cache.match(prompt)
    assert m is not None and m.full and m.next_token == 77


def test_radix_lru_eviction_respects_external_refs():
    pool = PagePool(16, PS)
    cache = PrefixCache(PS)
    a = _donate(cache, pool, list(range(16)), 16, 16, owner=0)
    b = _donate(cache, pool, list(range(50, 66)), 16, 16, owner=1)
    assert pool.n_in_use == 4
    # chain a was touched less recently -> refresh it, pin b's head page
    cache.match(list(range(16)))
    pool.share([b[0]], "pin")
    # first eviction takes the LRU leaf (b's tail)
    assert cache.evict(pool, 1) == 1
    assert pool.refcount(b[1]) == 0
    # full sweep: b's head survives under the external pin
    cache.evict(pool, 10)
    assert pool.pages_in_use() == [b[0]]
    assert pool.owners_of(b[0]) == {PREFIX_OWNER, "pin"}
    # once unpinned it becomes evictable
    pool.unshare([b[0]], "pin")
    assert cache.evict(pool, 10) == 1
    assert pool.n_in_use == 0 and cache.stats()["entries"] == 0


def test_radix_clear_keeps_externally_shared_pages():
    pool = PagePool(16, PS)
    cache = PrefixCache(PS)
    a = _donate(cache, pool, list(range(16)), 16, 16, owner=0)
    pool.share(a, 3)                          # a slot decoding off the chain
    cache.clear(pool)
    assert cache.stats()["entries"] == 0
    assert pool.pages_in_use() == sorted(a)   # survive under the slot
    pool.free(a, owner=3)
    assert pool.n_in_use == 0


# -- refcounted pool invariants -----------------------------------------------


def test_shared_page_never_freed_while_referenced():
    pool = PagePool(6, 4)
    pages = pool.alloc(2, 0)
    pool.share(pages, "cache")
    pool.free(pages, owner=0)                 # one ref down, page stays
    assert pool.n_in_use == 2
    # drain the free list: the shared pages are never handed out again
    rest = pool.alloc(pool.n_free, 1)
    assert set(rest).isdisjoint(pages)
    assert pool.alloc(1, 2) is None
    pool.unshare(pages, "cache")              # last ref: now truly free
    assert pool.n_free == 2
    got = pool.alloc(2, 3)
    assert sorted(got) == sorted(pages)


def test_refcount_interleaving_conserves_pages():
    """Seeded random interleaving of alloc/share/free against a mirror
    model: the pool's counts must track the mirror exactly, and
    free + in_use must equal capacity after every operation."""
    rng = random.Random(0)
    pool = PagePool(17, 4)
    refs = {}                                 # page -> set of owners
    for step in range(3000):
        r = rng.random()
        if r < 0.45:
            n = rng.randint(0, 4)
            owner = ("o", step)
            got = pool.alloc(n, owner)
            if got is not None:
                for p in got:
                    assert p not in refs, "allocated a live page"
                    refs[p] = {owner}
            else:
                assert n > pool.n_free
        elif r < 0.65 and refs:
            p = rng.choice(sorted(refs))
            owner = ("s", step)
            pool.share([p], owner)
            refs[p].add(owner)
        elif refs:
            p = rng.choice(sorted(refs))
            owner = rng.choice(sorted(refs[p], key=repr))
            pool.free([p], owner=owner)
            refs[p].discard(owner)
            if not refs[p]:
                del refs[p]
        assert pool.n_in_use == len(refs)
        assert pool.n_free == pool.capacity - len(refs)
        assert pool.n_shared == sum(1 for s in refs.values() if len(s) > 1)
    for p in sorted(refs):
        assert pool.refcount(p) == len(refs[p])
        assert pool.owners_of(p) == frozenset(refs[p])


def test_refcount_invariants_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 5)),
                    min_size=1, max_size=80))
    def scenario(script):
        pool = PagePool(13, 4)
        refs = {}
        for i, (kind, n) in enumerate(script):
            if kind == 0:
                got = pool.alloc(n, ("a", i))
                if got is not None:
                    for p in got:
                        refs[p] = {("a", i)}
            elif kind == 1 and refs:
                p = sorted(refs)[n % len(refs)]
                pool.share([p], ("s", i))
                refs[p].add(("s", i))
            elif kind == 2 and refs:
                p = sorted(refs)[n % len(refs)]
                o = sorted(refs[p], key=repr)[0]
                pool.free([p], owner=o)
                refs[p].discard(o)
                if not refs[p]:
                    del refs[p]
            assert pool.n_in_use == len(refs)
            assert pool.n_free == pool.capacity - len(refs)

    scenario()


# -- engine warm paths --------------------------------------------------------


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced("llama-30b")
    api = build(cfg)
    params = api.init(KEY)
    return cfg, api, params


def _mk_eng(cfg, params, **kw):
    base = dict(max_slots=4, max_seq=64, chunk_size=4, paged=True,
                page_size=PS, prefix_sharing=True)
    base.update(kw)
    return DecodeEngine(cfg, params, **base)


def _run_cold(cfg, params, pre, prompt, max_new, rid=0):
    eng = DecodeEngine(cfg, params, max_slots=2, max_seq=64, chunk_size=4,
                       paged=True, page_size=PS)
    r = GenRequest(rid, prompt.copy(), max_new_tokens=max_new)
    (rr, w, f), = pre.run([r], backend="ref")
    assert admit_one(eng, rr, f, wire=w, backend="ref")
    while eng.active:
        eng.step()
    return list(r.out_tokens)


def _wire_payloads(wire):
    out = []
    for name in sorted(wire.slots):
        for key in sorted(wire.slots[name]):
            wt = wire.slots[name][key]
            for k in sorted(wt.payload):
                out.append(np.asarray(wt.payload[k]).copy())
    return out


def test_full_hit_bit_identical_with_cow(small_model):
    """A full prefix hit (prefill skipped, decode straight off the shared
    chain, COW on the mid-page tail) must emit EXACTLY the cold run's
    tokens, and the shared pages' bytes must be untouched afterwards."""
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    rng = np.random.default_rng(7)
    prompt = rng.integers(1, cfg.vocab_size, 12).astype(np.int32)
    cold = _run_cold(cfg, params, pre, prompt, 6)

    eng = _mk_eng(cfg, params)
    r1 = GenRequest(1, prompt.copy(), max_new_tokens=6)
    (rr, w, f), = pre.run([r1], backend="ref")
    assert admit_one(eng, rr, f, wire=w, backend="ref")
    while eng.active:
        eng.step()
    assert list(r1.out_tokens) == cold        # donor == cold already

    m = eng.prefix_match(prompt)
    assert m is not None and m.full and m.length == 12
    assert m.next_token == cold[0]
    tag = ("prefix-pin", 2)
    assert eng.prefix_pin(m.pages, tag)
    before = _wire_payloads(eng.extract_prefix(m.pages, m.length))

    r2 = GenRequest(2, prompt.copy(), max_new_tokens=6)
    assert admit_one(eng, r2, m.next_token, pages=list(m.pages),
                 source=ADMIT_PREFIX_HIT)
    eng.prefix_unpin(tag)
    # prompt ends mid-page (12 into page 1): exactly one COW copy
    assert eng.cow_copies == 1
    while eng.active:
        eng.step()
    assert list(r2.out_tokens) == cold, "warm full hit must be bit-identical"

    after = _wire_payloads(eng.extract_prefix(m.pages, m.length))
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)
    st = eng.page_stats()
    assert st["leaked_pages"] == 0
    assert st["prefix_admits"] == 1 and st["cow_copies"] == 1


def test_page_aligned_full_hit_needs_no_cow(small_model):
    """A prompt ending ON a page boundary appends into a fresh page: the
    chain shares every prefix page and copies nothing."""
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    rng = np.random.default_rng(9)
    prompt = rng.integers(1, cfg.vocab_size, 16).astype(np.int32)
    eng = _mk_eng(cfg, params)
    r1 = GenRequest(1, prompt.copy(), max_new_tokens=5)
    (rr, w, f), = pre.run([r1], backend="ref")
    assert admit_one(eng, rr, f, wire=w, backend="ref")
    while eng.active:
        eng.step()
    cold = list(r1.out_tokens)
    m = eng.prefix_match(prompt)
    assert m is not None and m.full and m.pages and len(m.pages) == 2
    r2 = GenRequest(2, prompt.copy(), max_new_tokens=5)
    assert admit_one(eng, r2, m.next_token, pages=list(m.pages),
                 source=ADMIT_PREFIX_HIT)
    assert eng.cow_copies == 0
    while eng.active:
        eng.step()
    assert list(r2.out_tokens) == cold


def test_partial_hit_suffix_prefill_splices_shared_chain(small_model):
    """A partial hit prefills only the suffix against the dequantized
    shared prefix and splices onto the chain at the page boundary. Token
    parity with the cold run is checked exactly — the prefix KV bytes the
    warm path reads are the same int4 pages the cold path would produce
    for the identical prefix tokens."""
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    assert pre.supports_suffix
    rng = np.random.default_rng(11)
    base = rng.integers(1, cfg.vocab_size, 24).astype(np.int32)
    eng = _mk_eng(cfg, params)
    r1 = GenRequest(1, base.copy(), max_new_tokens=4)
    (rr, w, f), = pre.run([r1], backend="ref")
    assert admit_one(eng, rr, f, wire=w, backend="ref")
    while eng.active:
        eng.step()

    # same 16-token (2-page) prefix, fresh suffix
    prompt2 = np.concatenate([
        base[:16], rng.integers(1, cfg.vocab_size, 9).astype(np.int32)])
    cold = _run_cold(cfg, params, pre, prompt2, 6, rid=9)

    m = eng.prefix_match(prompt2)
    assert m is not None and not m.full
    assert m.length == 16 and len(m.pages) == 2
    tag = ("prefix-pin", 2)
    assert eng.prefix_pin(m.pages, tag)
    r2 = GenRequest(2, prompt2.copy(), max_new_tokens=6,
                    start_pos=m.length, prefix_pages=list(m.pages))
    r2.prefix_wire = eng.extract_prefix(m.pages, m.length)
    (rr2, w2, f2), = pre.run([r2], backend="ref")
    assert w2.request_len == len(prompt2) - 16, "wire covers only the suffix"
    assert admit_one(eng, rr2, f2, wire=w2, backend="ref")
    eng.prefix_unpin(tag)
    while eng.active:
        eng.step()
    assert list(r2.out_tokens) == cold
    assert eng.page_stats()["leaked_pages"] == 0


def test_cow_slot_migrates_bit_identical(small_model):
    """Preemption drain of a warm (full-hit, COW) slot: the migrated
    stream finishes on another replica with the cold run's tokens."""
    cfg, api, params = small_model
    pre = PrefillEngine(cfg, params, max_seq=64)
    rng = np.random.default_rng(13)
    prompt = rng.integers(1, cfg.vocab_size, 12).astype(np.int32)
    cold = _run_cold(cfg, params, pre, prompt, 8)

    eng_a = _mk_eng(cfg, params, chunk_size=2)
    r1 = GenRequest(1, prompt.copy(), max_new_tokens=8)
    (rr, w, f), = pre.run([r1], backend="ref")
    assert admit_one(eng_a, rr, f, wire=w, backend="ref")
    while eng_a.active:
        eng_a.step()
    m = eng_a.prefix_match(prompt)
    assert m is not None and m.full
    r2 = GenRequest(2, prompt.copy(), max_new_tokens=8)
    assert admit_one(eng_a, r2, m.next_token, pages=list(m.pages),
                 source=ADMIT_PREFIX_HIT)
    assert eng_a.cow_copies == 1
    eng_a.step()                              # mid-stream (2 more tokens)
    assert 0 < len(r2.out_tokens) < 8

    items = eng_a.extract_resident(backend="ref")
    assert len(items) == 1
    slot, req, wire, cur = items[0]
    eng_b = _mk_eng(cfg, params)
    rej = eng_b.admit(AdmissionBatch(
        [AdmissionItem(req, cur, ADMIT_MIGRATED, wire=wire)]),
        backend="ref")
    assert not rej
    eng_a.release(slot)
    while eng_b.active:
        eng_b.step()
    assert list(r2.out_tokens) == cold
    # source drained clean: slot refs gone, only the prefix index holds on
    assert eng_a.page_stats()["leaked_pages"] == 0
    eng_a.clear_prefix()
    assert eng_a.pool.n_in_use == 0
    eng_b.clear_prefix()
    assert eng_b.pool.n_in_use == 0
