"""Expert-parallel shard_map MoE == plain-jnp MoE, on a multi-device CPU
mesh (subprocess: forcing host devices must not leak into other tests)."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.models import moe
from repro.models.transformer import Runtime

cfg = get_reduced("qwen3-moe-235b-a22b")      # 8 experts, top-2
mesh = jax.make_mesh((2, 4), ("data", "model"))
p = moe.moe_init(cfg, jax.random.PRNGKey(0))

# seq-sharded path (prefill/train-like)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                      jnp.bfloat16)
y_ref, aux_ref = moe.moe_apply(cfg, p, x, capacity_factor=8.0)
y_sm, aux_sm = moe.moe_apply_shard_map(cfg, p, x, mesh=mesh,
                                       capacity_factor=8.0)
np.testing.assert_allclose(np.asarray(y_sm, np.float32),
                           np.asarray(y_ref, np.float32), atol=3e-2,
                           rtol=3e-2)
print("seq-shard OK")

# decode path (S=1 -> replicated tokens + psum combine)
xd = jax.random.normal(jax.random.PRNGKey(2), (4, 1, cfg.d_model),
                       jnp.bfloat16)
y_ref2, _ = moe.moe_apply(cfg, p, xd, capacity_factor=8.0)
y_sm2, _ = moe.moe_apply_shard_map(cfg, p, xd, mesh=mesh,
                                   capacity_factor=8.0)
np.testing.assert_allclose(np.asarray(y_sm2, np.float32),
                           np.asarray(y_ref2, np.float32), atol=3e-2,
                           rtol=3e-2)
print("decode OK")
"""


def test_shard_map_moe_matches_dense():
    out = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                         text=True, cwd=ROOT, timeout=600)
    assert "seq-shard OK" in out.stdout and "decode OK" in out.stdout, \
        (out.stdout[-1000:], out.stderr[-3000:])
