"""Chunked-prefill parity properties (DESIGN.md §11).

Pins the tentpole contract of SARATHI-style continuous batching: running a
prompt's prefill as fixed-token chunks (each chunk a suffix prefill over
the previous chunks' resident KV) must produce the SAME greedy tokens as
the one-shot prefill — dense and paged decode, page-straddling chunk
budgets, prefix-cache partial hits — and a cancel mid-chunk must free
every page exactly once (sanitizer-clean)."""
import jax
import numpy as np
import pytest

try:                         # optional dep: property tests sample widely
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # degrade to a fixed grid, don't skip parity
    HAVE_HYPOTHESIS = False

from conftest import admit_one

from repro.configs import get_reduced
from repro.models import build
from repro.serving.engine import (DecodeEngine, GenRequest, PartialPrefill,
                                  PrefillEngine)
from repro.serving.gateway import (PREFILLING, Gateway, SchedulerConfig,
                                   ServeRequest)

KEY = jax.random.PRNGKey(0)
MAX_NEW = 5


_MODEL = None


def _model():
    # module-level cache instead of a pytest fixture: hypothesis @given
    # tests can't take function arguments from fixtures
    global _MODEL
    if _MODEL is None:
        cfg = get_reduced("llama-30b")
        api = build(cfg)
        _MODEL = (cfg, api, api.init(KEY))
    return _MODEL


@pytest.fixture(scope="module")
def small_model():
    return _model()


def _prompt(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, n).astype(np.int32)


def _oneshot_tokens(cfg, params, toks, *, paged):
    pre = PrefillEngine(cfg, params, max_seq=128)
    dec = DecodeEngine(cfg, params, max_slots=2, max_seq=128, paged=paged)
    req = GenRequest(0, toks.copy(), MAX_NEW)
    (r, w, f), = pre.run([req], backend="ref")
    assert admit_one(dec, r, f, wire=w, backend="ref")
    while dec.active:
        dec.step()
    return list(req.out_tokens), w


def _chunked_tokens(cfg, params, toks, budget, *, paged):
    pre = PrefillEngine(cfg, params, max_seq=128)
    dec = DecodeEngine(cfg, params, max_slots=2, max_seq=128, paged=paged)
    req = GenRequest(1, toks.copy(), MAX_NEW)
    job = PartialPrefill(req)
    ticks = 0
    while not job.done:
        pre.prefill_chunk([job], budget, backend="ref")
        ticks += 1
        assert ticks <= len(toks) + 2, "chunk loop failed to make progress"
    assert ticks == -(-len(toks) // budget)
    assert admit_one(dec, req, job.first, wire=job.wire(), backend="ref")
    while dec.active:
        dec.step()
    return list(req.out_tokens), job


# chunk budgets chosen to straddle the 16-token page boundary from both
# sides (and one that divides it exactly); prompt lengths likewise leave
# ragged final chunks and mid-page prompt ends
_BUDGETS, _LENS = [7, 13, 16, 23], [20, 39, 50]


def _parity_cases(fn):
    """hypothesis sweep when available, a fixed straddle grid otherwise."""
    if HAVE_HYPOTHESIS:
        return settings(max_examples=6, deadline=None)(given(
            st.sampled_from(_BUDGETS), st.sampled_from(_LENS),
            st.integers(0, 2 ** 31 - 1))(fn))
    return pytest.mark.parametrize(
        "budget,n,seed",
        [(7, 20, 0), (13, 39, 1), (16, 50, 2), (23, 39, 3)])(fn)


@_parity_cases
def test_chunked_prefill_token_parity_dense(budget, n, seed):
    cfg, api, params = _model()
    toks = _prompt(cfg, n, seed)
    one, _ = _oneshot_tokens(cfg, params, toks, paged=False)
    chk, _ = _chunked_tokens(cfg, params, toks, budget, paged=False)
    assert chk == one, f"budget={budget} n={n}: {chk} != {one}"


@_parity_cases
def test_chunked_prefill_token_parity_paged(budget, n, seed):
    cfg, api, params = _model()
    toks = _prompt(cfg, n, seed)
    one, _ = _oneshot_tokens(cfg, params, toks, paged=True)
    chk, _ = _chunked_tokens(cfg, params, toks, budget, paged=True)
    assert chk == one, f"budget={budget} n={n}: {chk} != {one}"


def test_chunked_transport_wire_bit_identical_to_oneshot(small_model):
    """Chunk KV stays RAW until the job completes, then the spliced whole
    is quantized once with position-aligned groups — so the admission
    wire's int4 payloads equal a one-shot extraction's BIT-identically
    (same floats into the same quantizer layout)."""
    cfg, api, params = small_model
    toks = _prompt(cfg, 40, seed=11)
    _, w_one = _oneshot_tokens(cfg, params, toks, paged=False)
    _, job = _chunked_tokens(cfg, params, toks, 16, paged=False)
    assert len(job.wires) == 3                   # raw per-chunk wires
    assert all(wt.kind == "raw" for w in job.wires
               for s in w.slots.values() for wt in s.values())
    w_chunk = job.wire()
    assert w_chunk.request_len == w_one.request_len == 40
    for name, tens in w_chunk.slots.items():
        for key, wt in tens.items():
            ref = w_one.slots[name][key]
            assert (wt.kind, wt.orig_shape) == (ref.kind, ref.orig_shape)
            for part, a in wt.payload.items():
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(ref.payload[part]),
                    err_msg=f"{name}/{key}/{part}")


def test_gateway_chunked_prefix_partial_hit_parity(small_model):
    """Prefix-cache partial hits compose with chunking: the suffix prefill
    itself runs chunked, and the spliced result decodes the same greedy
    tokens as a one-shot gateway serving the identical trace."""
    cfg, api, params = small_model

    def serve(chunk_tokens):
        gw = Gateway(
            [PrefillEngine(cfg, params, max_seq=128)],
            [DecodeEngine(cfg, params, max_slots=4, max_seq=128,
                          paged=True, prefix_sharing=True)],
            scheduler=SchedulerConfig(prefill_chunk_tokens=chunk_tokens),
            backend="ref")
        shared = _prompt(cfg, 32, seed=21)          # two full pages
        long_a = np.concatenate([shared, _prompt(cfg, 24, seed=22)])
        long_b = np.concatenate([shared, _prompt(cfg, 30, seed=23)])
        h1 = gw.submit(GenRequest(0, long_a.copy(), MAX_NEW))
        gw.run_until_drained(max_iters=300)         # donate the chain
        h2 = gw.submit(GenRequest(1, long_b.copy(), MAX_NEW))
        gw.run_until_drained(max_iters=300)
        assert gw.n_prefix_partial >= 1, "second prompt must partial-hit"
        return list(h1.req.out_tokens), list(h2.req.out_tokens), gw

    one_a, one_b, gw1 = serve(0)
    chk_a, chk_b, gw2 = serve(13)                   # straddles page ends
    assert gw2.n_chunked_prefills >= 2
    assert chk_a == one_a
    assert chk_b == one_b


def test_cancel_mid_chunk_frees_pages_exactly_once(small_model, monkeypatch):
    """A request cancelled between chunk ticks: its job leaves the chunk
    set, pins are dropped, and after the drain the paged pool holds zero
    leaked pages (sanitizer-enabled run)."""
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    cfg, api, params = small_model
    dec = DecodeEngine(cfg, params, max_slots=4, max_seq=128, paged=True,
                       prefix_sharing=True)
    gw = Gateway([PrefillEngine(cfg, params, max_seq=128)], [dec],
                 scheduler=SchedulerConfig(prefill_chunk_tokens=8),
                 backend="ref")
    assert gw.sanitizer is not None
    victim = gw.submit(GenRequest(0, _prompt(cfg, 60, seed=31), MAX_NEW))
    rest = [gw.submit(GenRequest(1 + i, _prompt(cfg, 20, seed=32 + i),
                                 MAX_NEW)) for i in range(2)]
    gw.pump()                                      # one chunk tick in
    assert victim.state == PREFILLING and gw._chunks
    assert gw.cancel(victim)
    assert all(c.handle is not victim for c in gw._chunks)
    assert not gw.cancel(victim)                   # idempotent: freed once
    gw.run_until_drained(max_iters=300)
    for h in rest:
        assert len(h.req.out_tokens) == MAX_NEW
    st_pages = dec.page_stats()
    assert st_pages["leaked_pages"] == 0
    assert st_pages["in_use"] == 0 or st_pages["in_use"] == \
        st_pages["prefix_pages"], "only donated prefix chains may remain"
    gw.sanitize_check("cancel_mid_chunk")          # raises on violations


def test_cancelled_mid_chunk_preempt_requeue(small_model):
    """Killing the prefill replica mid-chunk requeues the partially
    prefilled request through the normal path — no token loss, restart
    counted — once a replacement replica exists."""
    cfg, api, params = small_model
    pres = [PrefillEngine(cfg, params, max_seq=128) for _ in range(2)]
    gw = Gateway(pres,
                 [DecodeEngine(cfg, params, max_slots=4, max_seq=128)],
                 scheduler=SchedulerConfig(prefill_chunk_tokens=8),
                 backend="ref")
    h = gw.submit(GenRequest(0, _prompt(cfg, 48, seed=41), MAX_NEW))
    gw.pump()
    assert gw._chunks
    busy = gw._chunks[0].pre
    gw.kill_replica("prefill", busy.idx)
    assert not gw._chunks and h in gw.queue and h.restarts == 1
    gw.run_until_drained(max_iters=300)
    assert len(h.req.out_tokens) == MAX_NEW
