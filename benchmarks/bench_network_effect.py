"""Paper Appendix H (Table 5, Figs. 16-17): effect of inter-instance
bandwidth on phase splitting. 4xA40 + 4x3090Ti; at 40 Gbps the scheduler
dedicates A40->prefill / 3090Ti->decode (cross-instance KV); at 5 Gbps it
colocates phases within instances (KV stays on fast intra-node links).
A non-disaggregated baseline anchors the speedups."""
from benchmarks.common import CFG, SLO, row
from repro.core import scheduler
from repro.core.cluster import _build
from repro.core.simulator import simulate
from repro.core.workload import Workload, generate

WL = Workload("appendixH", mean_in=1024, mean_out=64, cv_in=0.3, cv_out=0.5)


def _cluster(inter_bw):
    return _build([("A40", 4), ("3090Ti", 4)], intra_bw=12e9,
                  inter_bw=inter_bw, seed=0, jitter=0.05)


def run(quick: bool = False):
    rows = []
    rate = 1.0
    reqs = generate(WL, rate=rate, duration=30 if quick else 60, seed=2)
    for label, bw in (("40gbps", 5e9), ("5gbps", 0.625e9)):
        cluster = _cluster(bw)
        plan = scheduler.schedule(cluster, CFG, WL, rate, SLO,
                                  n_step=15 if quick else 30, seed=0)
        res = simulate(cluster, CFG, plan.replicas, plan.orchestration,
                       reqs, SLO)
        # cross-instance KV? check whether any prefill->decode pair spans nodes
        cross = False
        for p in plan.prefill_replicas:
            for d in plan.decode_replicas:
                pn = {cluster.devices[i].node for i in p.devices}
                dn = {cluster.devices[i].node for i in d.devices}
                if pn != dn:
                    cross = True
        rows.append(row(
            f"network_{label}", res.throughput_tokens,
            f"thpt={res.throughput_tokens:.0f};e2e={res.e2e_attain:.3f};"
            f"P={len(plan.prefill_replicas)};D={len(plan.decode_replicas)};"
            f"cross_instance_kv={cross}"))
    # non-disaggregated baseline at 40 Gbps
    cluster = _cluster(5e9)
    from repro.core import baselines
    hb = baselines.hexgen_like(cluster, CFG, WL, rate, SLO)
    resb = simulate(cluster, CFG, hb.replicas, hb.orchestration, reqs, SLO,
                    colocated=True, compress=False)
    rows.append(row("network_nodisagg_baseline", resb.throughput_tokens,
                    f"thpt={resb.throughput_tokens:.0f};"
                    f"e2e={resb.e2e_attain:.3f};paper_table5=1610tok/s"))
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
