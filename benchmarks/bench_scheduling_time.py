"""Paper Fig. 10: scheduling-from-scratch convergence time vs cluster size
(16 / 24 / 32 GPUs). The paper reports ~21/36/54 s; our reimplementation is
faster in absolute terms (pure-python cost model + LP), the scaling trend is
the comparable quantity."""
import time

from benchmarks.common import CFG, SLO, row
from repro.core import scheduler
from repro.core.cluster import make_paper_cloud
from repro.core.workload import CONVERSATION


def run(quick: bool = False):
    rows = []
    full = make_paper_cloud()
    sizes = {16: list(range(0, 8)) + list(range(8, 16)),
             24: list(range(0, 24)),
             32: list(range(0, 32))}
    for n, idxs in sizes.items():
        cluster = full.subset(idxs)
        t0 = time.perf_counter()
        plan = scheduler.schedule(cluster, CFG, CONVERSATION, 2.0, SLO,
                                  n_step=50 if not quick else 15, seed=0)
        dt = time.perf_counter() - t0
        rows.append(row(
            f"sched_time_{n}gpu", dt * 1e6,
            f"seconds={dt:.2f};evals={plan.evals};score={plan.score:.3f};"
            f"paper_reference_s={{16:21,24:36,32:54}}[{n}]"))
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
