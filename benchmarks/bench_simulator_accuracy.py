"""Paper Fig. 19 (Appendix J): accuracy of the analytic SLO estimator and
alpha-beta KV model against the event simulator ("measured" stand-in on this
CPU-only container): correlation + mean relative error across operating
points."""
import numpy as np

from benchmarks.common import CFG, SLO, cloud, plan_for, row
from repro.core import costmodel as cm
from repro.core import orchestrator as orch
from repro.core.simulator import simulate
from repro.core.workload import CONVERSATION, generate


def run(quick: bool = False):
    rows = []
    cluster = cloud()
    plan = plan_for(CONVERSATION, 2.0)
    est, meas = [], []
    scales = (1.0, 2.0, 4.0) if quick else (1.0, 1.5, 2.0, 3.0, 4.0, 6.0)
    solver_slo = [SLO.scaled(s) for s in scales]
    for slo_s in solver_slo:
        o = orch.orchestrate(cluster, CFG, plan.prefill_replicas,
                             plan.decode_replicas, CONVERSATION, 2.0, slo_s)
        est.append(o.attainment)
        reqs = generate(CONVERSATION, rate=2.0,
                        duration=30 if quick else 60, seed=17)
        meas.append(simulate(cluster, CFG, plan.replicas, o, reqs,
                             slo_s).e2e_attain)
    est, meas = np.array(est), np.array(meas)
    corr = float(np.corrcoef(est, meas)[0, 1]) if len(est) > 2 else 1.0
    mre = float(np.mean(np.abs(est - meas) / np.maximum(meas, 0.05)))
    rows.append(row("simulator_estimator_corr", corr * 1e6,
                    f"pearson={corr:.3f};mean_rel_err={mre:.3f};"
                    f"points={len(est)}"))
    # alpha-beta KV transfer model vs bytes/bandwidth first principles
    t_model = cm.kv_transfer_time(cluster, CFG, [0], [8], 1024,
                                  compress=True)
    kv_bytes = 1024 * cm.kv_bytes_per_token(CFG) * cm.INT4_WIRE_FACTOR
    beta = cluster.min_bw_between([0], [8])
    t_first = cluster.alpha + kv_bytes / beta
    rows.append(row("alphabeta_kv_model", t_model * 1e6,
                    f"model_s={t_model:.4f};first_principles_s={t_first:.4f};"
                    f"rel_err={abs(t_model-t_first)/t_first:.4f}"))
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
