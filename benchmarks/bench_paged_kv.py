"""Paged int4-resident decode KV cache vs the dense slotted cache.

Three measurements on the REAL reduced-config engines (CPU):

1. **equal batch**: tokens/s of the paged engine vs the dense engine
   draining the same request set — the paged path must stay within ~10%
   (the fused-dequant read is the price of 7x smaller residency).
2. **capacity at fixed cache memory**: give both engines the SAME byte
   budget (the dense engine's ``max_slots x max_seq`` bf16 slab) and count
   how many concurrent decodes each admits under a skewed prompt-length
   scenario. Page-budget admission + int4 residency is the paper's
   cost-efficiency lever: acceptance wants >= 1.5x, arithmetic says ~7x
   before raggedness even helps.
3. **long-context skewed scenario**: mostly-short prompts with a long
   tail, paged occupancy / internal fragmentation / zero-dequant insert
   counts over a full drain.

Emits ``BENCH_paged_kv.json`` (gated by ``scripts/check_bench.py``).
"""
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import row

BENCH_JSON = Path("BENCH_paged_kv.json")


def _tree_nbytes(tree) -> int:
    import jax
    return int(sum(a.nbytes for a in jax.tree_util.tree_leaves(tree)))


def _make_reqs(cfg, lens, max_new, seed=7):
    from repro.serving.engine import GenRequest
    rng = np.random.default_rng(seed)
    return [GenRequest(i, rng.integers(
        1, cfg.vocab_size, int(l)).astype(np.int32), max_new_tokens=max_new)
        for i, l in enumerate(lens)]


def _drain_tokens_per_s(pre, eng, reqs, *, repeats=1):
    from repro.serving.engine import AdmissionBatch, AdmissionItem
    done, dt_total, toks = [], 0.0, 0
    for rep in range(repeats):
        for i, r in enumerate(reqs):
            r.out_tokens = []
        wires = pre.run(reqs, backend="ref")
        for r, w, f in wires:
            rej = eng.admit(AdmissionBatch([AdmissionItem(r, f, wire=w)]),
                            backend="ref")
            assert not rej, "admission must fit"
        t0 = time.perf_counter()
        batch_done = []
        while eng.active:
            batch_done += eng.step()
        dt_total += time.perf_counter() - t0
        toks += sum(len(r.out_tokens) for r in batch_done)
        done = batch_done
    return toks / dt_total, done


def run(quick: bool = False):
    import jax

    from repro.configs import get_reduced
    from repro.models import build
    from repro.serving.engine import (AdmissionBatch, AdmissionItem,
                                      DecodeEngine, PrefillEngine)

    cfg = get_reduced("llama-30b")
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    page_size = 16
    max_seq = 128
    n_req = 8
    max_new = 16 if quick else 48
    lens = [16, 24, 32, 16, 24, 32, 16, 24][:n_req]

    pre = PrefillEngine(cfg, params, max_seq=max_seq)
    report = {"model": cfg.name, "page_size": page_size,
              "max_seq": max_seq, "n_requests": n_req,
              "max_new_tokens": max_new}

    # 1. tokens/s at equal batch ------------------------------------------
    engines = {
        "dense": DecodeEngine(cfg, params, max_slots=n_req, max_seq=max_seq,
                              chunk_size=16),
        "paged_int4": DecodeEngine(cfg, params, max_slots=n_req,
                                   max_seq=max_seq, chunk_size=16,
                                   paged=True, page_size=page_size),
    }
    eq = {}
    for name, eng in engines.items():
        _drain_tokens_per_s(pre, eng, _make_reqs(cfg, lens, max_new))  # warm
        tps, done = _drain_tokens_per_s(pre, eng,
                                        _make_reqs(cfg, lens, max_new),
                                        repeats=1 if quick else 2)
        eq[name] = {"tokens_per_s": tps,
                    "n_done": len(done)}
    ratio = eq["paged_int4"]["tokens_per_s"] / eq["dense"]["tokens_per_s"]
    report["equal_batch"] = {**eq, "paged_over_dense": ratio,
                            "within_10pct": bool(ratio >= 0.9)}

    # 2. concurrent capacity at a fixed cache-memory budget ----------------
    from repro.models import transformer
    dense_slots = 4
    budget = _tree_nbytes(transformer.init_cache(cfg, dense_slots, max_seq))
    probe = DecodeEngine(cfg, params, max_slots=1, max_seq=max_seq,
                         paged=True, page_size=page_size, num_pages=2)
    page_bytes = _tree_nbytes(
        {k: v for k, v in probe.cache.items()
         if k not in ("lengths", "page_table")}) // 2
    num_pages = max(2, budget // page_bytes)
    many = DecodeEngine(cfg, params, max_slots=256, max_seq=max_seq,
                        paged=True, page_size=page_size,
                        num_pages=num_pages)
    # skewed scenario: mostly short prompts, occasional long ones
    rng = np.random.default_rng(3)
    cap_lens = rng.choice([8, 12, 16, 24, 48, 96], size=192,
                          p=[.3, .25, .2, .15, .07, .03])
    cap_new = 8
    admitted = 0
    for i in range(0, len(cap_lens), 8):
        reqs = _make_reqs(cfg, cap_lens[i:i + 8], cap_new, seed=i)
        wires = pre.run(reqs, backend="ref")
        rejected = many.admit(AdmissionBatch(
            [AdmissionItem(r, f, wire=w) for r, w, f in wires]),
            backend="ref")
        admitted += len(wires) - len(rejected)
        if rejected:
            break
    cap_ratio = admitted / dense_slots
    report["capacity_fixed_mem"] = {
        "budget_mb": budget / 1e6,
        "page_bytes": page_bytes,
        "pages": many.pool.capacity,
        "dense_slots": dense_slots,
        "paged_concurrent": admitted,
        "paged_over_dense": cap_ratio,
        "occupancy": many.pool.occupancy(),
    }

    # 3. long-context skewed drain on the paged engine ---------------------
    long_seq = 256
    pre_long = PrefillEngine(cfg, params, max_seq=long_seq)
    rng = np.random.default_rng(5)
    n_long = 8 if quick else 16
    long_lens = rng.choice([16, 24, 32, 64, 160],
                           p=[.35, .25, .2, .12, .08], size=n_long)
    eng_long = DecodeEngine(cfg, params, max_slots=n_long, max_seq=long_seq,
                            chunk_size=16, paged=True, page_size=page_size)
    _drain_tokens_per_s(pre_long, eng_long,
                        _make_reqs(cfg, long_lens[:4], 8))      # warm
    t_long, _ = _drain_tokens_per_s(pre_long, eng_long,
                                    _make_reqs(cfg, long_lens, max_new))
    st = eng_long.page_stats()
    report["long_context"] = {
        "max_seq": long_seq,
        "tokens_per_s": t_long,
        "peak_pages_in_use": st["peak_in_use"],
        "page_budget": st["pages"],
        "zero_copy_inserts": st["zero_copy_inserts"],
        "reencoded_inserts": st["reencoded_inserts"],
    }

    BENCH_JSON.write_text(json.dumps(report, indent=2))
    rows = [
        row("paged_kv_equal_batch_paged",
            eq["paged_int4"]["tokens_per_s"],
            f"tokens_per_s={eq['paged_int4']['tokens_per_s']:.1f};"
            f"vs_dense={ratio:.2f}x;json={BENCH_JSON}"),
        row("paged_kv_equal_batch_dense", eq["dense"]["tokens_per_s"],
            f"tokens_per_s={eq['dense']['tokens_per_s']:.1f}"),
        row("paged_kv_capacity_fixed_mem", cap_ratio,
            f"paged_concurrent={admitted};dense_slots={dense_slots};"
            f"ratio={cap_ratio:.1f}x;budget_mb={budget/1e6:.2f}"),
        row("paged_kv_long_context", t_long,
            f"tokens_per_s={t_long:.1f};"
            f"peak_pages={st['peak_in_use']}/{st['pages']};"
            f"zero_copy_inserts={st['zero_copy_inserts']}"),
    ]
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
