"""Paper Figs. 7-8: SLO attainment vs SLO scale, ThunderServe vs baselines.

Cloud setting: ThunderServe vs HexGen-like on the heterogeneous 32-GPU pool.
In-house setting (same price budget): vLLM-like and DistServe-like on
8xA100. Metric: minimum SLO scale reaching 90% E2E attainment (lower is
better), per workload x arrival rate.
"""
from benchmarks.common import CFG, SLO, cloud, plan_for, row
from repro.core import baselines
from repro.core.simulator import min_slo_scale_for, simulate
from repro.core.workload import CODING, CONVERSATION, generate


def run(quick: bool = False):
    rows = []
    cluster = cloud()
    rates = (1.0, 2.0) if quick else (1.0, 2.0, 4.0)
    for wl in (CODING, CONVERSATION):
        for rate in rates:
            reqs = generate(wl, rate=rate, duration=30 if quick else 60,
                            seed=11)
            plan = plan_for(wl, rate)
            systems = {
                "thunderserve": (cluster, plan.replicas, plan.orchestration,
                                 False, True),
            }
            hx = baselines.hexgen_like(cluster, CFG, wl, rate, SLO)
            systems["hexgen"] = (cluster, hx.replicas, hx.orchestration,
                                 True, False)
            vl = baselines.vllm_like(CFG, wl, rate, SLO)
            systems["vllm"] = (vl.cluster, vl.replicas, vl.orchestration,
                               True, False)
            ds = baselines.distserve_like(CFG, wl, rate, SLO)
            systems["distserve"] = (ds.cluster, ds.replicas,
                                    ds.orchestration, False, False)
            scales = {}
            for name, (cl, reps, o, colo, comp) in systems.items():
                import functools
                from repro.core import simulator as S

                def sim_at(scale, cl=cl, reps=reps, o=o, colo=colo,
                           comp=comp):
                    return simulate(cl, CFG, reps, o, reqs,
                                    SLO.scaled(scale), compress=comp,
                                    colocated=colo)
                s = float("inf")
                for sc in (1.0, 1.5, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0):
                    if sim_at(sc).e2e_attain >= 0.9:
                        s = sc
                        break
                scales[name] = s
            base = scales["thunderserve"]
            for name, s in scales.items():
                speedup = (s / base) if base > 0 and s < float("inf") else 0
                rows.append(row(
                    f"slo_scale_{wl.name}_r{rate:g}_{name}", s * 1e6,
                    f"min_scale_for_90pct={s:g};vs_thunderserve={speedup:.2f}x"))
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
